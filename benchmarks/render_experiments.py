"""Render §Dry-run and §Roofline markdown tables for EXPERIMENTS.md from
results/dryrun_sweep.jsonl."""
from __future__ import annotations

import json
import sys

from benchmarks.bench_roofline import load


def fmt_bytes(b):
    if b is None:
        return "?"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def advice(r):
    t = r["roofline"]
    b = t["bottleneck"]
    arch, shape = r["arch"], r["shape"]
    if b == "collective_s":
        if "mixtral" in arch or "llama4" in arch or "moonshot" in arch:
            return "MoE dispatch gathers the full token buffer; localize dispatch / all-to-all"
        return "FSDP weight all-gathers dominate; bigger per-chip batch or 1D sharding"
    if b == "memory_s":
        if shape == "train_4k":
            return "fp32 elementwise chains at layer boundaries; fuse + keep residuals bf16"
        return "KV/state streaming; shrink cache dtype or window"
    return "compute-bound — already near the FLOP roof; only precision/algorithm cuts help"


def main(path=None):
    rows = load(path) if path else load()
    single = [r for r in rows if r["mesh"] == "16x16" and not r.get("p4")]
    multi = [r for r in rows if r["mesh"] == "2x16x16" and not r.get("p4")]
    single.sort(key=lambda r: (r["arch"], r["shape"]))
    multi.sort(key=lambda r: (r["arch"], r["shape"]))

    print("### Dry-run results (production artifact: lower + compile; "
          "memory_analysis of the scanned module)\n")
    print("| arch | shape | mesh | lower s | compile s | args/chip | temp/chip | collectives (ag/ar/rs/a2a/cp) | notes |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in single + multi:
        m = r["memory"]
        c = r["collectives"]["counts"] if "counts" in r["collectives"] else {}
        cs = "/".join(str(int(c.get(k, 0))) for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['lower_s']} | "
              f"{r['compile_s']} | {fmt_bytes(m['argument_bytes'])} | "
              f"{fmt_bytes(m['temp_bytes'])} | {cs} | "
              f"{'; '.join(r['notes']) or '—'} |")

    print("\n### Roofline terms (single-pod 16×16; per-chip seconds; "
          "v5e 197 TF/s, 819 GB/s, 50 GB/s/link)\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck | "
          "N_total | N_active | MODEL_FLOPs/HLO_FLOPs | what moves the bottleneck |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in single:
        t = r["roofline"]
        u = r.get("useful_flops_ratio")
        print(f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
              f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
              f"**{t['bottleneck'][:-2]}** | {r['params_total']/1e9:.1f}B | "
              f"{r['params_active']/1e9:.1f}B | "
              f"{u if u is None else round(u, 3)} | {advice(r)} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
