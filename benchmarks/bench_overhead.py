"""Paper §4.5 — P4 overhead on constrained hardware: run time per phase,
memory, and communication bandwidth (message bytes, pickle-serialized exactly
like the paper's RPi setup). Power draw is hardware-gated → N/A.

Paper reference points (RPi 4B, linear/CIFAR-10): phase-1 pair 0.04 s,
35-peer sampling ≈1.4 s total; phase-2 pair 5.27 s; weights message 622.82 kB;
phase-2 messages 1246.57 kB total.
"""
from __future__ import annotations

import resource
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer
from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
from repro.core.grouping import pairwise_l1
from repro.core.p2p import P2PNetwork, simulate_group_round, simulate_phase1
from repro.core.p4 import P4Trainer
from repro.core.scattering import scatter_feature_dim


def run(quick: bool = True):
    rows = []
    # the paper's phase-2 device pair: linear model on CIFAR-10 ScatterNet
    feat = scatter_feature_dim((32, 32, 3))       # 15552
    classes = 10
    cfg = RunConfig(dp=DPConfig(epsilon=15.0, rounds=100, sample_rate=0.5),
                    p4=P4Config(group_size=2, sample_peers=1),
                    train=TrainConfig(learning_rate=0.5))
    trainer = P4Trainer(feat_dim=feat, num_classes=classes, cfg=cfg)
    M = 2
    key = jax.random.PRNGKey(0)
    states = trainer.init_clients(key, M)
    xs = jax.random.normal(key, (M, 32, feat))
    ys = jax.random.randint(key, (M, 32), 0, classes)

    # ---- phase 1: similarity computation + message ------------------------
    net = P2PNetwork(M)
    one_client_params = jax.tree_util.tree_map(lambda t: t[0], states["proxy"])
    # stacked (M, ...) tree: simulate_phase1 slices out the initiator's own
    # (D,) weights per message (the paper's 622.82 kB figure is per client)
    t_msg = simulate_phase1(net, states["proxy"], [(0, 1)])
    w = jnp.stack([jnp.concatenate([states["proxy"]["w"][i].ravel(),
                                    states["proxy"]["b"][i]]) for i in range(M)])
    with Timer() as t1:
        d = pairwise_l1(w)
        d.block_until_ready()
    phase1_pair_s = t1.dt + t_msg
    rows.append(("overhead_phase1_pair_s", phase1_pair_s * 1e6, round(phase1_pair_s, 4)))
    rows.append(("overhead_phase1_35peers_s", 0.0, round(35 * phase1_pair_s, 3)))
    msg_kb = net.total_bytes("phase1_weights") / 1e3
    rows.append(("overhead_phase1_msg_kB", 0.0, round(msg_kb, 2)))

    # ---- phase 2: one co-training round between two clients ---------------
    trainer.local_round(states, xs, ys, key)      # compile once
    with Timer() as t2:
        states2, _ = trainer.local_round(states, xs, ys, jax.random.fold_in(key, 1))
        jax.tree_util.tree_leaves(states2)[0].block_until_ready()
    simulate_group_round(net, [0, 1], one_client_params, rnd=0)
    phase2_kb = net.total_bytes("proxy_update") / 1e3 + net.total_bytes("aggregated_model") / 1e3
    rows.append(("overhead_phase2_round_s", t2.dt * 1e6, round(t2.dt, 4)))
    rows.append(("overhead_phase2_msgs_kB", 0.0, round(phase2_kb, 2)))

    # ---- memory ------------------------------------------------------------
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    rows.append(("overhead_peak_rss_MB", 0.0, round(peak_mb, 1)))
    rows.append(("overhead_power_W", 0.0, "NA-hardware-gated"))

    print(f"[overhead] phase1_pair={phase1_pair_s:.3f}s phase1_msg={msg_kb:.1f}kB "
          f"phase2_round={t2.dt:.3f}s phase2_msgs={phase2_kb:.1f}kB "
          f"rss={peak_mb:.0f}MB", flush=True)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
