"""Kernel micro-benchmarks: interpret-mode Pallas vs pure-jnp oracle (CPU
wall-time is NOT a TPU signal — recorded for regression tracking; correctness
sweeps live in tests/test_kernels.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip import ops as dp_ops, ref as dp_ref
from repro.kernels.l1_distance import ops as l1_ops, ref as l1_ref


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 8192))
    rows.append(("kernel_dp_clip_pallas_us",
                 _time(lambda a: dp_ops.clip_accumulate_flat(a, 1.0), x), 16 * 8192))
    rows.append(("kernel_dp_clip_ref_us",
                 _time(lambda a: dp_ref.clip_accumulate(a, 1.0), x), 16 * 8192))
    w = jax.random.normal(key, (16, 4096))
    rows.append(("kernel_l1_pallas_us", _time(l1_ops.pairwise_l1, w), 16 * 16))
    rows.append(("kernel_l1_ref_us", _time(l1_ref.pairwise_l1, w), 16 * 16))
    for name, us, d in rows:
        print(f"[kernels] {name} {us:.0f}us")
    return rows


if __name__ == "__main__":
    run()
