"""Kernel micro-benchmarks: backend sweep (compiled pallas / interpret / ref
/ dispatched) across shapes, with speedup ratios vs the jnp reference.

CPU wall-time is NOT a TPU signal — it is recorded for regression tracking
and to enforce the dispatch policy: the dispatched path must track the jnp
reference on CPU (interpret-mode Pallas is never silently selected — it is
benchmarked here explicitly so the gap stays visible). Correctness sweeps
live in tests/test_kernels.py and tests/test_dispatch.py.

``run()`` stashes machine-readable records in ``LAST_RECORDS`` which
``benchmarks/run.py`` writes to BENCH_kernels.json at the repo root.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.config import KernelConfig
from repro.kernels import dispatch

# records from the most recent run(); benchmarks/run.py serializes them
LAST_RECORDS: list = []

_DP_SHAPES = {True: [(16, 8192), (32, 32768), (8, 131072)],
              False: [(32, 8192), (64, 65536), (16, 262144)]}
_L1_SHAPES = {True: [(16, 4096), (32, 16384), (8, 65536)],
              False: [(32, 16384), (64, 65536), (16, 131072)]}

# interpret mode above this element count takes minutes on CPU — skip
_INTERPRET_ELEM_CAP = 4 << 20


def _time(fn, *args, n=3):
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _backends():
    """(label, KernelConfig) pairs to sweep; 'dispatch' is the auto policy."""
    out = [("ref", KernelConfig(backend="ref")),
           ("dispatch", KernelConfig(backend="auto"))]
    if jax.default_backend() in dispatch._PALLAS_PLATFORMS:
        out.insert(1, ("pallas", KernelConfig(backend="pallas")))
    out.append(("interpret", KernelConfig(backend="interpret")))
    return out


def _sweep(kernel_name, shapes, make_args, call):
    rows, recs = [], []
    for shape in shapes:
        args = make_args(shape)
        ref_us = None
        for label, cfg in _backends():
            if (label == "interpret"
                    and shape[0] * shape[1] > _INTERPRET_ELEM_CAP):
                continue
            resolved = dispatch.resolve_backend(cfg.backend)
            us = _time(lambda *a: call(cfg, *a), *args)
            if label == "ref":
                ref_us = us
            ratio = (us / ref_us) if ref_us else None
            tag = f"{kernel_name}_{shape[0]}x{shape[1]}_{label}"
            rows.append((f"kernel_{tag}_us", us,
                         f"{ratio:.2f}x_ref" if ratio else shape[0] * shape[1]))
            recs.append({"kernel": kernel_name, "shape": list(shape),
                         "backend": label, "resolved": resolved, "us": us,
                         "vs_ref": ratio})
    return rows, recs


def run(quick: bool = True):
    key = jax.random.PRNGKey(0)
    platform = jax.default_backend()

    def dp_args(shape):
        return (jax.random.normal(key, shape),)

    def dp_call(cfg, x):
        return dispatch.dp_clip_flat(x, 1.0, key, sigma=0.5, kernels=cfg)

    def l1_args(shape):
        return (jax.random.normal(key, shape),)

    def l1_call(cfg, w):
        return dispatch.pairwise_l1(w, kernels=cfg)

    rows_dp, recs_dp = _sweep("dp_clip", _DP_SHAPES[quick], dp_args, dp_call)
    rows_l1, recs_l1 = _sweep("l1_distance", _L1_SHAPES[quick], l1_args, l1_call)

    rows = rows_dp + rows_l1
    LAST_RECORDS.clear()
    LAST_RECORDS.extend(recs_dp + recs_l1)
    for name, us, d in rows:
        print(f"[kernels] {name} {us:.0f}us ({d})")

    # dispatch-policy guard: on CPU the dispatched path resolves to the jnp
    # reference, so its wall time must track ref (never interpret's)
    worst = max((r["vs_ref"] for r in LAST_RECORDS
                 if r["backend"] == "dispatch" and r["vs_ref"]), default=None)
    if worst is not None:
        print(f"[kernels] dispatched worst-case vs ref: {worst:.2f}x "
              f"(platform={platform})")
    return rows


if __name__ == "__main__":
    run()
