"""Paper Fig. 7 — P4 accuracy across privacy budgets ε ∈ [3, 20] vs non-DP
local training (with and without handcrafted features), alpha-based γ=50%.

Claim validated: P4 beats local training even at ε = 3, and degrades
gracefully as ε tightens.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import Timer, client_split, feature_pool
from repro.baselines import local
from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
from repro.core.p4 import P4Trainer


def run(quick: bool = True, dataset: str = "femnist"):
    rows = []
    M, R = (16, 96) if quick else (32, 160)
    rounds = 40 if quick else 100
    batch = 24
    feats, rawf, labels, stats = feature_pool(dataset, 60 if quick else 120)
    trx, try_, tex, tey = client_split(feats, labels, M=M, R=R,
                                       mode="alpha", level=0.5)
    rtrx, rtry, rtex, rtey = client_split(rawf, labels, M=M, R=R,
                                          mode="alpha", level=0.5)
    tex_j, tey_j = jnp.asarray(tex), jnp.asarray(tey)

    # local baselines (no DP — data never leaves the client)
    _, h = local.train(trx, try_, tex_j, tey_j, rounds=rounds, lr=0.5,
                       batch_size=batch, eval_every=max(rounds - 1, 1))
    rows.append(("privacy_local_hc", 0.0, round(h[-1][1], 4)))
    _, h = local.train(rtrx, rtry, jnp.asarray(rtex), jnp.asarray(rtey),
                       rounds=rounds, lr=0.05, batch_size=batch,
                       eval_every=max(rounds - 1, 1))
    rows.append(("privacy_local_raw", 0.0, round(h[-1][1], 4)))

    for eps in ([3, 15] if quick else [3, 5, 10, 15, 20]):
        cfg = RunConfig(dp=DPConfig(epsilon=float(eps), rounds=rounds,
                                    sample_rate=batch / R),
                        p4=P4Config(group_size=4, sample_peers=min(10, M - 1)),
                        train=TrainConfig(learning_rate=0.5))
        tr = P4Trainer(feat_dim=trx.shape[-1], num_classes=stats["L"], cfg=cfg)
        with Timer() as t:
            _, _, hist = tr.fit(trx, try_, tex_j, tey_j, rounds=rounds,
                                eval_every=max(rounds - 1, 1), batch_size=batch)
        rows.append((f"privacy_p4_eps{eps}", t.dt * 1e6 / rounds,
                     round(hist[-1][1], 4)))
        # the RDP-accounted spend of the Eq. 12 sigma, read from the engine's
        # ledger record rather than recomputed here
        spent = hist.metrics.get("dp_epsilon", [float("nan")])[-1]
        print(f"[privacy] eps={eps} p4={hist[-1][1]:.3f} sigma={tr.sigma:.2f} "
              f"rdp_spent={spent:.2f}", flush=True)
    print(f"[privacy] local_hc={rows[0][2]} local_raw={rows[1][2]}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
