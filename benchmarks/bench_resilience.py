"""Resilience-subsystem benchmark: accuracy under correlated fault regimes
and the cost of surviving them (ISSUE 6 acceptance).

Each row trains the same FedAvg task under one stateful fault regime —
Gilbert–Elliott link bursts, node outage/repair churn, partition events,
straggler chains — and records final accuracy, rounds-to-target, the mean
realized availability, and the rounds/sec overhead the fault carry adds to
the scanned chunk. A P4 row exercises aggregator failover (quorum + next-up
member) and reports host-accounted failover counts. The checkpoint row
measures the durable save/verify/restore cycle the crash-safe resume path
leans on.

Writes ``BENCH_resilience.json`` via ``benchmarks/run.py`` (or directly when
run as a script).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.fedavg import FedAvgStrategy
from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, verify_checkpoint)
from repro.engine import Engine, FederatedData
from repro.resilience import (FaultModel, gilbert_elliott_rates,
                              host_realizations, make_fault_process)

LAST_RECORDS = []


def _make_data(M: int, R: int, feat: int, classes: int, seed: int = 0,
               noise: float = 0.4):
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(classes, feat)).astype(np.float32)
    ys = rng.integers(0, classes, size=(M, R))
    xs = protos[ys] + rng.normal(size=(M, R, feat)).astype(np.float32) * noise
    return FederatedData(xs, ys.astype(np.int32), jnp.asarray(xs),
                         jnp.asarray(ys.astype(np.int32)))


def _regimes(quick: bool):
    ge_fail, ge_repair = gilbert_elliott_rates(0.3, 4.0)
    return [
        ("none", None),
        ("burst", FaultModel(link_fail=ge_fail, link_repair=ge_repair)),
        ("churn", FaultModel(node_fail=0.2, node_repair=0.4)),
        ("partition", FaultModel(partition_prob=0.2, partition_repair=0.3)),
        ("straggler", FaultModel(slow_enter=0.25, slow_exit=0.5)),
    ]


def _fit_timed(data, feat, classes, rounds, batch, eval_every, model, M):
    strategy = FedAvgStrategy(feat_dim=feat, num_classes=classes, lr=0.5,
                              clip=1.0, sigma=0.3, reduce="gather")
    faults = None if model is None else make_fault_process(model, M)
    engine = Engine(strategy, eval_every=eval_every, faults=faults)
    key = jax.random.PRNGKey(0)
    state, hist = engine.fit(data, rounds=rounds, key=key, batch_size=batch)
    jax.tree_util.tree_leaves(state)[0].block_until_ready()
    t0 = time.perf_counter()
    state, hist = engine.fit(data, rounds=rounds, key=key, batch_size=batch)
    jax.tree_util.tree_leaves(state)[0].block_until_ready()
    return hist, rounds / (time.perf_counter() - t0)


def _p4_failover_row(M, rounds, quick):
    from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
    from repro.core.p2p import P2PNetwork
    from repro.core.p4 import P4Strategy, P4Trainer

    cfg = RunConfig(dp=DPConfig(epsilon=15.0, rounds=rounds, sample_rate=0.5),
                    p4=P4Config(group_size=4, sample_peers=7),
                    train=TrainConfig(learning_rate=0.5))
    strat = P4Strategy(trainer=P4Trainer(feat_dim=16, num_classes=4, cfg=cfg))
    strat.set_groups([list(range(g, M, M // 4)) for g in range(M // 4)], M)
    ge_fail, ge_repair = gilbert_elliott_rates(0.2, 3.0)
    model = FaultModel(link_fail=ge_fail, link_repair=ge_repair,
                       node_fail=0.25, node_repair=0.4, quorum=0.5)
    faults = make_fault_process(model, M)
    net = P2PNetwork(M)
    data = _make_data(M, 48, 16, 4, seed=1)
    Engine(strat, eval_every=rounds - 1, network=net, faults=faults).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(1), batch_size=8)
    return {"name": "p4_failover", "M": M, "rounds": rounds,
            "failover_count": strat.failover_count,
            "bytes_per_round": round(net.total_bytes() / rounds, 1),
            "messages_per_round": round(net.num_messages() / rounds, 2)}


def _checkpoint_row(quick):
    d = 4096 if quick else 65536
    tree = {"w": np.random.default_rng(0).normal(size=(d, 16))
            .astype(np.float32),
            "b": np.zeros((16,), np.float32)}
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        n = 8
        for s in range(n):
            save_checkpoint(tmp, s, tree, metadata={"history": {}},
                            keep_last=3)
        save_dt = (time.perf_counter() - t0) / n
        t0 = time.perf_counter()
        assert verify_checkpoint(tmp, latest_step(tmp))
        restore_checkpoint(tmp, tree)
        cycle_dt = time.perf_counter() - t0
    nbytes = sum(a.nbytes for a in tree.values())
    return {"name": "checkpoint", "leaf_bytes": nbytes,
            "save_ms": round(save_dt * 1e3, 2),
            "verify_restore_ms": round(cycle_dt * 1e3, 2),
            "save_mb_per_sec": round(nbytes / save_dt / 1e6, 1)}


def run(quick: bool = True):
    rows = []
    LAST_RECORDS.clear()
    M, R, feat, classes = (8, 64, 32, 4) if quick else (16, 128, 256, 10)
    rounds = 40 if quick else 120
    batch, eval_every, target = 16, 4, 0.7
    # data-starved regime: the noise floor keeps round-0 accuracy near
    # chance so rounds-to-target separates the fault regimes
    data = _make_data(M, R, feat, classes, noise=2.0)

    base_rps = None
    for name, model in _regimes(quick):
        hist, rps = _fit_timed(data, feat, classes, rounds, batch,
                               eval_every, model, M)
        if base_rps is None:
            base_rps = rps
        hit = [r for r, a in zip(hist.rounds, hist.accuracy) if a >= target]
        rec = {"name": name, "M": M, "rounds": rounds,
               "final_accuracy": round(hist.accuracy[-1], 4),
               "rounds_to_target": hit[0] if hit else None,
               "rounds_per_sec": round(rps, 2),
               "overhead_vs_none": round(base_rps / rps, 3)}
        if model is not None:
            frs = host_realizations(make_fault_process(model, M),
                                    jax.random.split(jax.random.fold_in(
                                        jax.random.PRNGKey(0), 0x9e37))[1],
                                    0, 0, rounds)
            rec["mean_availability"] = round(
                float(np.mean([f.active.mean() for f in frs])), 3)
        rows.append((f"resilience_{name}_rps", 1e6 / rps, round(rps, 1)))
        LAST_RECORDS.append(rec)
        print(f"[resilience] {name}: acc={rec['final_accuracy']:.3f} "
              f"to-target={rec['rounds_to_target']} {rps:.1f} r/s",
              flush=True)

    p4 = _p4_failover_row(M, 24 if quick else 60, quick)
    LAST_RECORDS.append(p4)
    rows.append(("resilience_p4_failovers", p4["failover_count"],
                 p4["failover_count"]))
    print(f"[resilience] p4_failover: {p4['failover_count']} failovers "
          f"{p4['bytes_per_round']:.0f} B/round", flush=True)

    ck = _checkpoint_row(quick)
    LAST_RECORDS.append(ck)
    rows.append(("resilience_checkpoint_save_us", ck["save_ms"] * 1e3,
                 ck["save_ms"]))
    print(f"[resilience] checkpoint: save={ck['save_ms']:.2f}ms "
          f"({ck['save_mb_per_sec']:.0f} MB/s) "
          f"verify+restore={ck['verify_restore_ms']:.2f}ms", flush=True)
    return rows


if __name__ == "__main__":
    import json
    _quick = "--full" not in sys.argv[1:]
    rows = run(quick=_quick)
    for r in rows:
        print(",".join(map(str, r)))
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_resilience.json")
    with open(out_path, "w") as f:
        json.dump({"platform": jax.default_backend(), "quick": _quick,
                   "entries": LAST_RECORDS}, f, indent=2)
    print(f"wrote {out_path}")
