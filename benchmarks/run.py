"""Benchmark harness — one module per paper table/figure (+ roofline/kernels).

  bench_heterogeneity  Figs. 2/3/5/6   accuracy vs heterogeneity, all methods
  bench_privacy        Fig. 7          accuracy vs ε, P4 vs local
  bench_ablation       Fig. 8          component ablation
  bench_overhead       §4.5            phase run time / bytes / memory
  bench_roofline       §Roofline       dry-run-derived terms per combo
  bench_kernels        (framework)     Pallas-vs-oracle microbench
  bench_engine         (framework)     scan round loop vs legacy Python loop
  bench_schedule       (framework)     round schedules vs the PR-2 loop
  bench_topology       (framework)     gossip loop vs graph family/density
  bench_population     (framework)     paged rounds/sec vs virtual M
  bench_resilience     (framework)     accuracy/overhead vs fault regime
  bench_obs            (framework)     telemetry overhead + off-is-free

Prints ``name,us_per_call,derived`` CSV. ``--full`` uses paper-scale rounds.
Suites exposing ``LAST_RECORDS`` also write ``BENCH_<suite>.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# make `python benchmarks/run.py` work without PYTHONPATH incantations
for _p in (REPO_ROOT, os.path.join(REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_ablation, bench_engine, bench_heterogeneity,
                            bench_kernels, bench_obs, bench_overhead,
                            bench_population, bench_privacy, bench_resilience,
                            bench_roofline, bench_schedule, bench_topology)
    suites = {
        "kernels": bench_kernels,
        "engine": bench_engine,
        "schedule": bench_schedule,
        "topology": bench_topology,
        "population": bench_population,
        "resilience": bench_resilience,
        "overhead": bench_overhead,
        "roofline": bench_roofline,
        "privacy": bench_privacy,
        "ablation": bench_ablation,
        "heterogeneity": bench_heterogeneity,
        "obs": bench_obs,
    }
    rows = []
    for name, mod in suites.items():
        if args.only and name not in args.only:
            continue
        t0 = time.time()
        print(f"\n===== {name} =====", flush=True)
        try:
            rows.extend(mod.run(quick=quick))
        except Exception as e:  # a failing suite must not hide the others
            print(f"[{name}] FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            rows.append((f"{name}_FAILED", 0.0, type(e).__name__))
        print(f"===== {name} done in {time.time()-t0:.0f}s =====", flush=True)
        if getattr(mod, "LAST_RECORDS", None):
            import jax
            payload = {"platform": jax.default_backend(),
                       "quick": quick,
                       "entries": mod.LAST_RECORDS}
            out_path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
            with open(out_path, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"[{name}] wrote {out_path}", flush=True)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
