"""Telemetry overhead: rounds/sec with the obs subsystem off vs fully on
(phase spans + chunk spans + the per-round metrics tap), plus a machine-
checked record that telemetry *off* is provably free.

Two gated working points mirror the repo's hot paths:

  * sharded M=64 over the 8-fake-device CPU mesh (tap streamed host-side
    from stacked chunk outputs — the shard_map trace stays tap-free);
  * paged M=4096 with a 16-wide cohort (tap is an ordered in-jit
    ``io_callback`` in the scanned round body).

``--assert-overhead`` is the CI gate: tap+spans on must hold ≥95% of the
off-throughput at both points (fails loudly with the measured ratios,
mirroring bench_engine's ``--assert-crossover``), and the off-is-free
record must pass (chunk-cache keys byte-identical with telemetry absent vs
disabled, zero retraces when a disabled-telemetry engine reuses a warm
cache, bit-exact History). Also writes a sample ``events.jsonl`` from a
small evaluated DP run (ledger attached) for the CI artifact. Writes
``BENCH_obs.json`` via ``benchmarks/run.py`` (or directly as a script).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        # must land before the first jax import below (sharded column)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()
    # make `python benchmarks/bench_obs.py` work without PYTHONPATH
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.fedavg import FedAvgStrategy
from repro.baselines.local import LocalStrategy
from repro.engine import (ClientSampling, Engine, FederatedData,
                          HostFederatedData, PagedEngine, PrivacyLedger,
                          ShardedEngine, clear_chunk_cache)
from repro.obs import Telemetry, probe_deltas

LAST_RECORDS = []

FEAT, CLASSES, R, BATCH = 8, 2, 8, 4
COHORT = 16
GATES = (("sharded", 64), ("paged", 4096))


def _raw_data(M: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(CLASSES, FEAT)).astype(np.float32) * 3
    ys = rng.integers(0, CLASSES, size=(M, R)).astype(np.int32)
    xs = protos[ys] + rng.normal(size=(M, R, FEAT)).astype(np.float32) * 0.4
    return xs, ys


def _data(M: int) -> FederatedData:
    xs, ys = _raw_data(M)
    return FederatedData(xs, ys, jnp.asarray(xs), jnp.asarray(ys))


def _host_data(M: int) -> HostFederatedData:
    xs, ys = _raw_data(M)
    return HostFederatedData(xs, ys, xs[:1], ys[:1])


def _strategy() -> LocalStrategy:
    return LocalStrategy(feat_dim=FEAT, num_classes=CLASSES, lr=0.5)


def _fit_once(engine, data, rounds: int) -> None:
    state, _ = engine.fit(data, rounds=rounds, key=jax.random.PRNGKey(7),
                          batch_size=BATCH, evaluate=False)
    jax.tree_util.tree_leaves(state)[0].block_until_ready()


def _overhead(name, make_engine, data, rounds: int, tmp: str, extra=None,
              repeats: int = 5):
    """rounds/sec for telemetry=None vs a full-on Telemetry (spans + tap).
    Off/on fits are timed alternately (best-of-N each) so load or clock
    drift on a shared box hits both sides of the ratio equally."""
    eng_off = make_engine(None)
    tel = Telemetry(os.path.join(tmp, name), tap=True)
    eng_on = make_engine(tel)
    _fit_once(eng_off, data, rounds)      # compile + warm caches
    _fit_once(eng_on, data, rounds)
    best_off = best_on = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _fit_once(eng_off, data, rounds)
        best_off = min(best_off, time.perf_counter() - t0)
        t0 = time.perf_counter()
        _fit_once(eng_on, data, rounds)
        best_on = min(best_on, time.perf_counter() - t0)
    off, on = rounds / best_off, rounds / best_on
    tel.close()
    ratio = on / off
    rec = {"name": f"obs_overhead_{name}",
           "rounds_per_sec_off": round(off, 2),
           "rounds_per_sec_on": round(on, 2),
           "on_vs_off": round(ratio, 4), "rounds": rounds,
           "feat": FEAT, "batch": BATCH}
    rec.update(extra or {})
    LAST_RECORDS.append(rec)
    print(f"[obs] {name}: off={off:.1f} r/s, tap+spans on={on:.1f} r/s "
          f"({ratio:.3f}x)", flush=True)
    return (f"obs_{name}_on_rps", 1e6 / on, round(ratio, 3))


def _off_is_free(rounds: int):
    """Machine-checked zero-overhead-off record: disabled telemetry builds
    the same chunk-cache key as no telemetry at all, reuses a warm compiled
    chunk without retracing, and produces a bit-exact History."""
    strategy = _strategy()
    data = _data(16)
    eng_plain = Engine(strategy, eval_every=rounds)
    k_plain = eng_plain._chunk_key(rounds, BATCH)
    k_none = Engine(strategy, eval_every=rounds,
                    telemetry=Telemetry(None))._chunk_key(rounds, BATCH)
    k_disabled_tap = Engine(
        strategy, eval_every=rounds,
        telemetry=Telemetry(None, tap=True))._chunk_key(rounds, BATCH)
    keys_equal = (k_plain == k_none == k_disabled_tap)

    clear_chunk_cache()
    key = jax.random.PRNGKey(5)
    state0, hist0 = eng_plain.fit(data, rounds=rounds, key=key,
                                  batch_size=BATCH, evaluate=False)
    with probe_deltas("engine.chunk_cache") as d:
        state1, hist1 = Engine(
            strategy, eval_every=rounds,
            telemetry=Telemetry(None, tap=True)).fit(
                data, rounds=rounds, key=key, batch_size=BATCH,
                evaluate=False)
    cache = d["engine.chunk_cache"]
    bit_exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(state0),
                        jax.tree_util.tree_leaves(state1)))
    rec = {"name": "obs_off_is_free",
           "chunk_key_unchanged": bool(keys_equal),
           "warm_cache_retraces": int(cache.get("traces", 0)),
           "warm_cache_hits": int(cache.get("hits", 0)),
           "state_bit_exact": bool(bit_exact),
           "passed": bool(keys_equal and cache.get("traces", 0) == 0
                          and cache.get("hits", 0) > 0 and bit_exact)}
    LAST_RECORDS.append(rec)
    print(f"[obs] off-is-free: keys_unchanged={keys_equal} "
          f"retraces={rec['warm_cache_retraces']} "
          f"hits={rec['warm_cache_hits']} bit_exact={bit_exact} "
          f"-> {'PASS' if rec['passed'] else 'FAIL'}", flush=True)
    return ("obs_off_is_free", 0.0, "pass" if rec["passed"] else "FAIL")


def _sample_events(out_path: str):
    """A small evaluated DP run (ledger attached, tap + profiler capture on)
    whose events.jsonl ships as the CI artifact."""
    rounds, evals = 8, 4
    strategy = FedAvgStrategy(feat_dim=FEAT, num_classes=CLASSES, lr=0.5,
                              clip=1.0, sigma=0.7)
    data = _data(16)
    tmp = tempfile.mkdtemp(prefix="bench_obs_sample_")
    try:
        tel = Telemetry(os.path.join(tmp, "run"), tap=True, profile_chunk=1)
        eng = Engine(strategy, eval_every=rounds // evals,
                     ledger=PrivacyLedger(sigma=0.7, delta=1e-5),
                     telemetry=tel)
        eng.fit(data, rounds=rounds, key=jax.random.PRNGKey(11),
                batch_size=BATCH)
        tel.close()
        shutil.copyfile(tel.events_path, out_path)
        n = sum(1 for _ in open(out_path))
        LAST_RECORDS.append({"name": "obs_sample_events",
                             "path": os.path.basename(out_path),
                             "events": n, "rounds": rounds})
        print(f"[obs] sample events: {n} events -> {out_path}", flush=True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(quick: bool = True):
    rows = []
    LAST_RECORDS.clear()
    # long fits on purpose: the gate measures the *per-round* steady-state
    # tax of tap+spans; per-phase fixed costs (one manifest write + three
    # span/phase events per fit, ~1 ms total) amortize out here exactly as
    # they do in a real run — at the toy ~0.13 ms/round they still need
    # hundreds of rounds to drop below the 5% gate
    rounds = 400 if quick else 800
    n_dev = len(jax.devices())
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    try:
        # gated point 1: sharded M=64 (tap streams host-side post-chunk —
        # the shard_map trace and cache key are identical on/off)
        M = 64
        rows.append(_overhead(
            "sharded_M64",
            lambda tel: ShardedEngine(_strategy(), eval_every=rounds,
                                      telemetry=tel),
            _data(M), rounds, tmp, {"M": M, "devices": n_dev}))

        # gated point 2: paged M=4096, 16-wide cohort (in-jit ordered
        # io_callback per scanned round)
        M = 4096
        rows.append(_overhead(
            "paged_M4096",
            lambda tel: PagedEngine(
                _strategy(), eval_every=rounds, telemetry=tel,
                schedule=ClientSampling(q=COHORT / M, mode="fixed")),
            _host_data(M), rounds, tmp, {"M": M, "cohort": COHORT}))

        # context (ungated): the resident single-device engine — its toy
        # linear round is so short (~0.15 ms) that even one io_callback per
        # TAP_BLOCK rounds plus the blocked-scan restructuring is a visible
        # fraction; on any real model the same absolute cost vanishes
        M = 64
        rows.append(_overhead(
            "resident_M64",
            lambda tel: Engine(_strategy(), eval_every=rounds,
                               telemetry=tel),
            _data(M), rounds, tmp,
            {"M": M, "gated": False,
             "note": "sub-ms toy rounds; absolute tap cost is per "
                     "TAP_BLOCK rounds, relative cost shrinks with "
                     "round duration"}))

        rows.append(_off_is_free(rounds))
        _sample_events(os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "BENCH_obs_events.jsonl"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def main() -> None:
    import json
    quick = "--full" not in sys.argv[1:]
    rows = run(quick=quick)
    payload = {"platform": jax.default_backend(), "quick": quick,
               "entries": LAST_RECORDS}
    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_obs.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[obs] wrote {out}", flush=True)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if "--assert-overhead" in sys.argv[1:]:
        # CI gate (ISSUE 10): tap+spans on keeps >= 95% of off-throughput
        # at both gated working points, and telemetry off is provably free
        ratios = {e["name"]: e["on_vs_off"] for e in LAST_RECORDS
                  if "on_vs_off" in e}
        failed = []
        for kind, m in GATES:
            key = f"obs_overhead_{kind}_M{m}"
            r = ratios.get(key)
            if r is None:
                print(f"OVERHEAD GATE: missing entry {key}", file=sys.stderr)
                sys.exit(2)
            if r < 0.95:
                failed.append(f"{key}={r:.3f}x")
        free = next((e for e in LAST_RECORDS
                     if e["name"] == "obs_off_is_free"), None)
        if free is None or not free["passed"]:
            failed.append(f"off_is_free={free}")
        if failed:
            print(f"OVERHEAD GATE FAILED: need >= 0.95x on/off and a "
                  f"passing off-is-free record; got {failed} "
                  f"(all ratios: {ratios})", file=sys.stderr)
            sys.exit(1)
        print(f"overhead gate passed: {ratios}, off-is-free OK")


if __name__ == "__main__":
    main()
