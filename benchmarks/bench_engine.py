"""Engine round-loop throughput: scan-chunked device-resident loop vs the
legacy per-round Python loop (the pre-refactor trainer shape: host numpy
batch sampling + one jitted dispatch + H2D transfer per round), plus the
shard_map client-mesh loop (``--sharded`` forces an 8-fake-device CPU mesh,
the honest simulation the CI job records — on real multi-chip hardware the
same path is a genuine speedup; on one CPU it measures collective overhead).

The linear-model config on CPU is the paper's small-scale setting; the claim
(ISSUE 2 acceptance) is that the engine's ``lax.scan`` loop wins on
rounds/sec because it amortizes dispatch and keeps batch gathers on device.
Writes ``BENCH_engine.json`` via ``benchmarks/run.py`` (or directly when run
as a script).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    if "--sharded" in sys.argv[1:] and \
            "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        # must land before the first jax import below
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=8").strip()
    # make `python benchmarks/bench_engine.py` work without PYTHONPATH
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import common
from repro.baselines.local import LocalStrategy
from repro.engine import Engine, FederatedData, ShardedEngine

LAST_RECORDS = []


def _make_data(M: int, R: int, feat: int, classes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, R))
    xs = protos[ys] + rng.normal(size=(M, R, feat)).astype(np.float32) * 0.4
    return xs, ys.astype(np.int32)


def _legacy_loop(strategy, X, Y, rounds: int, batch: int, seed: int = 0):
    """The deleted pre-refactor loop, reconstructed for comparison: numpy
    index draw + take_along_axis on host, jnp.asarray transfer, one jitted
    step dispatch per round."""
    M, R = Y.shape
    rng = np.random.default_rng(seed)
    params = common.init_clients(strategy.specs, jax.random.PRNGKey(seed), M)

    @jax.jit
    def step(params, xs, ys, key):
        def one(p, x, y, k):
            g = common.client_grad(strategy.apply_fn, p, x, y, k)
            return common.sgd_update(p, g, strategy.lr)
        return jax.vmap(one)(params, xs, ys, jax.random.split(key, M))

    key = jax.random.PRNGKey(seed + 1)

    def run():
        nonlocal params
        for r in range(rounds):
            idx = rng.integers(0, R, size=(M, batch))
            gx = np.take_along_axis(X, idx[..., None], axis=1)
            gy = np.take_along_axis(Y, idx, axis=1)
            params = step(params, jnp.asarray(gx), jnp.asarray(gy),
                          jax.random.fold_in(key, r))
        jax.tree_util.tree_leaves(params)[0].block_until_ready()

    run()                                 # compile + warm caches
    with_timer = time.perf_counter()
    run()
    return rounds / (time.perf_counter() - with_timer)


def _engine_loop(strategy, X, Y, rounds: int, batch: int, seed: int = 0,
                 engine=None):
    data = FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))
    engine = engine if engine is not None else Engine(strategy,
                                                      eval_every=rounds)
    key = jax.random.PRNGKey(seed)

    def run():
        state, _ = engine.fit(data, rounds=rounds, key=key, batch_size=batch,
                              evaluate=False)
        jax.tree_util.tree_leaves(state)[0].block_until_ready()

    run()                                 # compile the chunk once
    best = float("inf")
    for _ in range(3):                    # best-of-3: the box is 1-core and
        t0 = time.perf_counter()          # shared, single timings are noisy
        run()
        best = min(best, time.perf_counter() - t0)
    return rounds / best


def _paired_rps(make_strategy, X, Y, rounds: int, batch: int, mesh,
                seed: int = 0):
    """Single-device vs sharded rounds/sec with the timed runs interleaved
    (s, sh, s, sh, ...) and best-of-3 each, so drifting background load on a
    shared box hits both columns instead of biasing the ratio."""
    data = FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))
    key = jax.random.PRNGKey(seed)

    def make_run(engine):
        def go():
            state, _ = engine.fit(data, rounds=rounds, key=key,
                                  batch_size=batch, evaluate=False)
            jax.tree_util.tree_leaves(state)[0].block_until_ready()
        return go

    single = make_run(Engine(make_strategy(), eval_every=rounds))
    sh_strategy = make_strategy()
    shard = make_run(ShardedEngine(sh_strategy, eval_every=rounds,
                                   mesh=mesh))
    single()                              # compile both chunks first
    shard()
    bests = [float("inf"), float("inf")]
    for _ in range(5):
        for i, go in enumerate((single, shard)):
            t0 = time.perf_counter()
            go()
            bests[i] = min(bests[i], time.perf_counter() - t0)
    return rounds / bests[0], rounds / bests[1]


def run(quick: bool = True, sharded: bool = False):
    rows = []
    LAST_RECORDS.clear()
    M, R, feat, classes = (16, 96, 64, 10) if quick else (32, 160, 15552, 10)
    rounds = 100 if quick else 200
    batch = 24
    X, Y = _make_data(M, R, feat, classes)
    strategy = LocalStrategy(feat_dim=feat, num_classes=classes, lr=0.5)

    legacy_rps = _legacy_loop(strategy, X, Y, rounds, batch)
    engine_rps = _engine_loop(strategy, X, Y, rounds, batch)
    speedup = engine_rps / legacy_rps

    rows.append(("engine_legacy_loop_rps", 1e6 / legacy_rps, round(legacy_rps, 1)))
    rows.append(("engine_scan_loop_rps", 1e6 / engine_rps, round(engine_rps, 1)))
    rows.append(("engine_scan_speedup", 0.0, round(speedup, 2)))
    LAST_RECORDS.extend([
        {"name": "legacy_python_loop", "rounds_per_sec": round(legacy_rps, 2),
         "M": M, "R": R, "feat": feat, "rounds": rounds, "batch": batch},
        {"name": "engine_scan_loop", "rounds_per_sec": round(engine_rps, 2),
         "M": M, "R": R, "feat": feat, "rounds": rounds, "batch": batch},
        {"name": "speedup", "value": round(speedup, 3)},
    ])
    print(f"[engine] legacy={legacy_rps:.1f} r/s scan={engine_rps:.1f} r/s "
          f"speedup={speedup:.2f}x (linear model, M={M}, feat={feat})",
          flush=True)

    n_dev = len(jax.devices())
    if sharded or n_dev > 1:
        from repro.launch.mesh import make_client_mesh
        # M sweep (ISSUE 7): the 8-vs-1 crossover needs to be visible in the
        # trajectory, so each swept M gets its own single-device baseline and
        # its own vs_single_device ratio in BENCH_engine.json. 400 rounds
        # per fit so the ~7 ms fixed per-fit cost of the sharded engine
        # (device_put of the client layout + host finalize) is amortized and
        # the ratio reflects steady-state rounds/sec.
        sweep = (16, 64, 256) if quick else (M,)
        sweep_rounds = 400 if quick else rounds
        for M_s in sweep:
            X_s, Y_s = (X, Y) if M_s == M else _make_data(M_s, R, feat,
                                                          classes)
            single_rps, sharded_rps = _paired_rps(
                lambda: LocalStrategy(feat_dim=feat, num_classes=classes,
                                      lr=0.5),
                X_s, Y_s, sweep_rounds, batch, make_client_mesh())
            LAST_RECORDS.append(
                {"name": "engine_scan_loop",
                 "rounds_per_sec": round(single_rps, 2), "M": M_s,
                 "R": R, "feat": feat, "rounds": sweep_rounds,
                 "batch": batch})
            ratio = sharded_rps / single_rps
            rows.append((f"engine_sharded_loop_M{M_s}_rps",
                         1e6 / sharded_rps, round(sharded_rps, 1)))
            LAST_RECORDS.append(
                {"name": "engine_sharded_loop",
                 "rounds_per_sec": round(sharded_rps, 2),
                 "devices": n_dev, "M": M_s, "R": R, "feat": feat,
                 "rounds": sweep_rounds, "batch": batch,
                 "vs_single_device": round(ratio, 3)})
            print(f"[engine] M={M_s}: sharded={sharded_rps:.1f} r/s over "
                  f"{n_dev} device(s) ({ratio:.2f}x the single-device scan; "
                  "host-simulated devices measure collective overhead, not "
                  "speedup)", flush=True)
    return rows


if __name__ == "__main__":
    import json
    _quick = "--full" not in sys.argv[1:]
    rows = run(quick=_quick, sharded="--sharded" in sys.argv[1:])
    for r in rows:
        print(",".join(map(str, r)))
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine.json")
    with open(out_path, "w") as f:
        json.dump({"platform": jax.default_backend(), "quick": _quick,
                   "entries": LAST_RECORDS}, f, indent=2)
    print(f"wrote {out_path}")
    if "--assert-crossover" in sys.argv[1:]:
        # CI gate (ISSUE 7): at M=64 the 8-fake-device sharded loop must be
        # at least as fast as the single-device scan
        gate_m = 64
        ratios = {e["M"]: e["vs_single_device"] for e in LAST_RECORDS
                  if e.get("name") == "engine_sharded_loop"
                  and "vs_single_device" in e}
        ratio = ratios.get(gate_m)
        if ratio is None:
            print(f"CROSSOVER GATE: no sharded entry at M={gate_m} "
                  "(run with --sharded)", file=sys.stderr)
            sys.exit(2)
        if ratio < 1.0:
            print(f"CROSSOVER GATE FAILED: sharded/single at M={gate_m} is "
                  f"{ratio:.3f}x, need >= 1.0 (all ratios: {ratios})",
                  file=sys.stderr)
            sys.exit(1)
        print(f"crossover gate passed: sharded/single at M={gate_m} "
              f"= {ratio:.3f}x (all ratios: {ratios})")
