"""Engine round-loop throughput: scan-chunked device-resident loop vs the
legacy per-round Python loop (the pre-refactor trainer shape: host numpy
batch sampling + one jitted dispatch + H2D transfer per round).

The linear-model config on CPU is the paper's small-scale setting; the claim
(ISSUE 2 acceptance) is that the engine's ``lax.scan`` loop wins on
rounds/sec because it amortizes dispatch and keeps batch gathers on device.
Writes ``BENCH_engine.json`` via ``benchmarks/run.py``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import common
from repro.baselines.local import LocalStrategy
from repro.engine import Engine, FederatedData

LAST_RECORDS = []


def _make_data(M: int, R: int, feat: int, classes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, R))
    xs = protos[ys] + rng.normal(size=(M, R, feat)).astype(np.float32) * 0.4
    return xs, ys.astype(np.int32)


def _legacy_loop(strategy, X, Y, rounds: int, batch: int, seed: int = 0):
    """The deleted pre-refactor loop, reconstructed for comparison: numpy
    index draw + take_along_axis on host, jnp.asarray transfer, one jitted
    step dispatch per round."""
    M, R = Y.shape
    rng = np.random.default_rng(seed)
    params = common.init_clients(strategy.specs, jax.random.PRNGKey(seed), M)

    @jax.jit
    def step(params, xs, ys, key):
        def one(p, x, y, k):
            g = common.client_grad(strategy.apply_fn, p, x, y, k)
            return common.sgd_update(p, g, strategy.lr)
        return jax.vmap(one)(params, xs, ys, jax.random.split(key, M))

    key = jax.random.PRNGKey(seed + 1)

    def run():
        nonlocal params
        for r in range(rounds):
            idx = rng.integers(0, R, size=(M, batch))
            gx = np.take_along_axis(X, idx[..., None], axis=1)
            gy = np.take_along_axis(Y, idx, axis=1)
            params = step(params, jnp.asarray(gx), jnp.asarray(gy),
                          jax.random.fold_in(key, r))
        jax.tree_util.tree_leaves(params)[0].block_until_ready()

    run()                                 # compile + warm caches
    with_timer = time.perf_counter()
    run()
    return rounds / (time.perf_counter() - with_timer)


def _engine_loop(strategy, X, Y, rounds: int, batch: int, seed: int = 0):
    data = FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))
    engine = Engine(strategy, eval_every=rounds)
    key = jax.random.PRNGKey(seed)

    def run():
        state, _ = engine.fit(data, rounds=rounds, key=key, batch_size=batch,
                              evaluate=False)
        jax.tree_util.tree_leaves(state)[0].block_until_ready()

    run()                                 # compile the chunk once
    t0 = time.perf_counter()
    run()
    return rounds / (time.perf_counter() - t0)


def run(quick: bool = True):
    rows = []
    LAST_RECORDS.clear()
    M, R, feat, classes = (16, 96, 64, 10) if quick else (32, 160, 15552, 10)
    rounds = 100 if quick else 200
    batch = 24
    X, Y = _make_data(M, R, feat, classes)
    strategy = LocalStrategy(feat_dim=feat, num_classes=classes, lr=0.5)

    legacy_rps = _legacy_loop(strategy, X, Y, rounds, batch)
    engine_rps = _engine_loop(strategy, X, Y, rounds, batch)
    speedup = engine_rps / legacy_rps

    rows.append(("engine_legacy_loop_rps", 1e6 / legacy_rps, round(legacy_rps, 1)))
    rows.append(("engine_scan_loop_rps", 1e6 / engine_rps, round(engine_rps, 1)))
    rows.append(("engine_scan_speedup", 0.0, round(speedup, 2)))
    LAST_RECORDS.extend([
        {"name": "legacy_python_loop", "rounds_per_sec": round(legacy_rps, 2),
         "M": M, "R": R, "feat": feat, "rounds": rounds, "batch": batch},
        {"name": "engine_scan_loop", "rounds_per_sec": round(engine_rps, 2),
         "M": M, "R": R, "feat": feat, "rounds": rounds, "batch": batch},
        {"name": "speedup", "value": round(speedup, 3)},
    ])
    print(f"[engine] legacy={legacy_rps:.1f} r/s scan={engine_rps:.1f} r/s "
          f"speedup={speedup:.2f}x (linear model, M={M}, feat={feat})",
          flush=True)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
