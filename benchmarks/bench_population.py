"""Million-client paging throughput: rounds/sec vs virtual population size.

The resident engine's round cost scales with M (every client's state and
batch draw is materialized on device), so M is capped by device memory and
round latency. ``PagedEngine`` pins the device working set to the *cohort*
(q·M clients); this suite sweeps M with a fixed active cohort and records
the rounds/sec curve — the ISSUE 8 acceptance is that a population of
M ≥ 1e5 virtual clients trains at least as fast as the resident engine's
current M=16 configuration (same model, same cohort width doing real work).

Honest-measurement notes: the per-round cost that still scales with M is
the layout-invariant full-M participation draw (mode="fixed" argsorts an
(M,) vector per round — the price of the paged ≡ resident PRNG contract)
and the host-side cohort planning replay; both are in the timed region.
Writes ``BENCH_population.json`` via ``benchmarks/run.py`` (or directly
when run as a script).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    # make `python benchmarks/bench_population.py` work without PYTHONPATH
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.local import LocalStrategy
from repro.engine import (ClientSampling, Engine, FederatedData,
                          HostFederatedData, PagedEngine)

LAST_RECORDS = []

COHORT = 16          # active clients per round (q·M), matched across the sweep
FEAT, CLASSES, R = 8, 2, 8
BATCH = 4


def _host_data(M: int, seed: int = 0) -> HostFederatedData:
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(CLASSES, FEAT)).astype(np.float32) * 3
    ys = rng.integers(0, CLASSES, size=(M, R)).astype(np.int32)
    xs = protos[ys] + rng.normal(size=(M, R, FEAT)).astype(np.float32) * 0.4
    # the throughput runs never evaluate; tiny test stacks keep memory flat
    return HostFederatedData(xs, ys, xs[:1], ys[:1])


def _strategy() -> LocalStrategy:
    return LocalStrategy(feat_dim=FEAT, num_classes=CLASSES, lr=0.5)


def _rps(engine, data, rounds: int, repeats: int = 3) -> float:
    key = jax.random.PRNGKey(7)

    def go():
        state, _ = engine.fit(data, rounds=rounds, key=key,
                              batch_size=BATCH, evaluate=False)
        jax.tree_util.tree_leaves(state)[0].block_until_ready()

    go()                                  # compile + warm plan/replay caches
    best = float("inf")
    for _ in range(repeats):              # best-of-N: 1-core shared box
        t0 = time.perf_counter()
        go()
        best = min(best, time.perf_counter() - t0)
    return rounds / best


def run(quick: bool = True):
    rows = []
    LAST_RECORDS.clear()
    rounds = 30 if quick else 60
    sweep = (1_024, 16_384, 131_072) if quick else (1_024, 16_384, 131_072,
                                                    1_048_576)

    # the baseline the acceptance compares against: the resident engine at
    # its current M=16 working point (all 16 clients active per round)
    host16 = _host_data(16)
    data16 = FederatedData(host16.train_x, host16.train_y,
                           jnp.asarray(host16.test_x),
                           jnp.asarray(host16.test_y))
    resident_rps = _rps(Engine(_strategy(), eval_every=rounds), data16,
                        rounds)
    rows.append(("population_resident_M16_rps", 1e6 / resident_rps,
                 round(resident_rps, 1)))
    LAST_RECORDS.append(
        {"name": "resident_engine", "M": 16, "cohort": 16,
         "rounds_per_sec": round(resident_rps, 2), "rounds": rounds,
         "feat": FEAT, "batch": BATCH})
    print(f"[population] resident M=16 baseline: {resident_rps:.1f} r/s",
          flush=True)

    for M in sweep:
        host = _host_data(M)
        eng = PagedEngine(_strategy(), eval_every=rounds,
                          schedule=ClientSampling(q=COHORT / M, mode="fixed"))
        paged_rps = _rps(eng, host, rounds)
        pop_mb = eng._pop.nbytes / 2**20
        data_mb = (host.train_x.nbytes + host.train_y.nbytes) / 2**20
        ratio = paged_rps / resident_rps
        rows.append((f"population_paged_M{M}_rps", 1e6 / paged_rps,
                     round(paged_rps, 1)))
        LAST_RECORDS.append(
            {"name": "paged_engine", "M": M, "cohort": COHORT,
             "rounds_per_sec": round(paged_rps, 2), "rounds": rounds,
             "feat": FEAT, "batch": BATCH,
             "population_state_mb": round(pop_mb, 2),
             "host_data_mb": round(data_mb, 2),
             "prefetch_stats": dict(eng._prefetcher.stats),
             "vs_resident_M16": round(ratio, 3)})
        print(f"[population] paged M={M}: {paged_rps:.1f} r/s "
              f"({ratio:.2f}x the resident M=16 baseline; "
              f"state {pop_mb:.1f} MB + data {data_mb:.1f} MB host-side)",
              flush=True)

    biggest = LAST_RECORDS[-1]
    LAST_RECORDS.append(
        {"name": "acceptance", "criterion": "paged rps at max M >= resident "
         "rps at M=16", "M": biggest["M"],
         "passed": bool(biggest["rounds_per_sec"] >= resident_rps),
         "paged_overhead_ms_per_round": round(
             1e3 / biggest["rounds_per_sec"], 2),
         "resident_ms_per_round": round(1e3 / resident_rps, 3),
         "note": "the bit-exact paged ≡ resident contract draws every "
         "per-client PRNG stream at full population size and slices at the "
         "cohort's global ids, so each round pays O(M) threefry work (key "
         "split, batch-index draw, participation draw) even with a 16-wide "
         "cohort — the measured floor above. Strict parity with the toy "
         "resident M=16 round needs O(cohort) streams (counter-sliced "
         "threefry or fold_in-by-id), which are layout-invariant but not "
         "bit-exact with the resident engine; see README §Virtual clients "
         "& cohort paging."})
    return rows


def main() -> None:
    import json
    quick = "--full" not in sys.argv[1:]
    rows = run(quick=quick)
    payload = {"platform": jax.default_backend(), "quick": quick,
               "entries": LAST_RECORDS}
    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_population.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[population] wrote {out}", flush=True)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
