"""Paper Fig. 8 — component ablation on CIFAR-10-like data, γ = 50%:
  i) random client selection instead of ℓ1-similarity grouping,
  ii) raw images instead of handcrafted (ScatterNet) features,
  iii) no proxy model (single DP model per client).

Claim validated: removing ANY component hurts; full P4 is best.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, client_split, feature_pool
from repro.baselines import common as bcommon
from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
from repro.core.p4 import P4Trainer, group_mean
from repro.core.grouping import group_ids


def _p4(trx, try_, tex, tey, *, rounds, batch, similarity="l1", classes=None):
    M, R = try_.shape
    cfg = RunConfig(dp=DPConfig(epsilon=15.0, rounds=rounds, sample_rate=batch / R),
                    p4=P4Config(group_size=4, sample_peers=min(10, M - 1),
                                similarity=similarity),
                    train=TrainConfig(learning_rate=0.5))
    tr = P4Trainer(feat_dim=trx.shape[-1], num_classes=classes, cfg=cfg)
    _, _, hist = tr.fit(trx, try_, tex, tey, rounds=rounds,
                        eval_every=max(rounds - 1, 1), batch_size=batch)
    return hist[-1][1]


def _no_proxy(trx, try_, tex, tey, *, rounds, batch, classes):
    """Single DP model per client + group aggregation of that model —
    ablation iii (the private/proxy decoupling removed)."""
    from repro.baselines.local import train as local_train
    from repro.core import dp as dp_lib
    M, R = try_.shape
    cfg = RunConfig(dp=DPConfig(epsilon=15.0, rounds=rounds, sample_rate=batch / R),
                    p4=P4Config(group_size=4, sample_peers=min(10, M - 1)),
                    train=TrainConfig(learning_rate=0.5))
    tr = P4Trainer(feat_dim=trx.shape[-1], num_classes=classes, cfg=cfg)
    states = tr.init_clients(jax.random.PRNGKey(0), M)
    # tie proxy == private: aggregate BOTH (so the private model eats DP noise)
    import numpy as np
    key = jax.random.PRNGKey(1)
    xs = jnp.asarray(trx[:, :batch]), jnp.asarray(try_[:, :batch])
    states, _ = tr.local_round(states, xs[0], xs[1], key)
    groups = tr.form_groups(states, 0)
    ids = jnp.asarray(group_ids(groups, M))
    rng = np.random.default_rng(0)
    for r in range(rounds):
        idx = rng.integers(0, R, size=(M, batch))
        gx = jnp.asarray(np.take_along_axis(trx, idx[..., None], 1))
        gy = jnp.asarray(np.take_along_axis(try_, idx, 1))
        states, _ = tr.local_round(states, gx, gy, jax.random.fold_in(key, r))
        # aggregate the DP proxy and OVERWRITE the private model with it
        agg = group_mean(states["proxy"], ids, len(groups))
        states = {"private": agg, "proxy": agg}
    acc = tr.evaluate(states, tex, tey)
    return float(jnp.mean(acc))


def run(quick: bool = True, dataset: str = "cifar10"):
    rows = []
    M, R = (16, 96) if quick else (32, 160)
    rounds = 40 if quick else 100
    batch = 24
    feats, rawf, labels, stats = feature_pool(dataset, 60 if quick else 120)
    classes = stats["L"]
    split = dict(M=M, R=R, mode="alpha", level=0.5)
    trx, try_, tex, tey = client_split(feats, labels, **split)
    rtrx, rtry, rtex, rtey = client_split(rawf, labels, **split)
    tex_j, tey_j = jnp.asarray(tex), jnp.asarray(tey)

    results = {}
    with Timer() as t:
        results["p4_full"] = _p4(trx, try_, tex_j, tey_j, rounds=rounds,
                                 batch=batch, classes=classes)
    results["random_grouping"] = _p4(trx, try_, tex_j, tey_j, rounds=rounds,
                                     batch=batch, similarity="random",
                                     classes=classes)
    results["raw_features"] = _p4(rtrx, rtry, jnp.asarray(rtex), jnp.asarray(rtey),
                                  rounds=rounds, batch=batch, classes=classes)
    results["no_proxy"] = _no_proxy(trx, try_, tex_j, tey_j, rounds=rounds,
                                    batch=batch, classes=classes)
    for k, v in results.items():
        rows.append((f"ablation_{k}", t.dt * 1e6 / rounds, round(v, 4)))
    print("[ablation] " + " ".join(f"{k}={v:.3f}" for k, v in results.items()),
          flush=True)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
