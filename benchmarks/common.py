"""Shared benchmark utilities: cached ScatterNet feature pools + the paper's
client-partition setups at benchmark scale.

Scale note (DESIGN.md gate table): the paper's full setup is M=200–260
clients × R=200–300 samples. On this 1-core container we default to M=16,
R=96 with the same partitioners — orderings and deltas are the claim being
validated, not absolute accuracy.
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax.numpy as jnp

from repro.core.scattering import scatternet_features
from repro.data.partition import alpha_partition, shard_partition
from repro.data.pipeline import stack_client_data, train_test_split
from repro.data.synthetic import make_image_task_pool

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def feature_pool(dataset: str, samples_per_class: int = 60, seed: int = 0,
                 raw: bool = False, noise: float = 0.9):
    """(features, raw_images_flat, labels, stats) with on-disk caching —
    ScatterNet on 1 CPU core is the slow step (~1 min per pool).

    noise=0.9 puts per-client local training in the data-starved regime the
    paper operates in (collaboration must help; with clean templates a local
    linear probe saturates and no method can beat it)."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    tag = f"{dataset}_{samples_per_class}_{seed}_{noise}"
    path = os.path.join(CACHE_DIR, f"features_{tag}.npz")
    if os.path.exists(path):
        z = np.load(path, allow_pickle=True)
        return z["feats"], z["raw"], z["labels"], z["stats"].item()
    imgs, labels, stats = make_image_task_pool(dataset, seed=seed,
                                               samples_per_class=samples_per_class,
                                               noise=noise)
    feats = []
    for i in range(0, len(imgs), 256):
        feats.append(np.asarray(scatternet_features(jnp.asarray(imgs[i:i + 256]))))
    feats = np.concatenate(feats)
    rawf = imgs.reshape(len(imgs), -1)
    rawf = (rawf - rawf.mean()) / (rawf.std() + 1e-6)
    np.savez(path, feats=feats, raw=rawf, labels=labels, stats=stats)
    return feats, rawf, labels, stats


def client_split(features, labels, *, M: int, R: int, mode: str, level,
                 seed: int = 0):
    """Partition a pool into per-client train/test stacks.

    mode='shard' → level = N classes per client; mode='alpha' → level = γ."""
    if mode == "shard":
        idxs = shard_partition(labels, M, int(level), R, seed)
    else:
        idxs = alpha_partition(labels, M, float(level), R, seed)
    tr, te = zip(*[train_test_split(idx, 0.2, seed) for idx in idxs])
    n_tr = min(len(t) for t in tr)
    n_te = min(len(t) for t in te)
    trx, try_ = stack_client_data(features, labels, list(tr), n_tr)
    tex, tey = stack_client_data(features, labels, list(te), n_te)
    return trx, try_, tex, tey


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
