"""Round-schedule throughput: rounds/sec of the scheduled engine loop vs the
PR-2 full-participation body (ISSUE 3 acceptance).

ClientSampling keeps the scan device-resident — the mask draw, the two
participation merges, and the masked aggregation are the only ops added to
the PR-2 body, so q ∈ {1.0, 0.5, 0.1} should all land within noise of the
baseline (the simulation trains all M clients and masks the merge; the win
from sampling is privacy amplification, not FLOPs). AsyncStaleness skips
aggregation on non-boundary rounds. Writes ``BENCH_schedule.json`` via
``benchmarks/run.py``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.local import LocalStrategy
from repro.engine import (AsyncStaleness, ClientSampling, Engine,
                          FederatedData, FullParticipation)

LAST_RECORDS = []


def _make_data(M: int, R: int, feat: int, classes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, R))
    xs = protos[ys] + rng.normal(size=(M, R, feat)).astype(np.float32) * 0.4
    return xs, ys.astype(np.int32)


class _AvgStrategy(LocalStrategy):
    """Local training + a full-mean aggregate so AsyncStaleness has work to
    skip (LocalStrategy's aggregate is the identity)."""

    def aggregate(self, params, r, key):
        mean = jax.tree_util.tree_map(lambda t: jnp.mean(t, 0), params)
        M = jax.tree_util.tree_leaves(params)[0].shape[0]
        return jax.tree_util.tree_map(
            lambda m, p: 0.5 * p + 0.5 * jnp.broadcast_to(m[None], p.shape),
            mean, params)


def _loop_rps(schedule, X, Y, rounds: int, batch: int, feat: int,
              classes: int, seed: int = 0) -> float:
    strategy = _AvgStrategy(feat_dim=feat, num_classes=classes, lr=0.5)
    data = FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))
    engine = Engine(strategy, eval_every=rounds, schedule=schedule)
    key = jax.random.PRNGKey(seed)

    def run():
        state, _ = engine.fit(data, rounds=rounds, key=key, batch_size=batch,
                              evaluate=False)
        jax.tree_util.tree_leaves(state)[0].block_until_ready()

    run()                                 # compile the chunk once
    t0 = time.perf_counter()
    run()
    return rounds / (time.perf_counter() - t0)


def run(quick: bool = True):
    rows = []
    LAST_RECORDS.clear()
    M, R, feat, classes = (16, 96, 64, 10) if quick else (32, 160, 15552, 10)
    rounds = 100 if quick else 200
    batch = 24
    X, Y = _make_data(M, R, feat, classes)

    schedules = [
        ("pr2_full", FullParticipation()),
        ("sampling_q1.0", ClientSampling(q=1.0)),
        ("sampling_q0.5", ClientSampling(q=0.5)),
        ("sampling_q0.1", ClientSampling(q=0.1)),
        ("async_s4", AsyncStaleness(staleness=4)),
    ]
    base_rps = None
    for name, sched in schedules:
        rps = _loop_rps(sched, X, Y, rounds, batch, feat, classes)
        if base_rps is None:
            base_rps = rps
        rows.append((f"schedule_{name}_rps", 1e6 / rps, round(rps, 1)))
        LAST_RECORDS.append({"name": name, "rounds_per_sec": round(rps, 2),
                             "vs_pr2": round(rps / base_rps, 3),
                             "M": M, "R": R, "feat": feat, "rounds": rounds,
                             "batch": batch})
        print(f"[schedule] {name}: {rps:.1f} r/s ({rps / base_rps:.2f}x PR-2)",
              flush=True)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
