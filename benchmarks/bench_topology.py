"""Topology-subsystem throughput: rounds/sec and bytes/round of the DP-DSGT
gossip loop across graph families and densities (ISSUE 5 acceptance).

The mixing step is a sparse neighbor gather inside the scanned round body,
so denser graphs trade rounds/sec (more gather slots) and bytes/round
(more alive edges) for spectral gap — the same trade the accuracy sweeps
(``repro.launch.sweep --topology``) explore. The faulty ring row measures
the in-jit fault-draw overhead. ``--sharded`` adds the shard_map client-mesh
column under a forced 8-fake-device CPU mesh (the honest simulation the CI
job records: it measures collective overhead, not speedup).

Writes ``BENCH_topology.json`` via ``benchmarks/run.py`` (or directly when
run as a script).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    if "--sharded" in sys.argv[1:] and \
            "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        # must land before the first jax import below
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=8").strip()
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import topology as topo_lib
from repro.baselines.dp_dsgt import DPDSGTStrategy
from repro.core.p2p import P2PNetwork
from repro.engine import Engine, FederatedData, ShardedEngine

LAST_RECORDS = []


def _make_data(M: int, R: int, feat: int, classes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, R))
    xs = protos[ys] + rng.normal(size=(M, R, feat)).astype(np.float32) * 0.4
    return xs, ys.astype(np.int32)


def _loop_rps(topology, data, rounds: int, batch: int, feat: int,
              classes: int, mesh=None, seed: int = 0) -> float:
    strategy = DPDSGTStrategy(feat_dim=feat, num_classes=classes, lr=0.3,
                              sigma=0.3, topology=topology)
    engine = (ShardedEngine(strategy, eval_every=rounds, mesh=mesh)
              if mesh is not None else Engine(strategy, eval_every=rounds))
    key = jax.random.PRNGKey(seed)

    def run():
        state, _ = engine.fit(data, rounds=rounds, key=key, batch_size=batch,
                              evaluate=False)
        jax.tree_util.tree_leaves(state)[0].block_until_ready()

    run()                                 # compile the chunk once
    t0 = time.perf_counter()
    run()
    return rounds / (time.perf_counter() - t0)


def _bytes_per_round(topology, data, feat: int, classes: int,
                     seed: int = 0) -> dict:
    """Measured gossip load over a short accounted run (host-side ledger —
    independent of the engine flavor, so measured once)."""
    M = data.num_clients
    net = P2PNetwork(M)
    strategy = DPDSGTStrategy(feat_dim=feat, num_classes=classes, lr=0.3,
                              sigma=0.3, topology=topology)
    rounds = 4
    Engine(strategy, eval_every=rounds - 1, network=net).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(seed), batch_size=8)
    return {"bytes_per_round": round(net.total_bytes() / rounds, 1),
            "messages_per_round": round(net.num_messages() / rounds, 2),
            "links_used": len(net.per_link())}


def run(quick: bool = True, sharded: bool = False):
    rows = []
    LAST_RECORDS.clear()
    M, R, feat, classes = (16, 96, 64, 10) if quick else (32, 160, 1024, 10)
    rounds = 100 if quick else 200
    batch = 24
    X, Y = _make_data(M, R, feat, classes)
    data = FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))

    topologies = [
        ("ring", topo_lib.ring(M)),
        ("kregular4", topo_lib.k_regular(M, 4)),
        ("kregular8", topo_lib.k_regular(M, 8)),
        ("exponential", topo_lib.exponential(M)),
        ("full", topo_lib.fully_connected(M)),
        ("ring_drop0.2", topo_lib.ring(M).with_faults(0.2, 0.05)),
        ("gossip_seq", topo_lib.gossip_matchings(M, period=8)),
    ]

    mesh = None
    n_dev = len(jax.devices())
    if sharded or n_dev > 1:
        from repro.launch.mesh import make_client_mesh
        mesh = make_client_mesh()

    base_rps = None
    for name, topo in topologies:
        rps = _loop_rps(topo, data, rounds, batch, feat, classes)
        if base_rps is None:
            base_rps = rps
        load = _bytes_per_round(topo, data, feat, classes)
        rec = {"name": name, "rounds_per_sec": round(rps, 2),
               "vs_ring": round(rps / base_rps, 3),
               "spectral_gap": topo.describe()["spectral_gap"],
               "edges": topo.describe()["edges"],
               **load, "M": M, "rounds": rounds, "batch": batch}
        if mesh is not None:
            srps = _loop_rps(topo, data, rounds, batch, feat, classes,
                             mesh=mesh)
            rec["sharded_rounds_per_sec"] = round(srps, 2)
            rec["devices"] = n_dev
        rows.append((f"topology_{name}_rps", 1e6 / rps, round(rps, 1)))
        LAST_RECORDS.append(rec)
        extra = (f" sharded={rec['sharded_rounds_per_sec']:.1f} r/s"
                 if "sharded_rounds_per_sec" in rec else "")
        print(f"[topology] {name}: {rps:.1f} r/s "
              f"gap={rec['spectral_gap']} "
              f"{rec['bytes_per_round']:.0f} B/round{extra}", flush=True)

    rows += _learned_vs_static(data, feat, classes, batch)
    return rows


def _learned_vs_static(data, feat: int, classes: int, batch: int):
    """ISSUE 9 column: learned push-sum graph vs the static kregular4
    family at EQUAL TOTAL byte budget (estimation traffic included) —
    accuracy on both sides, plus the learned run's spectral-gap trajectory."""
    from repro.topology.learned import run_learned_dsgt

    M = data.num_clients
    rounds, interval = 32, 8
    net = P2PNetwork(M)
    t0 = time.perf_counter()
    _, lrec = run_learned_dsgt(data, rounds=rounds, interval=interval, k=4,
                               lr=0.3, sigma=0.3, sigma_dist=2.0, batch=batch,
                               seed=0, network=net, num_classes=classes)
    lsecs = time.perf_counter() - t0
    budget = net.total_bytes()

    static = topo_lib.k_regular(M, 4)
    load = _bytes_per_round(static, data, feat, classes)
    rounds_s = max(4, round(budget / max(load["bytes_per_round"], 1.0)))
    snet = P2PNetwork(M)
    strat = DPDSGTStrategy(feat_dim=feat, num_classes=classes, lr=0.3,
                           sigma=0.3, topology=static)
    _, hist = Engine(strat, eval_every=max(rounds_s - 1, 1), network=snet).fit(
        data, rounds=rounds_s, key=jax.random.PRNGKey(0), batch_size=batch)

    rec = {"name": "learned_vs_kregular4",
           "learned_accuracy": round(float(lrec["accuracy"]), 4),
           "static_accuracy": round(float(hist[-1][1]), 4),
           "learned_rounds": rounds, "static_rounds_at_budget": rounds_s,
           "bytes_budget": int(budget),
           "learned_bytes_per_round": round(budget / rounds, 1),
           "static_bytes_per_round": load["bytes_per_round"],
           "gap_trajectory": lrec["gap_trajectory"],
           "estimates": lrec["estimates"],
           "fallbacks": lrec["fallbacks"],
           "M": M, "batch": batch}
    LAST_RECORDS.append(rec)
    print(f"[topology] learned vs kregular4 @ equal bytes: "
          f"{rec['learned_accuracy']} vs {rec['static_accuracy']} "
          f"({rounds} vs {rounds_s} rounds), "
          f"gaps={rec['gap_trajectory']}", flush=True)
    return [("topology_learned_secs", lsecs * 1e6, round(lsecs, 1))]


if __name__ == "__main__":
    import json
    _quick = "--full" not in sys.argv[1:]
    rows = run(quick=_quick, sharded="--sharded" in sys.argv[1:])
    for r in rows:
        print(",".join(map(str, r)))
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_topology.json")
    with open(out_path, "w") as f:
        json.dump({"platform": jax.default_backend(), "quick": _quick,
                   "entries": LAST_RECORDS}, f, indent=2)
    print(f"wrote {out_path}")
