"""§Roofline table — renders the dry-run sweep (results/dryrun_sweep.jsonl)
into the per-(arch × shape × mesh) roofline report: three terms, dominant
bottleneck, MODEL_FLOPS ratio, and what would move the dominant term."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_sweep.jsonl")

_ADVICE = {
    "compute_s": "already compute-bound — only lower-precision math or fewer "
                 "model FLOPs (e.g. no remat, causal-skip attention) help",
    "memory_s": "fuse elementwise chains / keep activations bf16 / larger "
                "per-chip batch to amortize weight streaming",
    "collective_s": "reshard to cut all-gathers (e.g. 2D FSDP->1D, EP-friendly "
                    "dispatch) or overlap collectives with compute",
}


def load(path=RESULTS):
    rows = []
    if not os.path.exists(path):
        return rows
    for line in open(path):
        try:
            rows.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    # keep the newest entry per combo key
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"], r.get("p4", False),
               tuple(r.get("variant", ())))] = r
    return list(dedup.values())


def run(quick: bool = True):
    rows = []
    data = load()
    if not data:
        print("[roofline] no sweep results yet (run repro.launch.sweep)")
        return [("roofline_combos", 0.0, 0)]
    data.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    print(f"{'arch':26s} {'shape':12s} {'mesh':8s} {'compute':>9s} {'memory':>9s}"
          f" {'collective':>11s} {'bottleneck':>12s} {'useful':>7s}")
    for r in data:
        t = r["roofline"]
        useful = r.get("useful_flops_ratio")
        print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s}"
              f" {t['compute_s']:9.4f} {t['memory_s']:9.4f}"
              f" {t['collective_s']:11.4f} {t['bottleneck'][:-2]:>12s}"
              f" {useful if useful is None else round(useful, 3)!s:>7s}")
        rows.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                     t[t["bottleneck"]] * 1e6 if t["bottleneck"] in t else 0.0,
                     t["bottleneck"]))
    n_combo = len({(r['arch'], r['shape']) for r in data})
    n_multi = len([r for r in data if r["mesh"] == "2x16x16"])
    print(f"[roofline] combos={n_combo} multi-pod rows={n_multi}")
    rows.append(("roofline_combos", 0.0, n_combo))
    return rows


if __name__ == "__main__":
    run()
