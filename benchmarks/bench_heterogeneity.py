"""Paper Figs. 2/3/5/6 — test accuracy of all methods across heterogeneity
levels (shard-based N ∈ {2,4,8}; alpha-based γ ∈ {0.25, 0.5, 0.75}), ε = 15,
linear model on ScatterNet features.

Claim validated (paper §4.3): P4 ≥ every baseline at every heterogeneity
level, with the gap largest at high heterogeneity (small N / small γ).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, client_split, feature_pool
from repro.baselines import centralized, dp_dsgt, fedavg, local, proxyfl, scaffold
from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
from repro.core.p4 import P4Trainer

EPS = 15.0


def run_methods(trx, try_, tex, tey, *, rounds: int, lr: float = 0.5,
                batch: int = 24, group_size: int = 4, methods=None):
    tex_j, tey_j = jnp.asarray(tex), jnp.asarray(tey)
    out = {}
    sel = methods or ("p4", "local", "centralized", "fedavg", "scaffold",
                      "proxyfl", "dp_dsgt")
    classes = int(try_.max()) + 1

    if "p4" in sel:
        cfg = RunConfig(dp=DPConfig(epsilon=EPS, rounds=rounds,
                                    sample_rate=batch / try_.shape[1]),
                        p4=P4Config(group_size=group_size,
                                    sample_peers=min(10, try_.shape[0] - 1)),
                        train=TrainConfig(learning_rate=lr))
        tr = P4Trainer(feat_dim=trx.shape[-1], num_classes=classes, cfg=cfg)
        _, groups, hist = tr.fit(trx, try_, tex_j, tey_j, rounds=rounds,
                                 eval_every=max(rounds - 1, 1),
                                 batch_size=batch)
        out["p4"] = hist[-1][1]
    if "local" in sel:
        _, h = local.train(trx, try_, tex_j, tey_j, rounds=rounds, lr=lr,
                           batch_size=batch, eval_every=max(rounds - 1, 1))
        out["local"] = h[-1][1]
    if "centralized" in sel:
        _, h = centralized.train(trx.reshape(-1, trx.shape[-1]), try_.reshape(-1),
                                 tex_j, tey_j, rounds=rounds, lr=lr,
                                 eval_every=max(rounds - 1, 1))
        out["centralized"] = h[-1][1]
    if "fedavg" in sel:
        _, h, _ = fedavg.train(trx, try_, tex_j, tey_j, rounds=rounds, lr=lr,
                               batch_size=batch, epsilon=EPS,
                               eval_every=max(rounds - 1, 1))
        out["fedavg"] = h[-1][1]
    if "scaffold" in sel:
        _, h, _ = scaffold.train(trx, try_, tex_j, tey_j, rounds=rounds, lr=lr / 2,
                                 batch_size=batch, epsilon=EPS,
                                 eval_every=max(rounds - 1, 1))
        out["scaffold"] = h[-1][1]
    if "proxyfl" in sel:
        _, h, _ = proxyfl.train(trx, try_, tex_j, tey_j, rounds=rounds, lr=lr,
                                batch_size=batch, epsilon=EPS,
                                eval_every=max(rounds - 1, 1))
        out["proxyfl"] = h[-1][1]
    if "dp_dsgt" in sel:
        _, h, _ = dp_dsgt.train(trx, try_, tex_j, tey_j, rounds=rounds, lr=lr / 2,
                                batch_size=batch, epsilon=EPS,
                                eval_every=max(rounds - 1, 1))
        out["dp_dsgt"] = h[-1][1]
    return out


def run(quick: bool = True, dataset: str = "femnist", mode: str = "shard"):
    rows = []
    M, R = (16, 96) if quick else (32, 160)
    rounds = 40 if quick else 100
    feats, _, labels, stats = feature_pool(dataset,
                                           samples_per_class=60 if quick else 120)
    levels = ([2, 4, 8] if mode == "shard" else [0.25, 0.5, 0.75])
    for level in levels:
        trx, try_, tex, tey = client_split(feats, labels, M=M, R=R, mode=mode,
                                           level=level)
        with Timer() as t:
            accs = run_methods(trx, try_, tex, tey, rounds=rounds)
        for m, a in accs.items():
            rows.append((f"hetero_{dataset}_{mode}{level}_{m}", t.dt * 1e6 / rounds,
                         round(a, 4)))
        print(f"[hetero {dataset} {mode}={level}] " +
              " ".join(f"{m}={a:.3f}" for m, a in sorted(accs.items())), flush=True)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
