"""Stateful (Markov) fault processes — the correlated failure regimes the
i.i.d. draws in ``topology/faults.py`` cannot express.

PR 5's fault injection redraws every link/node fate independently each round;
real edge deployments fail in *bursts*: a congested link stays congested, a
crashed device stays down until repaired, a partition cuts the network for a
stretch of rounds, a thermally-throttled phone lags for minutes.  This module
models those as per-edge / per-node / global two-state Markov chains whose
state is carried through the engine's ``lax.scan`` chunk:

  * **links** — Gilbert–Elliott: each undirected edge is good/bad; good→bad
    with ``link_fail``, bad→good with ``link_repair`` (mean burst length
    ``1/link_repair`` rounds).  ``gilbert_elliott_rates`` converts the
    (stationary drop rate, mean burst length) parameterization the sweeps
    use into the two transition rates.
  * **nodes** — outage/repair chain with geometric dwell times
    (``node_fail`` / ``node_repair``); a down node's links all drop and its
    mixing row degenerates to the identity, exactly as PR-5 churn.
  * **partition** — with ``partition_prob`` a balanced bisection of the
    clients is sampled and every cross-cut link drops until the partition
    heals (geometric duration, ``partition_repair``).
  * **stragglers** — per-client slow/fast chain (``slow_enter`` /
    ``slow_exit``).  A slow client is *frozen*: its local update is
    discarded and it receives nothing; its ``age`` (rounds since it last
    participated) feeds ``AsyncStaleness``'s per-client staleness discount,
    so slow devices emerge from the fault model instead of a fixed ``s``.

Each round the process steps on ``fold_in(fold_in(phase_key, r),
RESILIENCE_STREAM)`` — a stream disjoint from the batch/local/aggregate/
cohort streams 0–3 and from ``topology.faults.FAULT_STREAM`` — so installing
a process never perturbs any other draw, and eager host-side replay
(``host_realizations`` / ``fault_state_at``) re-derives the exact traced
realizations for byte accounting and crash-resume fast-forward: jax's PRNG
is bit-identical eager and traced.

The realized ``keep`` matrix stays symmetric with ``diag = up``, the same
contract as ``draw_fault_masks`` — the mixing step's diagonal-fold therefore
keeps every realized gossip matrix doubly stochastic under correlated masks
too.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, NamedTuple, Tuple

from repro.obs.probes import Probe

RESILIENCE_STREAM = 0x71

# Registry-backed fault/failover event tallies (see repro.obs). Counted on
# the HOST replay path — the traced chains are bit-identical replays of the
# same transitions, so these are exact event counts for every run that logs
# communication or resumes, at zero traced cost. ``failover_rounds`` /
# ``quorum_silent_rounds`` are bumped by the P4 rotating-aggregator
# accounting when a group runs on a stand-in or falls silent below quorum.
FAULT_STATS = Probe("resilience.faults", {
    "replayed_rounds": 0,
    "down_client_rounds": 0,   # Σ over replayed rounds of clients down
    "slow_client_rounds": 0,   # Σ over replayed rounds of straggling clients
    "failover_rounds": 0,
    "quorum_silent_rounds": 0,
})


# ---------------------------------------------------------------------------
# model + state
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultModel:
    """Transition rates (per round) of the correlated fault chains. A rate of
    zero statically removes that chain's ops from the trace."""
    link_fail: float = 0.0        # Gilbert–Elliott good→bad, per edge
    link_repair: float = 1.0      # bad→good (mean burst = 1/link_repair)
    node_fail: float = 0.0        # node up→down
    node_repair: float = 1.0      # down→up (mean outage = 1/node_repair)
    partition_prob: float = 0.0   # chance a partition event starts
    partition_repair: float = 0.5  # chance an active partition heals
    slow_enter: float = 0.0       # client fast→slow (straggler chain)
    slow_exit: float = 1.0        # slow→fast
    quorum: float = 0.0           # P4: min up-fraction for group aggregation

    @property
    def enabled(self) -> bool:
        return (self.link_fail > 0 or self.node_fail > 0
                or self.partition_prob > 0 or self.slow_enter > 0)


def gilbert_elliott_rates(drop: float, burst_len: float) -> Tuple[float, float]:
    """(stationary drop probability, mean burst length in rounds) → the
    (link_fail, link_repair) rates realizing them: repair = 1/L and
    fail = drop·repair/(1-drop), from the chain's stationary distribution
    π_bad = fail/(fail+repair)."""
    if drop <= 0.0:
        return 0.0, 1.0
    if not 0.0 < drop < 1.0 or burst_len < 1.0:
        raise ValueError(f"need 0<drop<1 and burst_len>=1, got {drop}, {burst_len}")
    repair = 1.0 / burst_len
    fail = drop * repair / (1.0 - drop)
    return min(fail, 1.0), repair


class FaultState(NamedTuple):
    """The scanned carry: one entry per chain, all float32 indicators."""
    link_bad: object    # (M, M) symmetric, diag 0 — edge currently bursty
    down: object        # (M,) node currently in outage
    part_active: object  # () a partition is currently cutting the graph
    part_side: object   # (M,) bisection side of the active partition
    slow: object        # (M,) client currently a straggler
    age: object         # (M,) rounds since the client last participated


class FaultRealization(NamedTuple):
    """What one round actually sees, derived from the post-transition state."""
    keep: object        # (M, M) effective edge-keep (symmetric, diag = up)
    up: object          # (M,) node not in outage
    slow: object        # (M,) straggler indicator
    age: object         # (M,) rounds the client missed entering this round

    def active(self):
        """Participating this round: up and not a straggler."""
        return self.up * (1.0 - self.slow)


@dataclass(frozen=True)
class FaultProcess:
    """A fault model bound to a client count — hashable, so it can live in the
    compiled-chunk cache key, and stateless, so host replay and the traced
    scan share one ``step``."""
    model: FaultModel
    M: int

    def fingerprint(self) -> Tuple:
        m = self.model
        return ("faults", self.M, m.link_fail, m.link_repair, m.node_fail,
                m.node_repair, m.partition_prob, m.partition_repair,
                m.slow_enter, m.slow_exit, m.quorum)

    def init_state(self) -> FaultState:
        import jax.numpy as jnp
        M = self.M
        z = lambda shape: jnp.zeros(shape, jnp.float32)
        return FaultState(z((M, M)), z((M,)), z(()), z((M,)), z((M,)), z((M,)))

    def round_key(self, phase_key, r):
        import jax
        return jax.random.fold_in(jax.random.fold_in(phase_key, r),
                                  RESILIENCE_STREAM)

    def step(self, state: FaultState, r, key):
        """One Markov transition + the round's realization. Ordinary jax:
        eager on the host (replay) and traced in the chunk — bit-identical."""
        import jax
        import jax.numpy as jnp
        m, M = self.model, self.M
        kl, kn, kp, kside, ks = jax.random.split(key, 5)
        f32 = jnp.float32

        # links: one coupled uniform per undirected edge drives both branches
        if m.link_fail > 0.0:
            u = jax.random.uniform(kl, (M, M))
            tri = jnp.triu(u, 1)
            u_sym = tri + tri.T
            bad = state.link_bad
            stay = (u_sym >= m.link_repair).astype(f32)
            enter = (u_sym < m.link_fail).astype(f32)
            link_bad = bad * stay + (1.0 - bad) * enter
            link_bad = jnp.where(jnp.eye(M, dtype=bool), 0.0, link_bad)
        else:
            link_bad = state.link_bad

        # node outage/repair chain
        if m.node_fail > 0.0:
            u = jax.random.uniform(kn, (M,))
            down = state.down
            down = (down * (u >= m.node_repair).astype(f32)
                    + (1.0 - down) * (u < m.node_fail).astype(f32))
        else:
            down = state.down

        # partition: scalar on/off chain + a balanced bisection sampled at
        # every onset (the argsort trick draws exactly M//2 per side)
        if m.partition_prob > 0.0:
            u = jax.random.uniform(kp, ())
            act = state.part_active
            new_act = jnp.where(act > 0,
                                (u >= m.partition_repair).astype(f32),
                                (u < m.partition_prob).astype(f32))
            su = jax.random.uniform(kside, (M,))
            fresh = (jnp.argsort(jnp.argsort(su)) < M // 2).astype(f32)
            starts = (act <= 0) & (new_act > 0)
            side = jnp.where(starts, fresh, state.part_side)
        else:
            new_act, side = state.part_active, state.part_side

        # straggler chain
        if m.slow_enter > 0.0:
            u = jax.random.uniform(ks, (M,))
            slow = state.slow
            slow = (slow * (u >= m.slow_exit).astype(f32)
                    + (1.0 - slow) * (u < m.slow_enter).astype(f32))
        else:
            slow = state.slow

        up = 1.0 - down
        keep = 1.0 - link_bad            # diag stays 1 pre-outage
        if m.partition_prob > 0.0:
            cross = (side[:, None] != side[None, :]).astype(f32)
            keep = keep * (1.0 - new_act * cross)
        keep = keep * up[:, None] * up[None, :]   # diag = up, as PR-5 churn

        # the realization carries the age *entering* the round — a client
        # recovering after k missed rounds contributes a k-stale update, so
        # its AsyncStaleness merge weight is (1+k)^-pow even though the state
        # counter resets now that it participates again
        active = up * (1.0 - slow)
        age_next = jnp.where(active > 0, 0.0, state.age + 1.0)
        new_state = FaultState(link_bad, down, new_act, side, slow, age_next)
        return new_state, FaultRealization(keep, up, slow, state.age)


def make_fault_process(cfg, M: int):
    """Build a process from a ``config.FaultConfig`` (or any object with the
    same rate attributes); ``None`` when every chain is disabled."""
    model = FaultModel(
        link_fail=cfg.link_fail, link_repair=cfg.link_repair,
        node_fail=cfg.node_fail, node_repair=cfg.node_repair,
        partition_prob=cfg.partition_prob,
        partition_repair=cfg.partition_repair,
        slow_enter=cfg.slow_enter, slow_exit=cfg.slow_exit,
        quorum=cfg.quorum)
    if not model.enabled:
        return None
    return FaultProcess(model=model, M=int(M))


# ---------------------------------------------------------------------------
# trace-time context: how strategies/schedules see the round's realization
# without a hook-signature change (same mechanism as runtime_params)
# ---------------------------------------------------------------------------

_CTX = threading.local()


@dataclass
class ActiveFaults:
    """The traced realization plus the static model (quorum etc.) — what
    ``current_faults`` hands to schedule bodies and strategy hooks during the
    chunk trace."""
    real: FaultRealization
    model: FaultModel


@contextmanager
def active_faults(af: ActiveFaults):
    prev = getattr(_CTX, "value", None)
    _CTX.value = af
    try:
        yield
    finally:
        _CTX.value = prev


def current_faults():
    return getattr(_CTX, "value", None)


def wrap_round_body(body, process: FaultProcess):
    """Engine glue: step the process on the round's resilience stream, expose
    the realization to the inner body via the context, and thread the
    ``FaultState`` through the scan carry. Works unchanged for the sharded
    body — the carry is replicated, the step uses no collectives, and every
    slice realizes the identical masks."""
    import jax.numpy as jnp

    def wrapped(carry, r, phase_key, *data):
        state, fstate = carry
        fstate, real = process.step(fstate, r, process.round_key(phase_key, r))
        with active_faults(ActiveFaults(real, process.model)):
            state, (metrics, aux) = body(state, r, phase_key, *data)
        aux = dict(aux)
        aux.setdefault("participation", real.active())
        aux["fault_up"] = jnp.mean(real.up)
        aux["fault_slow"] = jnp.mean(real.slow)
        aux["fault_keep"] = jnp.mean(real.keep)
        return (state, fstate), (metrics, aux)

    return wrapped


# ---------------------------------------------------------------------------
# host-side replay: byte accounting + crash-resume fast-forward
# ---------------------------------------------------------------------------

class HostFaults:
    """Numpy view of one round's realization for host-side consumers
    (``Strategy.log_communication``), plus the static model for failover /
    quorum re-derivation."""

    def __init__(self, real: FaultRealization, model: FaultModel):
        import numpy as np
        self.keep = np.asarray(real.keep)
        self.up = np.asarray(real.up)
        self.slow = np.asarray(real.slow)
        self.age = np.asarray(real.age)
        self.model = model

    @property
    def active(self):
        return self.up * (1.0 - self.slow)


def _key_bytes(key) -> bytes:
    import jax
    import numpy as np
    try:
        data = jax.random.key_data(key)
    except Exception:
        data = key
    return np.asarray(data).tobytes()


# (process, phase-key bytes, origin) → incremental replay: the accounting
# calls arrive in ascending round order, so the chain advances monotonically
# and each realization is derived exactly once per phase.
_REPLAY: Dict[Tuple, Dict] = {}
_REPLAY_MAX = 32


def _replay_entry(process: FaultProcess, phase_key, origin: int, upto: int):
    cache_key = (process, _key_bytes(phase_key), origin)
    ent = _REPLAY.get(cache_key)
    if ent is None:
        ent = {"round": origin, "state": process.init_state(), "reals": []}
        _REPLAY[cache_key] = ent
        while len(_REPLAY) > _REPLAY_MAX:
            _REPLAY.pop(next(iter(_REPLAY)))
    while ent["round"] < upto:
        r = ent["round"]
        ent["state"], real = process.step(
            ent["state"], r, process.round_key(phase_key, r))
        hf = HostFaults(real, process.model)
        FAULT_STATS["replayed_rounds"] += 1
        FAULT_STATS["down_client_rounds"] += int((hf.up <= 0).sum())
        FAULT_STATS["slow_client_rounds"] += int((hf.slow > 0).sum())
        ent["reals"].append(hf)
        ent["round"] += 1
    return ent


def host_realizations(process: FaultProcess, phase_key, origin: int,
                      start: int, stop: int):
    """The exact realizations the traced rounds [start, stop) used, replayed
    eagerly from the phase origin — the correlated-process twin of
    ``topology.faults.host_fault_masks``."""
    ent = _replay_entry(process, phase_key, origin, stop)
    return ent["reals"][start - origin:stop - origin]


def fault_state_at(process: FaultProcess, phase_key, origin: int,
                   round_: int) -> FaultState:
    """The chain's state entering ``round_``, replayed from the phase origin —
    how a resumed run rejoins the fault trajectory bit-exactly without
    persisting fault state in checkpoints."""
    state = process.init_state()
    for r in range(origin, round_):
        state, _ = process.step(state, r, process.round_key(phase_key, r))
    return state
