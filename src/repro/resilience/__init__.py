from repro.resilience.processes import (ActiveFaults, FAULT_STATS, FaultModel,
                                        FaultProcess, FaultRealization,
                                        FaultState, HostFaults,
                                        RESILIENCE_STREAM, active_faults,
                                        current_faults, fault_state_at,
                                        gilbert_elliott_rates,
                                        host_realizations, make_fault_process,
                                        wrap_round_body)

__all__ = [
    "ActiveFaults", "FAULT_STATS", "FaultModel", "FaultProcess",
    "FaultRealization", "FaultState", "HostFaults", "RESILIENCE_STREAM",
    "active_faults", "current_faults", "fault_state_at",
    "gilbert_elliott_rates", "host_realizations", "make_fault_process",
    "wrap_round_body",
]
