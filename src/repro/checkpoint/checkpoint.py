"""Crash-safe flat-npz pytree checkpointing with verified sidecar metadata.

Leaves are addressed by their tree path ("params/blocks/attn_attn/wq"); the
treedef is reconstructed from the template pytree at restore time, so restore
is shape- and dtype-checked against the current model definition.

Durability contract (the chaos tier SIGKILLs a training run mid-write and
resumes from whatever survived):

  * the archive is serialized fully in memory, written to a deterministic
    ``<path>.tmp`` through an explicit handle, fsync'd, and renamed into
    place — a reader never observes a torn ``ckpt_*.npz``;
  * a ``ckpt_*.json`` sidecar (same atomic dance) records the step, a CRC-32
    of the archive bytes, and the byte count, plus any caller metadata (the
    engine stores its ``History`` there so a resumed run's record is
    bit-exact with an uninterrupted one);
  * ``latest_step`` only reports steps whose archive verifies against the
    sidecar, skipping ``.tmp`` orphans and torn/corrupt files, so auto-resume
    falls back to the newest checkpoint that actually survived the crash;
  * ``keep_last`` retains a bounded history, deleting npz+json pairs oldest
    first.
"""
from __future__ import annotations

import io
import json
import os
import re
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(ValueError):
    """A checkpoint exists but cannot be trusted (torn write, bad checksum,
    or a leaf that no longer matches the template)."""


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_seg(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":         # ml_dtypes (bf16, fp8, ...)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _npz_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


def _json_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.json")


def _atomic_write(path: str, data: bytes) -> None:
    """Deterministic temp name, explicit handle, fsync, rename — then fsync
    the directory so the rename itself survives a crash."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dirfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dirfd)
    except OSError:
        pass   # some filesystems refuse directory fsync; rename still landed
    finally:
        os.close(dirfd)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None, keep_last: int = 0):
    os.makedirs(directory, exist_ok=True)
    buf = io.BytesIO()
    np.savez(buf, **_flatten(tree))
    data = buf.getvalue()
    path = _npz_path(directory, step)
    _atomic_write(path, data)
    meta = {"step": int(step), "checksum": zlib.crc32(data),
            "nbytes": len(data), **(metadata or {})}
    _atomic_write(_json_path(directory, step), json.dumps(meta).encode())
    if keep_last and keep_last > 0:
        for old in _all_steps(directory)[:-keep_last]:
            for p in (_npz_path(directory, old), _json_path(directory, old)):
                try:
                    os.remove(p)
                except OSError:
                    pass
    return path


def _all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    # the $ anchor already excludes ckpt_*.npz.tmp orphans from a torn write
    return sorted(int(m.group(1)) for f in os.listdir(directory)
                  if (m := re.match(r"ckpt_(\d+)\.npz$", f)))


def verify_checkpoint(directory: str, step: int) -> bool:
    """Cheap integrity check: archive bytes hash to the sidecar's CRC-32 and
    match its length. Sidecar-less archives (pre-hardening writers) pass if
    the zip at least parses."""
    path = _npz_path(directory, step)
    if not os.path.isfile(path):
        return False
    jpath = _json_path(directory, step)
    if os.path.isfile(jpath):
        try:
            with open(jpath) as f:
                meta = json.load(f)
        except (json.JSONDecodeError, OSError):
            return False
        if "checksum" in meta:
            try:
                with open(path, "rb") as f:
                    data = f.read()
            except OSError:
                return False
            return (len(data) == meta.get("nbytes", len(data))
                    and zlib.crc32(data) == meta["checksum"])
    try:
        np.load(path).close()
        return True
    except Exception:
        return False


def latest_step(directory: str) -> Optional[int]:
    """Newest step whose checkpoint verifies — torn or corrupt archives are
    skipped so auto-resume falls back to the last durable one."""
    for step in reversed(_all_steps(directory)):
        if verify_checkpoint(directory, step):
            return step
    return None


def load_checkpoint_metadata(directory: str, step: int) -> Optional[dict]:
    jpath = _json_path(directory, step)
    if not os.path.isfile(jpath):
        return None
    try:
        with open(jpath) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None


# ---------------------------------------------------------------------------
# Incremental population checkpoints (PagedEngine's host-resident client
# store). A full (M, ...) snapshot every ``full_every`` saves, dirty-row
# deltas in between: pop_<step>.npz holds one entry per population leaf
# ("a0", "a1", ...) restricted to the rows touched since the previous save
# (plus "__rows__"), and the pop_<step>.json sidecar records the delta's
# base step, so restore walks base-chain to the newest full snapshot and
# replays deltas oldest→newest — bit-exact regardless of the restoring run's
# init values, because the full snapshot covers every row.
#
# Durability: same atomic-write + CRC sidecar dance as the plain checkpoint.
# The ENGINE writes the population before the plain ckpt npz, making the
# ckpt the commit point — resume walks ``verified_steps`` newest-first and
# takes the first whose ``population_chain_ok`` also holds, so a SIGKILL
# between the two writes falls back to the previous durable pair.
# ---------------------------------------------------------------------------


def _pop_npz_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"pop_{step:08d}.npz")


def _pop_json_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"pop_{step:08d}.json")


def _pop_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for f in os.listdir(directory)
                  if (m := re.match(r"pop_(\d+)\.npz$", f)))


def _pop_meta(directory: str, step: int) -> Optional[dict]:
    jpath = _pop_json_path(directory, step)
    if not os.path.isfile(jpath):
        return None
    try:
        with open(jpath) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None


def verify_population(directory: str, step: int) -> bool:
    """One population file verifies against its CRC sidecar."""
    path = _pop_npz_path(directory, step)
    meta = _pop_meta(directory, step)
    if meta is None or not os.path.isfile(path):
        return False
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return False
    return (len(data) == meta.get("nbytes", len(data))
            and zlib.crc32(data) == meta.get("checksum"))


def _pop_chain(directory: str, step: int):
    """Steps full-snapshot→...→step, or None if the chain is broken (a file
    missing, torn, or a base loop)."""
    chain, seen = [], set()
    cur = step
    while cur is not None:
        if cur in seen or not verify_population(directory, cur):
            return None
        seen.add(cur)
        chain.append(cur)
        meta = _pop_meta(directory, cur)
        if meta.get("full"):
            return list(reversed(chain))
        cur = meta.get("base")
    return None   # ran off the chain without hitting a full snapshot


def population_chain_ok(directory: str, step: int) -> bool:
    """True when the population at ``step`` is restorable — either the delta
    chain back to a full snapshot verifies, or the run has no population
    files at all (strategies with no client-stacked leaves)."""
    if not _pop_steps(directory):
        return True
    return _pop_chain(directory, step) is not None


def save_population(directory: str, step: int, pop, keep_last: int = 0,
                    full_every: int = 8):
    """Save the population incrementally: dirty rows as a delta on the
    previous save, or a full snapshot when there is no prior verified save
    or the delta chain has reached ``full_every`` links. Clears the
    population's dirty tracking on success."""
    os.makedirs(directory, exist_ok=True)
    prev = None
    for s in reversed(_pop_steps(directory)):
        if s < step and verify_population(directory, s):
            prev = s
            break
    depth = None
    if prev is not None:
        pmeta = _pop_meta(directory, prev)
        depth = pmeta.get("depth", 0 if pmeta.get("full") else None)
    full = depth is None or depth + 1 >= max(int(full_every), 1)
    buf = io.BytesIO()
    if full:
        np.savez(buf, **{f"a{i}": a for i, a in enumerate(pop.arrays)})
        rows = pop.M
    else:
        dirty = pop.dirty_rows()
        np.savez(buf, __rows__=dirty.astype(np.int64),
                 **{f"a{i}": a[dirty] for i, a in enumerate(pop.arrays)})
        rows = int(len(dirty))
    data = buf.getvalue()
    path = _pop_npz_path(directory, step)
    _atomic_write(path, data)
    meta = {"step": int(step), "full": bool(full),
            "base": None if full else int(prev),
            "depth": 0 if full else int(depth) + 1,
            "rows": int(rows), "leaves": len(pop.arrays),
            "checksum": zlib.crc32(data), "nbytes": len(data)}
    _atomic_write(_pop_json_path(directory, step), json.dumps(meta).encode())
    pop.clear_dirty()
    if keep_last and keep_last > 0:
        # retain every file REACHABLE from the newest keep_last saves' chains
        # (deleting a delta's base would orphan the whole suffix)
        steps = _pop_steps(directory)
        reachable = set()
        for s in steps[-keep_last:]:
            reachable.update(_pop_chain(directory, s) or [s])
        for s in steps:
            if s not in reachable:
                for p in (_pop_npz_path(directory, s),
                          _pop_json_path(directory, s)):
                    try:
                        os.remove(p)
                    except OSError:
                        pass
    return path


def restore_population(directory: str, pop, step: int) -> None:
    """Rebuild the population at ``step`` in place: apply the full snapshot
    then every delta along the chain, oldest→newest. Raises
    ``CheckpointError`` when the chain is broken."""
    chain = _pop_chain(directory, step)
    if chain is None:
        raise CheckpointError(
            f"population chain for step {step} in {directory} is broken "
            "(missing, torn, or pruned base)")
    for s in chain:
        meta = _pop_meta(directory, s)
        if meta.get("leaves", len(pop.arrays)) != len(pop.arrays):
            raise CheckpointError(
                f"population file {s} has {meta.get('leaves')} leaves, "
                f"expected {len(pop.arrays)}")
        with np.load(_pop_npz_path(directory, s)) as data:
            if meta.get("full"):
                for i, a in enumerate(pop.arrays):
                    src = data[f"a{i}"]
                    if src.shape != a.shape:
                        raise CheckpointError(
                            f"population leaf a{i} at step {s} has shape "
                            f"{src.shape}, expected {a.shape}")
                    a[...] = src.astype(a.dtype)
            else:
                rows = data["__rows__"]
                for i, a in enumerate(pop.arrays):
                    a[rows] = data[f"a{i}"].astype(a.dtype)
    pop.clear_dirty()
    pop.version += 1


def verified_steps(directory: str):
    """All steps (ascending) whose PLAIN checkpoint verifies — candidates
    for paged resume, to be filtered by ``population_chain_ok``."""
    return [s for s in _all_steps(directory) if verify_checkpoint(directory, s)]


def restore_checkpoint(directory: str, template: Any, step: Optional[int] = None):
    """Restore into the structure of ``template`` (shape/dtype enforced).
    Raises ``CheckpointError`` on corruption and ``ValueError`` naming the
    leaf path when the archive no longer matches the template."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = _npz_path(directory, step)
    if not os.path.isfile(path):
        raise FileNotFoundError(path)
    if not verify_checkpoint(directory, step):
        raise CheckpointError(f"checkpoint {path} failed integrity check "
                              "(torn write or bit rot)")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(_seg(x) for x in p)
        if key not in data:
            raise ValueError(f"checkpoint {path} is missing leaf '{key}' "
                             f"required by the template")
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf '{key}' has shape {tuple(arr.shape)} but "
                f"the template expects {tuple(leaf.shape)} ({path})")
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
