"""Flat-npz pytree checkpointing with step metadata.

Leaves are addressed by their tree path ("params/blocks/attn_attn/wq"); the
treedef is reconstructed from the template pytree at restore time, so restore
is shape- and dtype-checked against the current model definition.
"""
from __future__ import annotations

import json
import jax.numpy as jnp
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_seg(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":         # ml_dtypes (bf16, fp8, ...)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any, metadata: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    meta = {"step": step, **(metadata or {})}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: Any, step: Optional[int] = None):
    """Restore into the structure of ``template`` (shape/dtype enforced)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(_seg(x) for x in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
