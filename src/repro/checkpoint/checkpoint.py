"""Crash-safe flat-npz pytree checkpointing with verified sidecar metadata.

Leaves are addressed by their tree path ("params/blocks/attn_attn/wq"); the
treedef is reconstructed from the template pytree at restore time, so restore
is shape- and dtype-checked against the current model definition.

Durability contract (the chaos tier SIGKILLs a training run mid-write and
resumes from whatever survived):

  * the archive is serialized fully in memory, written to a deterministic
    ``<path>.tmp`` through an explicit handle, fsync'd, and renamed into
    place — a reader never observes a torn ``ckpt_*.npz``;
  * a ``ckpt_*.json`` sidecar (same atomic dance) records the step, a CRC-32
    of the archive bytes, and the byte count, plus any caller metadata (the
    engine stores its ``History`` there so a resumed run's record is
    bit-exact with an uninterrupted one);
  * ``latest_step`` only reports steps whose archive verifies against the
    sidecar, skipping ``.tmp`` orphans and torn/corrupt files, so auto-resume
    falls back to the newest checkpoint that actually survived the crash;
  * ``keep_last`` retains a bounded history, deleting npz+json pairs oldest
    first.
"""
from __future__ import annotations

import io
import json
import os
import re
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(ValueError):
    """A checkpoint exists but cannot be trusted (torn write, bad checksum,
    or a leaf that no longer matches the template)."""


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_seg(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":         # ml_dtypes (bf16, fp8, ...)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _npz_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


def _json_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.json")


def _atomic_write(path: str, data: bytes) -> None:
    """Deterministic temp name, explicit handle, fsync, rename — then fsync
    the directory so the rename itself survives a crash."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dirfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dirfd)
    except OSError:
        pass   # some filesystems refuse directory fsync; rename still landed
    finally:
        os.close(dirfd)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None, keep_last: int = 0):
    os.makedirs(directory, exist_ok=True)
    buf = io.BytesIO()
    np.savez(buf, **_flatten(tree))
    data = buf.getvalue()
    path = _npz_path(directory, step)
    _atomic_write(path, data)
    meta = {"step": int(step), "checksum": zlib.crc32(data),
            "nbytes": len(data), **(metadata or {})}
    _atomic_write(_json_path(directory, step), json.dumps(meta).encode())
    if keep_last and keep_last > 0:
        for old in _all_steps(directory)[:-keep_last]:
            for p in (_npz_path(directory, old), _json_path(directory, old)):
                try:
                    os.remove(p)
                except OSError:
                    pass
    return path


def _all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    # the $ anchor already excludes ckpt_*.npz.tmp orphans from a torn write
    return sorted(int(m.group(1)) for f in os.listdir(directory)
                  if (m := re.match(r"ckpt_(\d+)\.npz$", f)))


def verify_checkpoint(directory: str, step: int) -> bool:
    """Cheap integrity check: archive bytes hash to the sidecar's CRC-32 and
    match its length. Sidecar-less archives (pre-hardening writers) pass if
    the zip at least parses."""
    path = _npz_path(directory, step)
    if not os.path.isfile(path):
        return False
    jpath = _json_path(directory, step)
    if os.path.isfile(jpath):
        try:
            with open(jpath) as f:
                meta = json.load(f)
        except (json.JSONDecodeError, OSError):
            return False
        if "checksum" in meta:
            try:
                with open(path, "rb") as f:
                    data = f.read()
            except OSError:
                return False
            return (len(data) == meta.get("nbytes", len(data))
                    and zlib.crc32(data) == meta["checksum"])
    try:
        np.load(path).close()
        return True
    except Exception:
        return False


def latest_step(directory: str) -> Optional[int]:
    """Newest step whose checkpoint verifies — torn or corrupt archives are
    skipped so auto-resume falls back to the last durable one."""
    for step in reversed(_all_steps(directory)):
        if verify_checkpoint(directory, step):
            return step
    return None


def load_checkpoint_metadata(directory: str, step: int) -> Optional[dict]:
    jpath = _json_path(directory, step)
    if not os.path.isfile(jpath):
        return None
    try:
        with open(jpath) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None


def restore_checkpoint(directory: str, template: Any, step: Optional[int] = None):
    """Restore into the structure of ``template`` (shape/dtype enforced).
    Raises ``CheckpointError`` on corruption and ``ValueError`` naming the
    leaf path when the archive no longer matches the template."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = _npz_path(directory, step)
    if not os.path.isfile(path):
        raise FileNotFoundError(path)
    if not verify_checkpoint(directory, step):
        raise CheckpointError(f"checkpoint {path} failed integrity check "
                              "(torn write or bit rot)")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(_seg(x) for x in p)
        if key not in data:
            raise ValueError(f"checkpoint {path} is missing leaf '{key}' "
                             f"required by the template")
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf '{key}' has shape {tuple(arr.shape)} but "
                f"the template expects {tuple(leaf.shape)} ({path})")
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
