from repro.checkpoint.checkpoint import (CheckpointError,
                                         load_checkpoint_metadata,
                                         latest_step, population_chain_ok,
                                         restore_checkpoint,
                                         restore_population, save_checkpoint,
                                         save_population, verified_steps,
                                         verify_checkpoint,
                                         verify_population)
