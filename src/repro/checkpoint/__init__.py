from repro.checkpoint.checkpoint import (CheckpointError,
                                         load_checkpoint_metadata,
                                         latest_step, restore_checkpoint,
                                         save_checkpoint, verify_checkpoint)
