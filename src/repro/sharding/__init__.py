from repro.sharding.rules import make_rules, batch_axes, logical_spec
