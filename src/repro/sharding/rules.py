"""Logical-axis sharding rules.

One rules dict per (architecture, mesh, step-kind). Axes are only assigned
when the dimension size divides the mesh-axis size — GSPMD requires even
shards for jit in/out shardings, and per-arch head counts differ (e.g. the
40-head archs cannot shard heads over a 16-way model axis; they fall back to
replicated heads + sharded d_ff/vocab, see DESIGN.md §4).

Logical dim vocabulary (used by every ParamSpec in repro.models):

  batch        activation batch            -> ("pod","data") / ("data",)
  seq          activation sequence         -> None (context-parallel = hillclimb)
  kv_seq       KV-cache sequence           -> "model" when heads don't shard
  embed        d_model                     -> "data" (FSDP)
  heads        query heads                 -> "model" if divisible
  kv_heads     KV heads (GQA)              -> "model" if divisible
  head_dim                                  -> None
  ffn          MLP hidden                  -> "model"
  vocab        vocabulary                  -> "model"
  experts      MoE expert dim              -> "data" if divisible (EP), else None
  d_inner      SSM inner dim               -> "model"
  ssm_state / conv / codebooks / layers    -> None
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.config import MeshConfig, ModelConfig

# ---------------------------------------------------------------------------
# Activation-sharding context: model code calls shard_act(x, dims) at block
# boundaries; under an active context this pins activations (e.g. batch ->
# "data"), which is what forces GSPMD to all-gather FSDP-sharded weights
# instead of replicating activations (ZeRO-3 semantics). Outside the context
# (unit tests, single-device runs) it is a no-op.
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, rules: dict):
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules)
    try:
        yield
    finally:
        _CTX.val = prev


def context_axis_size(axis: str) -> int:
    """Size of a mesh axis under the active activation-sharding context
    (1 outside any context — single-device tests degrade gracefully)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return 1
    mesh, _ = ctx
    return int(mesh.shape.get(axis, 1))


def _manual_axes() -> set:
    """Mesh axes currently under manual (shard_map) control — constraints
    inside the region must not mention them."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            return set()
        return {n for n, t in zip(am.axis_names, am.axis_types)
                if "Manual" in str(t)}
    except Exception:
        return set()


def shard_act(x, dims):
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_spec(dims, rules)
    manual = _manual_axes()
    # drop manual axes and axes that don't divide the dim (GSPMD needs even shards)
    axes = []
    for size, ax in zip(x.shape, spec):
        if ax is not None:
            ax_t = tuple(a for a in ((ax,) if isinstance(ax, str) else ax)
                         if a not in manual)
            n = 1
            for a in ax_t:
                n *= mesh.shape[a]
            if not ax_t or size % n or size == 0:
                ax = None
            else:
                ax = ax_t[0] if len(ax_t) == 1 else ax_t
        axes.append(ax)
    if manual:
        # inside shard_map: raw PartitionSpec resolves on the ambient mesh
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, PartitionSpec(*axes)))


def _axis_if(divides: int, size: int, axis):
    return axis if size > 0 and divides > 0 and divides % size == 0 else None


# ---------------------------------------------------------------------------
# Client (federation) axis: the sharded engine runs the scanned round body
# under shard_map over a mesh axis holding disjoint client shards. The spec
# derivation lives here so the engine and the LM-scale pod path agree on how
# client-stacked pytrees map onto a mesh.
# ---------------------------------------------------------------------------

CLIENT_AXIS = "clients"


def client_specs(tree, stacked: int, axis: str = CLIENT_AXIS):
    """PartitionSpec tree for a client-stacked pytree: leaves whose leading
    dim equals ``stacked`` shard over ``axis``; everything else replicates.

    ``stacked`` is the (padded) client count — an exact-size match, not a
    divisibility heuristic, so a replicated (D,) leaf with D == stacked is
    the only ambiguity; strategies whose carry is server-style (no client
    axis at all, e.g. FedAvg's global model) override
    ``Strategy.state_client_stacked`` to force full replication instead of
    relying on this shape test."""
    def spec(leaf):
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == stacked:
            return PartitionSpec(axis)
        return PartitionSpec()
    return jax.tree_util.tree_map(
        spec, tree, is_leaf=lambda x: hasattr(x, "shape"))


def shard_map_compat(f, mesh, in_specs, out_specs, *, manual_axes=None):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., axis_names=..., check_vma=...)``;
    0.4.x has ``jax.experimental.shard_map.shard_map(..., auto=...,
    check_rep=...)`` where partial-manual regions are expressed as the
    complement (``auto`` = axes NOT under manual control). ``manual_axes``
    None means fully manual over every mesh axis."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {"check_vma": False}
        if manual_axes is not None:
            kw["axis_names"] = set(manual_axes)
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm_exp
    kw = {"check_rep": False}
    if manual_axes is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def batch_axes(mesh_cfg: MeshConfig):
    """Mesh axes that shard the global batch (pod joins data in multi-pod)."""
    return ("pod", "data") if mesh_cfg.multi_pod else ("data",)


def make_rules(model: ModelConfig, mesh: MeshConfig, *, kind: str = "train",
               fsdp: bool = True) -> dict:
    dsz, msz = mesh.data, mesh.model
    hd = model.resolved_head_dim
    n_q = max(model.pad_attn_heads_to, model.num_heads)
    heads_ax = _axis_if(n_q, msz, "model")
    kv_heads_ax = _axis_if(model.num_kv_heads, msz, "model")
    # GQA: logits einsum needs q- and kv-heads co-sharded; if kv heads don't
    # divide, shard q heads only (kv replicated is cheap for small kv counts).
    rules = {
        "batch": batch_axes(mesh),
        "seq": None,
        # decode against a long cache: if heads can't shard, shard the cache
        # sequence dim over "model" so the (1-token q · full K) contraction is
        # distributed (flash-decoding style partial-softmax, handled by XLA).
        "kv_seq": ("model" if (kind == "decode" and kv_heads_ax is None) else None),
        "embed": _axis_if(model.d_model, dsz, "data") if fsdp else None,
        "embed_act": None,          # activations' embed dim stays unsharded
        "heads": heads_ax,
        "kv_heads": kv_heads_ax,
        "head_dim": None,
        "ffn": _axis_if(model.d_ff, msz, "model"),
        "vocab": _axis_if(model.vocab_size, msz, "model"),
        "vocab_table": _axis_if(model.vocab_size, msz, "model"),
        "experts": _axis_if(model.moe.num_experts, dsz, "data"),
        "experts_router": None,
        "capacity": None,
        "d_inner": _axis_if(model.ssm.expand * model.d_model, msz, "model"),
        "ssm_state": None,
        "ssm_heads": _axis_if(model.ssm.num_heads, msz, "model"),
        "conv": None,
        "codebooks": None,
        "layers": None,
        "units": None,
    }
    return rules


def logical_spec(dims, rules) -> PartitionSpec:
    """Build a PartitionSpec for an *activation* given logical dim names."""
    used, axes = set(), []
    for d in dims:
        ax = rules.get(d) if d is not None else None
        if ax is None:
            axes.append(None)
            continue
        ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
        ax_t = tuple(a for a in ax_t if a not in used)
        if not ax_t:
            axes.append(None)
        else:
            used.update(ax_t)
            axes.append(ax_t[0] if len(ax_t) == 1 else ax_t)
    return PartitionSpec(*axes)
