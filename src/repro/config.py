"""Config system for the framework.

Everything a run needs is described by frozen dataclasses:

  ModelConfig  — architecture (one per assigned arch in ``repro.configs``)
  DPConfig     — differential-privacy knobs (paper Eqs. 10–12)
  P4Config     — the paper's technique: grouping + proxy/private co-training
  MeshConfig   — device mesh (single-pod / multi-pod)
  ScheduleConfig — round schedule (full / sampling / async) + DP accounting
  TopologyConfig — P2P communication graph + mixing weights + link faults
  KernelConfig — Pallas/jnp kernel backend selection + autotuning
  TrainConfig  — optimizer/schedule/steps
  RunConfig    — the composed top-level config consumed by launch scripts

Configs are plain dataclasses (no framework dependency) so they can be
constructed programmatically, overridden from the CLI (``--arch``,
``--shape``, ``key=value`` dotted overrides) and serialized to JSON next to
checkpoints.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Architecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # 0 => dense MLP
    experts_per_token: int = 0      # top-k
    aux_loss_weight: float = 0.01   # router load-balance loss
    shared_expert: bool = False     # llama4-style shared expert alongside routed
    capacity_factor: float = 0.0    # 0 => dense (masked einsum) dispatch
    dispatch: str = "global"        # "local" = per-data-shard dispatch (§Perf)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) / xLSTM settings for ssm and hybrid architectures."""
    state_dim: int = 0              # N (per-head state size); 0 => no SSM
    num_heads: int = 0              # SSD heads (mamba2) / mLSTM heads
    head_dim: int = 0
    conv_width: int = 4             # causal depthwise conv width (mamba2)
    chunk_size: int = 128           # SSD chunked-scan block length
    expand: int = 2                 # d_inner = expand * d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0               # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 8192
    # attention flavour
    qk_norm: bool = False           # qwen3
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) split of head_dim/2
    window: int = 0                 # sliding-window size; 0 => full attention
    swa_every: int = 1              # 1 => all layers windowed when window>0; mixtral=1
    # beyond-paper perf knob: pad query heads up to a mesh-divisible count so
    # attention shards over the model axis (e.g. qwen3/llama4: 40 -> 48).
    # Zero-extra-capacity heads: a strict superset model, HLO-validated in
    # EXPERIMENTS.md §Perf.
    pad_attn_heads_to: int = 0
    attn_logit_softcap: float = 0.0
    # hybrid layout (zamba2): attention block shared & interleaved every k mamba blocks
    hybrid_attn_every: int = 0      # 0 => homogeneous stack
    # xLSTM layout: pattern of block kinds, e.g. ("m","m","s") cycled
    xlstm_pattern: Tuple[str, ...] = ()
    # MoE / SSM subconfigs
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # multimodal stubs (brief carve-out: frontends provide embeddings)
    vision_tokens: int = 0          # qwen2-vl: number of patch embeddings per sample
    audio_codebooks: int = 0        # musicgen: EnCodec codebooks (delay pattern)
    # numerics
    dtype: str = "bfloat16"         # activation/compute dtype
    kv_cache_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    logits_dtype: str = "bfloat16"  # large-vocab logits kept in bf16, sharded
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # remat: "none" | "block" (checkpoint each scanned block) | "full"
    remat: str = "block"
    # --- cost-faithful lowering knobs (dry-run roofline extraction ONLY) ---
    # XLA cost_analysis counts while-loop bodies ONCE; the roofline pass
    # lowers with two unroll factors (1 and u) and extrapolates
    # total = f1 + (L-1)(fu - f1)/(u-1) to recover true per-step cost.
    unroll_layers: int = 1        # outer layer-stack scan unroll factor
    unroll_inner: bool = False    # fully unroll SSD/mLSTM chunk scans
    force_full_attention: bool = False
    # citation for the assigned-architecture table
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads={self.num_heads} not divisible by "
            f"num_kv_heads={self.num_kv_heads}")
        if self.family == "moe" or self.moe.num_experts:
            assert self.moe.experts_per_token >= 1
        if self.family in ("ssm", "hybrid"):
            assert self.ssm.state_dim > 0 or self.xlstm_pattern


# ---------------------------------------------------------------------------
# The paper's technique
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DPConfig:
    """Differential privacy (paper §3.3 Phase 2, Eqs. 10–12)."""
    enabled: bool = True
    epsilon: float = 15.0           # paper's default target budget
    delta: float = 0.0              # 0 => 1/R, paper §4.1
    clip_norm: float = 1.0          # C
    # σ_g: 0 => derive from (ε, δ) via Eq. 12 (Noble et al. with l = M' = 1)
    noise_multiplier: float = 0.0
    sample_rate: float = 1.0        # s — data (batch) subsampling ratio
    local_steps: int = 1            # K — local steps between exchanges
    rounds: int = 100               # T — paper fixes T=100 communication rounds
    microbatches: int = 0           # 0 => exact per-example (vmap); k => scan over k
    # 0 => one vmap over the whole batch (B× parameter memory); c => scan over
    # B/c chunks of c vmapped examples — same per-example semantics, c× memory
    per_example_chunk: int = 0
    noise_router: bool = True       # MoE ablation knob (see DESIGN §4)


@dataclass(frozen=True)
class P4Config:
    """The paper's contribution as a first-class framework feature."""
    enabled: bool = True
    # Phase 1 — grouping
    group_size: int = 8             # T in Eq. 5 (paper: 8, CIFAR-100: 4)
    sample_peers: int = 35          # H — peers sampled for similarity (paper §4.5)
    similarity: str = "l1"          # paper metric (Eq. 3); "random" => ablation
    # Phase 2 — co-training
    alpha: float = 0.5              # Eq. 8 proxy   = (1-a) CE + a KL(w ‖ θ)
    beta: float = 0.5               # Eq. 9 private = (1-b) CE + b KL(θ ‖ w)
    distill_temperature: float = 1.0
    proxy_width_mult: float = 1.0   # <1 => width-reduced proxy (LM scale, DESIGN §4)
    aggregator_rotation: int = 1    # rounds between rotating the group aggregator
    handcrafted_features: bool = True  # ScatterNet frontend (ablation knob)
    manual_pod: bool = False        # shard_map the pod axis (XLA-version gated)


@dataclass(frozen=True)
class TopologyConfig:
    """Communication graph for the P2P layer (``repro.topology``).

    ``family="none"`` keeps each strategy's built-in pattern (DP-DSGT's
    ring, P4's group-internal mean). Any other family builds an explicit
    graph + doubly-stochastic mixing matrix: DP-DSGT gossips over it, P4
    routes its group messages along its shortest paths (per-link byte/hop
    accounting) and — with fault rates — drops member↔aggregator exchanges.
    """
    family: str = "none"    # none | ring | full | torus | kregular |
                            # exponential | erdos | smallworld | group | gossip
    k: int = 4              # degree (kregular / smallworld base lattice)
    p: float = 0.3          # erdos edge prob / smallworld rewire prob
    self_weight: float = 0.5   # lazy self weight for uniform weighting
    weighting: str = "metropolis"  # metropolis | uniform (regular graphs)
    drop_prob: float = 0.0  # per-round undirected-link failure probability
    churn_prob: float = 0.0  # per-round node-offline probability
    period: int = 8         # gossip family: matchings per cycle
    bridge: bool = True     # group family: ring bridge between groups
    seed: int = 0           # random-family construction seed
    # learned graphs (repro.topology.learned.GraphLearner): re-estimate the
    # collaboration graph from private pairwise model similarity every
    # learn_every rounds (0 = static graph), keeping learn_k out-neighbors
    # per client; each release adds Gaussian noise at learn_sigma × clip to
    # the measured distances and is charged to the PrivacyLedger
    learn_every: int = 0    # rounds between re-estimations (0 = off)
    learn_k: int = 0        # out-degree kept per client (0 => use k)
    learn_window: int = 1   # estimates folded as a TimeVaryingTopology
    learn_sigma: float = 1.0   # DP noise multiplier on released distances
    learn_temperature: float = 1.0  # similarity→trust softmax temperature


@dataclass(frozen=True)
class ScheduleConfig:
    """Round schedule + engine-native privacy accounting
    (``repro.engine.schedule`` / ``repro.engine.accounting``)."""
    kind: str = "full"              # full | sampling | async
    client_rate: float = 1.0        # q — per-round client participation
    mode: str = "bernoulli"         # sampling: bernoulli | fixed cohort
    staleness: int = 0              # async: rounds between buffered merges
    staleness_pow: float = 0.5      # async: merge weight (1+s)^-pow (FedBuff)
    accountant: str = "rdp"         # rdp | none — (ε, δ) ledger into History


@dataclass(frozen=True)
class FaultConfig:
    """Correlated fault processes (``repro.resilience``).

    Unlike ``TopologyConfig.drop_prob``/``churn_prob`` (i.i.d. per round),
    these are Markov transition rates: links fail in bursts (Gilbert–Elliott),
    nodes dwell in outages, partitions cut a sampled bisection for a random
    stretch of rounds, and stragglers stay slow until they recover. All rates
    zero = disabled (the engine's fault-free trace is untouched). When a
    process is enabled it supersedes the topology's i.i.d. rates.
    """
    link_fail: float = 0.0        # per-edge good→bad rate (bursty links)
    link_repair: float = 1.0      # bad→good; mean burst = 1/link_repair
    node_fail: float = 0.0        # node outage rate
    node_repair: float = 1.0      # mean outage = 1/node_repair rounds
    partition_prob: float = 0.0   # chance a bisection partition starts
    partition_repair: float = 0.5  # chance an active partition heals
    slow_enter: float = 0.0       # straggler chain: fast→slow
    slow_exit: float = 1.0        # slow→fast
    quorum: float = 0.0           # P4: min up-fraction for group aggregation


@dataclass(frozen=True)
class KernelConfig:
    """Kernel backend selection + autotuning (repro.kernels.dispatch).

    Replaces the old bare ``use_pallas: bool``: backend choice is a policy
    (compiled Pallas on TPU, jnp reference on CPU, interpreter only for
    explicit debugging), and tile sizes are autotuned per (shape, dtype,
    backend) rather than hardcoded.
    """
    backend: str = "auto"           # auto | pallas | interpret | ref
    autotune: bool = True           # tile-size search on first (shape, dtype)
    autotune_trials: int = 2        # timed repetitions per candidate
    # explicit tile overrides; (0, 0) => autotune (or kernel defaults)
    dp_clip_tile: Tuple[int, int] = (0, 0)    # (tb, td)
    l1_tile: Tuple[int, int] = (0, 0)         # (tm, td)
    dp_round_tile: int = 0                    # tf; 0 => autotune/default
    mix_halo_tile: int = 0                    # halo-mix row block; 0 => auto
                                              # (untiled unless tuned better)


# ---------------------------------------------------------------------------
# Distribution / run
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    pods: int = 2
    data: int = 16
    model: int = 16
    # client-mesh size for the sharded federation engine
    # (repro.engine.ShardedEngine over launch.mesh.make_client_mesh):
    # 0 = single-device engine; N = shard the (M, ...) client stacks over a
    # 1-D "clients" axis of min(N, available devices)
    clients: int = 0

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self.pods, self.data, self.model) if self.multi_pod else (self.data, self.model)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = self.data * self.model
        return n * self.pods if self.multi_pod else n


@dataclass(frozen=True)
class InputShape:
    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    kind: str = "train"             # train | prefill | decode


# The four assigned input shapes (brief).
INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"        # constant | linear | cosine
    grad_accum: int = 1
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    dp: DPConfig = field(default_factory=DPConfig)
    p4: P4Config = field(default_factory=P4Config)
    kernels: KernelConfig = field(default_factory=KernelConfig)
    schedule: ScheduleConfig = field(default_factory=ScheduleConfig)
    topology: TopologyConfig = field(default_factory=TopologyConfig)
    faults: FaultConfig = field(default_factory=FaultConfig)


# ---------------------------------------------------------------------------
# (De)serialization + CLI overrides
# ---------------------------------------------------------------------------

def to_dict(cfg: Any) -> Any:
    if dataclasses.is_dataclass(cfg):
        return {f.name: to_dict(getattr(cfg, f.name)) for f in dataclasses.fields(cfg)}
    if isinstance(cfg, (list, tuple)):
        return [to_dict(x) for x in cfg]
    return cfg


def to_json(cfg: Any) -> str:
    return json.dumps(to_dict(cfg), indent=2)


def apply_overrides(cfg, overrides: dict):
    """Apply dotted-path overrides, e.g. {"dp.epsilon": 3.0, "model.window": 8192}."""
    for path, value in overrides.items():
        parts = path.split(".")
        cfg = _set_path(cfg, parts, value)
    return cfg


def _set_path(cfg, parts, value):
    if len(parts) == 1:
        f = {f.name: f for f in dataclasses.fields(cfg)}[parts[0]]
        typ = f.type if isinstance(f.type, type) else None
        cur = getattr(cfg, parts[0])
        if isinstance(cur, bool):
            value = value in (True, "true", "True", "1", 1)
        elif isinstance(cur, int) and not isinstance(value, bool):
            value = int(value)
        elif isinstance(cur, float):
            value = float(value)
        return dataclasses.replace(cfg, **{parts[0]: value})
    child = getattr(cfg, parts[0])
    return dataclasses.replace(cfg, **{parts[0]: _set_path(child, parts[1:], value)})
