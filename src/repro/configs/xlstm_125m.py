"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

Pattern: units of (5 mLSTM + 1 sLSTM), 2 units = 12 layers — the paper's
mostly-mLSTM [7:1]-style mix in a scan-friendly layout.
"""
from repro.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,                           # xLSTM blocks carry their own 4x FFN
        vocab_size=50304,
        max_seq_len=524288,
        xlstm_pattern=("m", "m", "m", "m", "m", "s"),
        ssm=SSMConfig(state_dim=192, num_heads=4, head_dim=192, chunk_size=256),
        source="arXiv:2405.04517",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-reduced",
        family="ssm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        max_seq_len=512,
        xlstm_pattern=("m", "s"),
        ssm=SSMConfig(state_dim=32, num_heads=4, head_dim=32, chunk_size=32),
        remat="none",
        source="arXiv:2405.04517",
    )
