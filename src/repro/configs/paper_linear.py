"""The paper's own evaluation model #1 (§4.1): one linear layer + softmax on
ScatterNet features. Not an LM — consumed by repro.core.P4Trainer and the
benchmark suite rather than the decoder stack."""
from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
from repro.core.scattering import scatter_feature_dim

DATASET_SHAPES = {"femnist": (28, 28, 1), "cifar10": (32, 32, 3),
                  "cifar100": (32, 32, 3)}
NUM_CLASSES = {"femnist": 47, "cifar10": 10, "cifar100": 100}


def config(dataset: str = "cifar10") -> dict:
    return {
        "model": "linear",
        "feat_dim": scatter_feature_dim(DATASET_SHAPES[dataset]),
        "num_classes": NUM_CLASSES[dataset],
        "run": RunConfig(
            dp=DPConfig(epsilon=15.0, rounds=100, clip_norm=1.0),
            # paper §4.3: |g| = 4 for CIFAR-100, 8 otherwise; H = 35 peers
            p4=P4Config(group_size=4 if dataset == "cifar100" else 8,
                        sample_peers=35),
            train=TrainConfig(optimizer="sgd", learning_rate=0.5),
        ),
    }
