"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
early fusion [hf:meta-llama/Llama-4-Scout-17B-16E family].

bf16 params + bf16 optimizer moments: 400B × 16 B/param of fp32 state would
not fit a 256-chip v5e pod (DESIGN.md §6)."""
from repro.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        max_seq_len=524288,
        rope_theta=500_000.0,
        moe=MoEConfig(num_experts=128, experts_per_token=1, aux_loss_weight=0.01,
                      shared_expert=True, capacity_factor=1.25),
        param_dtype="bfloat16",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b-reduced",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        max_seq_len=512,
        moe=MoEConfig(num_experts=4, experts_per_token=1, shared_expert=True,
                      capacity_factor=1.25),
        remat="none",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
