"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B]."""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        max_seq_len=524288,
        rope_theta=500_000.0,
        tie_embeddings=True,
        source="hf:meta-llama/Llama-3.2-1B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-reduced",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        tie_embeddings=True,
        remat="none",
        source="hf:meta-llama/Llama-3.2-1B",
    )
