"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

Layout adaptation (DESIGN.md): 81 Mamba2 layers grouped into 27 units of 3,
ONE shared attention block (shared weights, per-unit KV cache) applied at the
start of every unit — zamba2's "shared transformer block re-applied along the
depth", in a scan-friendly homogeneous layout.
"""
from repro.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,                    # mamba2 layers
        hybrid_attn_every=3,              # => 27 units × (shared attn + 3 mamba)
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        max_seq_len=524288,
        ssm=SSMConfig(state_dim=64, num_heads=112, head_dim=64, expand=2,
                      conv_width=4, chunk_size=128),
        source="arXiv:2411.15242",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-reduced",
        family="hybrid",
        num_layers=2,
        hybrid_attn_every=1,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        ssm=SSMConfig(state_dim=16, num_heads=4, head_dim=64, expand=2,
                      conv_width=4, chunk_size=32),
        remat="none",
        source="arXiv:2411.15242",
    )
