"""Architecture registry.

Every assigned architecture is a selectable config (``--arch <id>``); each
cites its source in ``ModelConfig.source``. ``reduced()`` returns the smoke-
test variant (≤2 layers, d_model ≤ 512, ≤4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict

from repro.config import ModelConfig

_MODULES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "zamba2-7b": "zamba2_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen3-14b": "qwen3_14b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "granite-34b": "granite_34b",
    "llama3.2-1b": "llama3_2_1b",
    "xlstm-125m": "xlstm_125m",
    "musicgen-large": "musicgen_large",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
}

ARCHITECTURES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.config()
    cfg.validate()
    return cfg


def get_reduced_config(name: str) -> ModelConfig:
    """Smoke-test variant: same family/features, tiny dimensions."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.reduced()
    cfg.validate()
    return cfg
