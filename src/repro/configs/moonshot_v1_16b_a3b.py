"""moonshot-v1-16b-a3b [dense/moe] — kimi/moonlight 16B-A3B: 64 experts
top-6 + shared expert [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,                        # per-expert intermediate
        vocab_size=163840,
        max_seq_len=524288,
        moe=MoEConfig(num_experts=64, experts_per_token=6, aux_loss_weight=0.01,
                      shared_expert=True, capacity_factor=1.25),
        source="hf:moonshotai/Moonlight-16B-A3B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-reduced",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        max_seq_len=512,
        moe=MoEConfig(num_experts=4, experts_per_token=2, shared_expert=True,
                      capacity_factor=1.25),
        remat="none",
        source="hf:moonshotai/Moonlight-16B-A3B",
    )
