"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        max_seq_len=524288,
        window=4096,                      # SWA — makes long_500k legal
        rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=8, experts_per_token=2, aux_loss_weight=0.01,
                      capacity_factor=1.25),
        source="arXiv:2401.04088",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-reduced",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        window=64,
        moe=MoEConfig(num_experts=4, experts_per_token=2, capacity_factor=1.25),
        remat="none",
        source="arXiv:2401.04088",
    )
