"""The paper's own evaluation model #2 (§4.1): the Tramèr–Boneh CNN [47] on
ScatterNet features (Fig. 4 uses it on CIFAR-10)."""
from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
from repro.configs.paper_linear import DATASET_SHAPES, NUM_CLASSES


def config(dataset: str = "cifar10") -> dict:
    H, W, C = DATASET_SHAPES[dataset]
    return {
        "model": "cnn",
        # CNN consumes the (C*81, H/4, W/4) scattering stack as an image
        "cnn_shape": (C * 81, H // 4, W // 4),
        "num_classes": NUM_CLASSES[dataset],
        "run": RunConfig(
            dp=DPConfig(epsilon=15.0, rounds=100, clip_norm=1.0),
            p4=P4Config(group_size=8, sample_peers=35),
            train=TrainConfig(optimizer="sgd", learning_rate=0.3),
        ),
    }
