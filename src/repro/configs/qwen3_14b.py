"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        max_seq_len=524288,
        qk_norm=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-8B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-reduced",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        qk_norm=True,
        remat="none",
        source="hf:Qwen/Qwen3-8B",
    )
