"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Vision frontend (ViT + merger) is a stub per the brief; the config describes
the 72B language decoder that consumes patch embeddings.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        max_seq_len=524288,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),      # t/h/w split of head_dim/2 = 64
        vision_tokens=1024,               # stubbed ViT patch embeddings
        source="arXiv:2409.12191",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-reduced",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        mrope_sections=(2, 3, 3),         # head_dim/2 = 8
        vision_tokens=16,
        remat="none",
        source="arXiv:2409.12191",
    )
