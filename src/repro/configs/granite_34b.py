"""granite-34b [dense] — llama-arch code model, MQA (kv=1) [arXiv:2405.04324]."""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,                   # multi-query attention
        d_ff=24576,
        vocab_size=49152,
        max_seq_len=524288,
        source="arXiv:2405.04324",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-reduced",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        remat="none",
        source="arXiv:2405.04324",
    )
