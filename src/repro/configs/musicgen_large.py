"""musicgen-large [audio] — decoder-only over EnCodec tokens, 4 codebooks
with delay pattern [arXiv:2306.05284]. EnCodec itself is a stub per the brief;
``input_specs`` provides frame embeddings."""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        max_seq_len=524288,
        audio_codebooks=4,
        source="arXiv:2306.05284",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-reduced",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=256,
        max_seq_len=512,
        audio_codebooks=4,
        remat="none",
        source="arXiv:2306.05284",
    )
