"""Pure-JAX optimizers over pytrees (no optax dependency).

Moments are stored in the parameter dtype by default so bf16-parameter
configs (llama4-maverick) keep the optimizer-state HBM budget at 8 B/param
(see DESIGN.md §6 memory table).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.optim.schedules import make_schedule


@dataclass(frozen=True)
class Optimizer:
    init: Callable            # params -> state
    update: Callable          # (grads, state, params) -> (new_params, new_state)
    name: str = "adamw"


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    schedule = make_schedule(cfg)

    if cfg.optimizer in ("adam", "adamw"):
        wd = cfg.weight_decay if cfg.optimizer == "adamw" else 0.0
        b1, b2, eps = cfg.beta1, cfg.beta2, 1e-8

        def init(params):
            zeros = lambda p: jnp.zeros_like(p)
            return {"m": jax.tree_util.tree_map(zeros, params),
                    "v": jax.tree_util.tree_map(zeros, params),
                    "count": jnp.zeros((), jnp.int32)}

        def update(grads, state, params):
            count = state["count"] + 1
            lr = schedule(count)
            c1 = 1.0 - b1 ** count.astype(jnp.float32)
            c2 = 1.0 - b2 ** count.astype(jnp.float32)

            def upd(g, m, v, p):
                g32 = g.astype(jnp.float32)
                m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
                v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
                step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
                if wd:
                    step = step + wd * p.astype(jnp.float32)
                p_new = p.astype(jnp.float32) - lr * step
                return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

            out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
            new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                                is_leaf=lambda x: isinstance(x, tuple))
            new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                           is_leaf=lambda x: isinstance(x, tuple))
            new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                           is_leaf=lambda x: isinstance(x, tuple))
            return new_params, {"m": new_m, "v": new_v, "count": count}

        return Optimizer(init, update, cfg.optimizer)

    if cfg.optimizer in ("sgd", "momentum"):
        mu = 0.9 if cfg.optimizer == "momentum" else 0.0

        def init(params):
            state = {"count": jnp.zeros((), jnp.int32)}
            if mu:
                state["m"] = jax.tree_util.tree_map(jnp.zeros_like, params)
            return state

        def update(grads, state, params):
            count = state["count"] + 1
            lr = schedule(count)
            if mu:
                new_m = jax.tree_util.tree_map(
                    lambda m, g: mu * m + g.astype(m.dtype), state["m"], grads)
                new_params = jax.tree_util.tree_map(
                    lambda p, m: (p.astype(jnp.float32) - lr * m.astype(jnp.float32)).astype(p.dtype),
                    params, new_m)
                return new_params, {"m": new_m, "count": count}
            new_params = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, {"count": count}

        return Optimizer(init, update, cfg.optimizer)

    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
