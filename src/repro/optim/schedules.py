"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def make_schedule(cfg: TrainConfig):
    base = cfg.learning_rate
    warm = max(cfg.warmup_steps, 1)
    total = max(cfg.total_steps, warm + 1)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warmup = base * jnp.minimum(step / warm, 1.0)
        frac = jnp.clip((step - warm) / (total - warm), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif cfg.schedule == "linear":
            decay = 1.0 - frac
        else:
            decay = 1.0
        return jnp.where(step < warm, warmup, base * decay)

    return schedule
