from repro.optim.optimizers import make_optimizer, Optimizer
from repro.optim.schedules import make_schedule
