"""Topology subsystem: communication graphs, mixing matrices, and
fault-injected gossip for the P2P layer (README §Topologies).

``graphs`` builds the graph families (ring / torus / k-regular expander /
exponential / Erdős–Rényi / small-world / group-clustered / randomized
gossip sequences), ``mixing`` constructs doubly-stochastic mixing matrices
(Metropolis–Hastings or the lazy uniform rule) and compiles them into the
in-jit sparse mixing step every P2P strategy shares, ``faults`` draws
per-round link-drop / node-churn realizations inside the scanned round
body, and ``accounting`` extends ``core.p2p.P2PNetwork`` with per-link
byte/hop ledgers and shortest-path relay routing. ``learned`` learns the
graph jointly with the models (private periodic re-estimation from
pairwise model similarity), whose directed column-stochastic weights mix
via the push-sum path in ``mixing``.
"""
from repro.topology.accounting import (log_gossip_round, per_link_summary,
                                       route, send_routed, shortest_hops)
from repro.topology.faults import (FAULT_STREAM, draw_fault_masks, fault_key,
                                   host_fault_masks)
from repro.topology.graphs import (TimeVaryingTopology, Topology,
                                   erdos_renyi, exponential, fully_connected,
                                   gossip_matchings, group_clustered,
                                   k_regular, make_topology, ring,
                                   small_world, torus)
from repro.topology.learned import (GraphLearner, make_learner,
                                    run_learned_dsgt, sparsify_similarity)
from repro.topology.mixing import (MixPlan, edges_shard_resident,
                                   is_column_stochastic, is_connected,
                                   is_doubly_stochastic, make_plan,
                                   metropolis_weights, mix_stacked,
                                   mix_stacked_sharded, push_sum_debias,
                                   push_sum_mix, push_sum_mix_paged,
                                   push_sum_mix_sharded, push_sum_weights,
                                   spectral_gap, uniform_weights)
