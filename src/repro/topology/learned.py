"""Learned, time-varying collaboration graphs (Dada / MAPL direction).

Instead of fixing the communication graph up front, the federation
periodically re-estimates it from how the models themselves have diverged
(Zantedeschi et al. 2020 alternate model steps with sparsity-controlled
graph updates; MAPL 2024 learns personalized weighted graphs that beat any
static topology):

  1. every re-estimation releases the clients' flattened weights once more:
     pairwise ℓ1 discrepancies go through the same triangular dispatch
     kernel Phase-1 grouping uses (``repro.kernels.dispatch.pairwise_l1``),
     plus calibrated symmetric Gaussian noise on the released distances;
  2. each client keeps its k most-similar peers (mutual kNN support — if i
     measures j as similar, j may also use i) and splits its trust mass
     over them with a temperature-scaled softmax of −distance; the
     resulting row-stochastic trust matrix transposes into the
     column-stochastic W push-sum mixing consumes;
  3. if the learned support is disconnected, ring edges are unioned into
     every candidate set and the softmax re-runs (connectivity-or-fallback
     — push-sum's ratio estimate needs strong connectivity);
  4. each estimate is charged to the ``PrivacyLedger`` as one adaptive
     release at the estimate's own noise multiplier (``sigma_dist <= 0``
     honestly reports ε = ∞), and its measurement traffic is logged on the
     ``P2PNetwork`` so equal-byte-budget comparisons include it.

The learner folds its history in as a standard ``Topology`` /
``TimeVaryingTopology`` (symmetric support + directed weights), so the
compiled-chunk cache, fault masks, halo schedules, and byte accounting all
keep working unchanged — ``Strategy.set_topology`` with the new estimate
bumps the cache token and the adjacency+weights fingerprint keys the chunk.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.topology.graphs import TimeVaryingTopology, Topology
from repro.topology.mixing import is_connected, push_sum_weights


def sparsify_similarity(dist: np.ndarray, k: int, *,
                        temperature: float = 1.0, self_weight: float = 0.5,
                        ensure_connected: bool = True,
                        ) -> Tuple[np.ndarray, bool]:
    """Row-stochastic sparse trust matrix from an (M, M) distance matrix.

    Each node keeps its k nearest peers; candidate sets are symmetrized
    (mutual kNN), so the directed trust graph has symmetric support — which
    makes strong connectivity equivalent to plain connectivity of the
    support, the property push-sum needs. Trust shares are
    softmax(−d / (τ · median(d))) over each row's candidates scaled to
    ``1 − self_weight``, with ``self_weight`` kept on the diagonal.

    Returns ``(trust, fell_back)``; ``fell_back`` is True when the learned
    support was disconnected and ring edges were unioned in.
    """
    d = np.asarray(dist, np.float64)
    M = d.shape[0]
    if M <= 1:
        return np.eye(max(M, 1)), False
    k = max(1, min(int(k), M - 1))
    off = d + np.where(np.eye(M, dtype=bool), np.inf, 0.0)
    order = np.argsort(off, axis=1, kind="stable")
    cand = np.zeros((M, M), bool)
    cand[np.arange(M)[:, None], order[:, :k]] = True
    cand |= cand.T
    np.fill_diagonal(cand, False)
    fell_back = False
    if ensure_connected and not is_connected(cand):
        fell_back = True
        idx = np.arange(M)
        cand[idx, (idx + 1) % M] = True
        cand[idx, (idx - 1) % M] = True
        cand |= cand.T
        np.fill_diagonal(cand, False)
    scale = float(np.median(off[cand])) if cand.any() else 1.0
    scale = max(scale, 1e-12) * max(float(temperature), 1e-6)
    z = np.where(cand, -off / scale, -np.inf)
    z = z - z.max(axis=1, keepdims=True)
    e = np.exp(z)
    p = e / e.sum(axis=1, keepdims=True)
    # exploration floor: a tiny uniform share over each row's candidates.
    # Without it the softmax underflows to exactly 0 for very-far fallback
    # edges, which would silently disconnect the support again — the trust
    # graph must have positive weight on EVERY candidate edge.
    floor = 1e-3
    u = cand / np.maximum(cand.sum(axis=1, keepdims=True), 1)
    p = (1.0 - floor) * p + floor * u
    s = float(np.clip(self_weight, 0.0, 1.0))
    trust = (1.0 - s) * p
    np.fill_diagonal(trust, s)
    return trust, fell_back


@dataclass(eq=False)
class GraphLearner:
    """Private periodic graph re-estimation.

    ``estimate`` turns one (M, D) matrix of DP-protected client weights into
    a fresh directed ``Topology`` (column-stochastic W over a symmetric
    support); ``current`` folds the last ``window`` estimates into the
    evolving graph handed to ``Strategy.set_topology``. The learner keeps
    the full estimate ``history`` and the ``gap_trajectory`` of spectral
    gaps the sweep plots.
    """

    M: int
    k: int = 4
    temperature: float = 1.0
    self_weight: float = 0.5
    sigma_dist: float = 1.0        # noise multiplier on released distances
    clip: float = 1.0              # release sensitivity (the DP clip bound)
    window: int = 1
    seed: int = 0
    kernels: Optional[object] = None
    name: str = "learned"

    def __post_init__(self):
        self.history: List[Topology] = []
        self.gap_trajectory: List[float] = []
        self.fallbacks = 0
        self._rng = np.random.default_rng(self.seed)

    # ------------------------------------------------------------------
    def estimate(self, weights, *, ledger=None, net=None, rnd: int = 0,
                 ) -> Topology:
        """One re-estimation from the (M, D) client weight matrix.

        The pairwise distances are computed with the triangular dispatch
        kernel, perturbed by symmetric Gaussian noise of scale
        ``sigma_dist · clip`` (both endpoints of a measurement see the same
        noisy value), and the release is charged to ``ledger`` as one more
        adaptive query at that noise multiplier. ``net`` (optional
        ``P2PNetwork``) logs the measurement traffic — every client ships
        its flattened weights to its learned neighbors.
        """
        import jax.numpy as jnp

        from repro.core.grouping import pairwise_l1

        w = jnp.asarray(weights)
        if w.ndim != 2 or w.shape[0] != self.M:
            raise ValueError(f"expected (M={self.M}, D) weights, got "
                             f"{tuple(w.shape)}")
        dist = np.asarray(pairwise_l1(w, kernels=self.kernels), np.float64)
        if self.sigma_dist > 0:
            noise = self._rng.normal(size=dist.shape) \
                * self.sigma_dist * self.clip
            noise = np.triu(noise, 1)
            dist = np.maximum(dist + noise + noise.T, 0.0)
            np.fill_diagonal(dist, 0.0)
        if ledger is not None:
            # one extra release of the per-client weights: composed into the
            # run's RDP budget at this release's own noise multiplier;
            # sigma_dist <= 0 honestly drives ε to ∞
            ledger.advance(1, q=1.0, sigma=self.sigma_dist)
        trust, fell_back = sparsify_similarity(
            dist, self.k, temperature=self.temperature,
            self_weight=self.self_weight)
        self.fallbacks += int(fell_back)
        support = (trust > 0) | (trust > 0).T
        np.fill_diagonal(support, False)
        topo = Topology(f"{self.name}{self.M}_t{len(self.history)}",
                        support, push_sum_weights(trust))
        self.history.append(topo)
        self.gap_trajectory.append(topo.spectral_gap())
        if net is not None:
            self._log_estimation(net, int(np.asarray(weights).shape[-1]), rnd)
        return topo

    def current(self, window: Optional[int] = None):
        """The evolving graph for ``Strategy.set_topology``: the last
        ``window`` estimates as a ``TimeVaryingTopology`` (a single static
        ``Topology`` when one estimate suffices)."""
        if not self.history:
            raise ValueError("GraphLearner has no estimates yet; call "
                             "estimate() first")
        w = max(1, int(window if window is not None else self.window))
        topos = self.history[-w:]
        if len(topos) == 1:
            return topos[0]
        return TimeVaryingTopology(
            f"{self.name}{self.M}_w{len(topos)}_t{len(self.history)}",
            list(topos))

    # ------------------------------------------------------------------
    def _log_estimation(self, net, feat_dim: int, rnd: int) -> None:
        """Byte-account the measurement itself: each client ships its (D,)
        flattened DP weights to every learned neighbor, so equal-byte-budget
        sweeps pay for the re-estimation traffic too."""
        payload = np.zeros((feat_dim,), np.float32)
        adj = self.history[-1].adjacency
        for i in range(self.M):
            for j in np.nonzero(adj[i])[0]:
                net.send(int(i), int(j), payload, kind="graph_estimate",
                         rnd=rnd)


def make_learner(cfg, M: int, kernels=None, clip: float = 1.0,
                 ) -> GraphLearner:
    """GraphLearner from a ``TopologyConfig``'s learn_* knobs."""
    return GraphLearner(M=M, k=int(cfg.learn_k) or int(cfg.k),
                        temperature=float(cfg.learn_temperature),
                        self_weight=float(cfg.self_weight),
                        sigma_dist=float(cfg.learn_sigma), clip=clip,
                        window=int(cfg.learn_window), seed=int(cfg.seed),
                        kernels=kernels)


def run_learned_dsgt(data, *, rounds: int, interval: int, k: int = 4,
                     lr: float = 0.3, clip: float = 1.0, sigma: float = 0.0,
                     sigma_dist: float = 1.0, window: int = 1,
                     temperature: float = 1.0, self_weight: float = 0.5,
                     batch: int = 16, seed: int = 0, network=None,
                     ledger=None, mesh=None, eval_every: Optional[int] = None,
                     kernels=None, num_classes: Optional[int] = None):
    """DP-DSGT with a periodically re-learned push-sum graph.

    Segment 0 runs on the default ring; every ``interval`` rounds the
    learner re-estimates the graph from the current (already DP-noised)
    client models, the strategy's topology is swapped (cache-correct: the
    estimate's fingerprint keys the compiled chunk) and the state is
    aligned across the symmetric↔push-sum boundary. Training continues via
    ``Engine.fit(start_round=, state=)`` — the resume path — so per-round
    keys, fault replay, and ledger advancement stay consistent with an
    uninterrupted run.

    Returns ``(state, record)``; the record carries the stitched accuracy
    history, the spectral-gap trajectory, and the estimate count.
    """
    import jax

    from repro.baselines.dp_dsgt import DPDSGTStrategy
    from repro.engine import Engine
    from repro.engine.sharded import ShardedEngine

    M = data.num_clients
    feat = int(data.train_x.shape[-1])
    classes = (int(num_classes) if num_classes is not None
               else int(np.asarray(data.train_y).max()) + 1)
    strategy = DPDSGTStrategy(feat_dim=feat, num_classes=classes, lr=lr,
                              clip=clip, sigma=sigma)
    learner = GraphLearner(M=M, k=k, temperature=temperature,
                           self_weight=self_weight, sigma_dist=sigma_dist,
                           clip=clip, window=window, seed=seed,
                           kernels=kernels)
    ev = int(eval_every if eval_every is not None else interval)
    if mesh is not None:
        engine = ShardedEngine(strategy, eval_every=ev, network=network,
                               ledger=ledger, mesh=mesh)
    else:
        engine = Engine(strategy, eval_every=ev, network=network,
                        ledger=ledger)
    key = jax.random.PRNGKey(seed)

    history_pairs: List[Tuple[int, float]] = []
    state = None
    r0 = 0
    while r0 < rounds:
        r1 = min(r0 + int(interval), rounds)
        state, hist = engine.fit(data, rounds=r1, key=key, batch_size=batch,
                                 start_round=r0, state=state)
        history_pairs.extend(hist.as_tuples())
        r0 = r1
        if r0 >= rounds:
            break
        from repro.core.grouping import flatten_clients
        flat = np.asarray(flatten_clients(state["x"]))
        learner.estimate(flat, ledger=ledger, net=network, rnd=r0 - 1)
        strategy.set_topology(learner.current(), kernels=kernels)
        state = strategy.align_push_sum_state(state)

    record = {
        "accuracy": history_pairs[-1][1] if history_pairs else None,
        "history": history_pairs,
        "gap_trajectory": [round(g, 6) for g in learner.gap_trajectory],
        "estimates": len(learner.history),
        "fallbacks": learner.fallbacks,
        "final_topology": (learner.history[-1].describe()
                          if learner.history else None),
    }
    return state, record
