"""Mixing matrices and the in-jit gossip step.

Two layers:

  * host-side construction — Metropolis–Hastings weights (doubly stochastic
    on ANY symmetric graph), the lazy uniform rule for regular graphs (the
    DP-DSGT ring's historical 1/2–1/4–1/4 row), spectral-gap reporting, and
    connectivity checks;

  * the traced mixing step — ``make_plan`` compiles a topology into a
    ``MixPlan`` (padded neighbor-index/weight arrays plus special-case
    flags) and ``mix_stacked`` applies one gossip round to a stacked
    (M, ...) pytree inside the engine's scanned round body. The plan keeps
    three executions of the same arithmetic:

      - uniform fast path: ``s·t + w·Σ_k t[nbr_k]`` with scalar s, w —
        for the ring this is bit-identical to the pre-refactor
        ``_ring_mix`` expression ``0.5·t + 0.25·(left + right)``;
      - general path: per-row self weights + per-slot neighbor weights
        (Metropolis rows, matchings, fault-adjusted rows);
      - sharded paths (``mix_stacked_sharded``): ppermute halo exchange
        when the topology is the shard-aligned ring, slice-local gathers
        when every edge is shard-resident, and the gather→mix→re-shard
        fallback (exact for any graph) otherwise.

    Link faults are drawn in-jit per round (``repro.topology.faults``) and
    folded into the row weights with the dropped mass moved to the diagonal,
    so every realized matrix stays doubly stochastic — gossip under faults
    still preserves the global mean.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Host-side: weight construction + graph diagnostics (numpy only — graphs.py
# imports these at module load, before jax is necessarily initialized)
# ---------------------------------------------------------------------------


def metropolis_weights(adjacency: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings: W_ij = 1 / (1 + max(d_i, d_j)) on edges,
    diagonal absorbs the remainder. Symmetric + doubly stochastic on any
    symmetric graph (Xiao & Boyd 2004)."""
    adj = np.asarray(adjacency, bool)
    deg = adj.sum(axis=1)
    denom = 1.0 + np.maximum(deg[:, None], deg[None, :])
    w = np.where(adj, 1.0 / denom, 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def uniform_weights(adjacency: np.ndarray, self_weight: float = 0.5, *,
                    allow_irregular: bool = False) -> np.ndarray:
    """Lazy uniform rule: diagonal ``s``, each edge ``(1−s)/d``. Requires a
    regular graph (that is what makes it doubly stochastic); with
    ``allow_irregular`` the edge weight becomes ``(1−s)/max(d_i, d_j)`` and
    the diagonal absorbs the remainder (used for matchings, where degrees
    are 0/1 and the two rules coincide)."""
    adj = np.asarray(adjacency, bool)
    deg = adj.sum(axis=1)
    s = float(self_weight)
    if not 0.0 <= s <= 1.0:
        raise ValueError(f"self_weight must be in [0, 1], got {s}")
    pos = deg[deg > 0]
    if pos.size == 0:
        return np.eye(adj.shape[0])
    if not allow_irregular and not np.all(pos == pos[0]):
        raise ValueError(
            "uniform weighting needs a regular graph; use "
            "weighting='metropolis' (or allow_irregular for matchings)")
    denom = np.maximum(np.maximum(deg[:, None], deg[None, :]), 1)
    w = np.where(adj, (1.0 - s) / denom, 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def is_doubly_stochastic(w: np.ndarray, tol: float = 1e-9) -> bool:
    w = np.asarray(w, np.float64)
    return (np.all(w >= -tol)
            and np.allclose(w.sum(axis=0), 1.0, atol=1e-8)
            and np.allclose(w.sum(axis=1), 1.0, atol=1e-8))


def is_connected(adjacency: np.ndarray) -> bool:
    """BFS from node 0 (single-node graphs count as connected)."""
    adj = np.asarray(adjacency, bool)
    M = adj.shape[0]
    if M <= 1:
        return True
    seen = np.zeros(M, bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        nxt = adj[frontier].any(axis=0) & ~seen
        frontier = np.nonzero(nxt)[0].tolist()
        seen |= nxt
    return bool(seen.all())


def spectral_gap(w: np.ndarray) -> float:
    """1 − |λ₂| of a symmetric mixing matrix: the per-round contraction of
    the consensus error, the quantity accuracy-vs-topology sweeps plot."""
    w = np.asarray(w, np.float64)
    if w.shape[0] <= 1:
        return 1.0
    lam = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    return float(1.0 - lam[1])


# ---------------------------------------------------------------------------
# The traced mixing step
# ---------------------------------------------------------------------------


@dataclass(eq=False)
class MixPlan:
    """A topology compiled for the scanned round body: numpy neighbor
    index/weight stacks (baked into the trace as constants) + the
    special-case flags the apply functions branch on at trace time."""

    topology: object              # the Topology / TimeVaryingTopology source
    M: int
    degree: int                   # max slots per row (padded with self-loops)
    period: int                   # 1 for static topologies
    nbr_np: np.ndarray            # (T, M, d) int32
    nbr_w_np: np.ndarray          # (T, M, d) float32, 0 on padding
    self_w_np: np.ndarray         # (T, M) float32
    uniform: Optional[Tuple[float, float]]   # (self_w, nbr_w) scalars
    ring: bool                    # shard-aligned halo exchange eligible
    drop_prob: float
    churn_prob: float

    @property
    def faulty(self) -> bool:
        return self.drop_prob > 0.0 or self.churn_prob > 0.0


def make_plan(topology) -> MixPlan:
    """Compile a (possibly time-varying) topology into a MixPlan."""
    topos = getattr(topology, "topologies", None) or [topology]
    M = topos[0].M
    d = max((int(t.degrees.max()) if t.M and t.num_edges else 0)
            for t in topos)
    T = len(topos)
    nbr = np.tile(np.arange(M, dtype=np.int32)[None, :, None], (T, 1, max(d, 1)))
    nbr_w = np.zeros((T, M, max(d, 1)), np.float32)
    self_w = np.ones((T, M), np.float32)
    for t, topo in enumerate(topos):
        w = topo.weights
        for i in range(M):
            js = np.nonzero(topo.adjacency[i])[0]
            nbr[t, i, : len(js)] = js
            nbr_w[t, i, : len(js)] = w[i, js].astype(np.float32)
            self_w[t, i] = np.float32(w[i, i])

    # uniform fast path: one scalar self weight + one scalar edge weight and
    # a full (regular) slot occupancy everywhere — the precondition for the
    # coefficient-after-sum expression the bit-exact ring contract needs
    uniform = None
    pos_w = nbr_w[nbr_w > 0]
    if (d > 0 and pos_w.size == T * M * d
            and np.all(pos_w == pos_w.flat[0])
            and np.all(self_w == self_w.flat[0])):
        uniform = (float(self_w.flat[0]), float(pos_w.flat[0]))

    ring = bool(
        uniform is not None and d == 2 and T == 1 and M > 2
        and all(set(nbr[0, i]) == {(i - 1) % M, (i + 1) % M}
                for i in range(M)))
    return MixPlan(topology=topology, M=M, degree=d, period=T,
                   nbr_np=nbr, nbr_w_np=nbr_w, self_w_np=self_w,
                   uniform=uniform, ring=ring,
                   drop_prob=float(getattr(topology, "drop_prob", 0.0)),
                   churn_prob=float(getattr(topology, "churn_prob", 0.0)))


def _round_slice(arr: np.ndarray, r, period: int):
    """Select the round's (M, ...) slab from a (T, M, ...) stack; static
    topologies skip the dynamic index entirely."""
    import jax
    import jax.numpy as jnp
    if period == 1:
        return jnp.asarray(arr[0])
    return jax.lax.dynamic_index_in_dim(jnp.asarray(arr), jnp.mod(r, period),
                                        0, keepdims=False)


def _fault_adjusted_rows(plan: MixPlan, nbr, r, key, keep=None):
    """(self_w, nbr_w) rows for round r with this round's fault realization
    folded in: dropped slots zeroed, their mass moved to the diagonal — the
    realized matrix stays symmetric doubly stochastic. An explicit ``keep``
    (a correlated process realization from ``repro.resilience``) supersedes
    the plan's i.i.d. draw."""
    import jax.numpy as jnp
    from repro.topology.faults import draw_fault_masks
    w_row = _round_slice(plan.nbr_w_np, r, plan.period)
    s_row = _round_slice(plan.self_w_np, r, plan.period)
    if keep is None:
        if not plan.faulty:
            return s_row, w_row
        keep, _up = draw_fault_masks(key, plan.M, plan.drop_prob,
                                     plan.churn_prob)
    keep_slots = keep[jnp.arange(plan.M)[:, None], nbr]
    s_row = s_row + jnp.sum(w_row * (1.0 - keep_slots), axis=1)
    return s_row, w_row * keep_slots


def mix_stacked(tree, plan: MixPlan, r=0, key=None, keep=None):
    """One gossip round on a stacked (M, ...) pytree: t ← W_r t, with W_r
    the round's (fault-realized) mixing matrix, evaluated as a sparse
    neighbor gather. ``r`` and ``key`` may be traced (the engine passes the
    round index and the local-update key). ``keep`` is an optional external
    (M, M) edge realization (correlated fault process) that forces the
    general fault-folding path."""
    import jax
    import jax.numpy as jnp
    if plan.degree == 0 or plan.M <= 1:
        return tree

    if plan.ring and not plan.faulty and keep is None:
        # the pre-refactor ``_ring_mix`` lowering, verbatim — roll-based
        # neighbor reads keep the XLA fusion (and therefore the float
        # rounding) bit-identical to the historical DP-DSGT trajectories
        s, w = plan.uniform

        def mix_r(t):
            return s * t + w * (jnp.roll(t, 1, axis=0)
                                + jnp.roll(t, -1, axis=0))

        return jax.tree_util.tree_map(mix_r, tree)

    nbr = _round_slice(plan.nbr_np, r, plan.period)

    if plan.uniform is not None and not plan.faulty and keep is None:
        s, w = plan.uniform

        def mix_u(t):
            acc = t[nbr[:, 0]]
            for k in range(1, plan.degree):
                acc = acc + t[nbr[:, k]]
            return s * t + w * acc        # the same coefficient-after-sum shape

        return jax.tree_util.tree_map(mix_u, tree)

    s_row, w_row = _fault_adjusted_rows(plan, nbr, r, key, keep=keep)

    def mix_g(t):
        ex = (-1,) + (1,) * (t.ndim - 1)
        acc = s_row.reshape(ex) * t
        for k in range(plan.degree):
            acc = acc + w_row[:, k].reshape(ex) * t[nbr[:, k]]
        return acc.astype(t.dtype)

    return jax.tree_util.tree_map(mix_g, tree)


# ---------------------------------------------------------------------------
# Sharded execution (inside a shard_map region over the client axis)
# ---------------------------------------------------------------------------


def edges_shard_resident(plan: MixPlan, ctx) -> bool:
    """Host-side layout check: every positive-weight edge stays inside one
    mesh slice of ``ctx.m`` rows — mixing then needs no collective at all
    (the topology twin of P4's pod-resident groups)."""
    if plan.period != 1:
        return False
    m = ctx.m
    rows = np.arange(plan.M)[:, None]
    live = plan.nbr_w_np[0] > 0
    return bool(np.all(~live | (rows // m == plan.nbr_np[0] // m)))


def _halo_ring_mix(tree, plan: MixPlan, ctx):
    """Shard-aligned ring gossip as a ppermute halo exchange — each slice
    sends only its edge rows to its mesh neighbors. Bit-identical arithmetic
    to the historical ``_ring_mix_sharded``."""
    import jax
    import jax.numpy as jnp
    s, w = plan.uniform
    fwd = [(i, (i + 1) % ctx.n) for i in range(ctx.n)]
    bwd = [(i, (i - 1) % ctx.n) for i in range(ctx.n)]

    def mix(t):
        prev_last = jax.lax.ppermute(t[-1:], ctx.axis, fwd)
        next_first = jax.lax.ppermute(t[:1], ctx.axis, bwd)
        left = jnp.concatenate([prev_last, t[:-1]], axis=0)
        right = jnp.concatenate([t[1:], next_first], axis=0)
        return s * t + w * (left + right)

    return jax.tree_util.tree_map(mix, tree)


def _pad_rows_np(arr: np.ndarray, target: int, fill):
    if arr.shape[0] == target:
        return arr
    pad = np.full((target - arr.shape[0],) + arr.shape[1:], fill,
                  arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def _local_mix(tree, plan: MixPlan, r, key, ctx, keep=None):
    """Slice-local gather mix for shard-resident topologies: global neighbor
    indices are localized against the shard offset; padded rows self-loop
    with zero weight. Same per-row arithmetic as the single-device paths."""
    import jax.numpy as jnp
    import jax
    M, d = plan.M, plan.degree
    nbr_pad = _pad_rows_np(plan.nbr_np[0].astype(np.int32), ctx.M_pad, 0)
    for i in range(M, ctx.M_pad):
        nbr_pad[i] = i          # padded slots reference themselves
    local_nbr = (ctx.shard_rows(jnp.asarray(nbr_pad))
                 - ctx.shard_offset())

    if plan.uniform is not None and not plan.faulty and keep is None:
        s, w = plan.uniform

        def mix_u(t):
            acc = t[local_nbr[:, 0]]
            for k in range(1, d):
                acc = acc + t[local_nbr[:, k]]
            return s * t + w * acc

        return jax.tree_util.tree_map(mix_u, tree)

    s_full, w_full = _fault_adjusted_rows(plan, jnp.asarray(plan.nbr_np[0]),
                                          r, key, keep=keep)
    s_row = ctx.shard_rows(jnp.concatenate(
        [s_full, jnp.ones((ctx.M_pad - M,), s_full.dtype)]) if ctx.M_pad != M
        else s_full)
    w_row = ctx.shard_rows(jnp.concatenate(
        [w_full, jnp.zeros((ctx.M_pad - M, d), w_full.dtype)])
        if ctx.M_pad != M else w_full)

    def mix_g(t):
        ex = (-1,) + (1,) * (t.ndim - 1)
        acc = s_row.reshape(ex) * t
        for k in range(d):
            acc = acc + w_row[:, k].reshape(ex) * t[local_nbr[:, k]]
        return acc.astype(t.dtype)

    return jax.tree_util.tree_map(mix_g, tree)


def mix_stacked_sharded(tree, plan: MixPlan, r, key, ctx, keep=None):
    """Sharded twin of ``mix_stacked`` (call inside the shard_map region):

      ring, shard-aligned, fault-free → ppermute halo exchange;
      all edges shard-resident         → slice-local gather (no collective);
      anything else                    → all_gather → mix → re-shard, which
                                         is bit-exact with the single-device
                                         step by construction.

    Fault draws are replicated (every shard draws the identical (M, M) keep
    matrix from the same key) so realized topologies agree across layouts;
    an external correlated ``keep`` realization is replicated by the same
    argument (the fault carry is stepped identically on every slice).
    """
    if plan.degree == 0 or plan.M <= 1:
        return tree
    if plan.ring and not plan.faulty and keep is None and ctx.M_pad == ctx.M:
        return _halo_ring_mix(tree, plan, ctx)
    if edges_shard_resident(plan, ctx):
        return _local_mix(tree, plan, r, key, ctx, keep=keep)
    full = ctx.gather(tree)
    return ctx.scatter_like(mix_stacked(full, plan, r, key, keep=keep), full)
