"""Mixing matrices and the in-jit gossip step.

Two layers:

  * host-side construction — Metropolis–Hastings weights (doubly stochastic
    on ANY symmetric graph), the lazy uniform rule for regular graphs (the
    DP-DSGT ring's historical 1/2–1/4–1/4 row), spectral-gap reporting, and
    connectivity checks;

  * the traced mixing step — ``make_plan`` compiles a topology into a
    ``MixPlan`` (padded neighbor-index/weight arrays plus special-case
    flags) and ``mix_stacked`` applies one gossip round to a stacked
    (M, ...) pytree inside the engine's scanned round body. The plan keeps
    three executions of the same arithmetic:

      - uniform fast path: ``s·t + w·Σ_k t[nbr_k]`` with scalar s, w —
        for the ring this is bit-identical to the pre-refactor
        ``_ring_mix`` expression ``0.5·t + 0.25·(left + right)``;
      - general path: per-row self weights + per-slot neighbor weights
        (Metropolis rows, matchings, fault-adjusted rows);
      - sharded paths (``mix_stacked_sharded``): a slice-local gather when
        every edge is shard-resident (no collective at all), a ppermute
        halo exchange of exactly the boundary rows when the graph has
        bounded bandwidth under the shard layout (``halo_schedule`` —
        rings, tori, circulant expanders, banded/clustered graphs, with
        or without fault ``keep`` masks), and the gather→mix→re-shard
        fallback (exact for any graph) otherwise. The trace-time
        ``MIX_STATS`` probe records which path ran and how many
        collectives it issued, so tests can assert "0 gathers per round"
        for banded families.

    Link faults are drawn in-jit per round (``repro.topology.faults``) and
    folded into the row weights with the dropped mass moved to the diagonal,
    so every realized matrix stays doubly stochastic — gossip under faults
    still preserves the global mean.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.obs.probes import Probe

# ---------------------------------------------------------------------------
# Host-side: weight construction + graph diagnostics (numpy only — graphs.py
# imports these at module load, before jax is necessarily initialized)
# ---------------------------------------------------------------------------


def metropolis_weights(adjacency: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings: W_ij = 1 / (1 + max(d_i, d_j)) on edges,
    diagonal absorbs the remainder. Symmetric + doubly stochastic on any
    symmetric graph (Xiao & Boyd 2004)."""
    adj = np.asarray(adjacency, bool)
    deg = adj.sum(axis=1)
    denom = 1.0 + np.maximum(deg[:, None], deg[None, :])
    w = np.where(adj, 1.0 / denom, 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def uniform_weights(adjacency: np.ndarray, self_weight: float = 0.5, *,
                    allow_irregular: bool = False) -> np.ndarray:
    """Lazy uniform rule: diagonal ``s``, each edge ``(1−s)/d``. Requires a
    regular graph (that is what makes it doubly stochastic); with
    ``allow_irregular`` the edge weight becomes ``(1−s)/max(d_i, d_j)`` and
    the diagonal absorbs the remainder (used for matchings, where degrees
    are 0/1 and the two rules coincide)."""
    adj = np.asarray(adjacency, bool)
    deg = adj.sum(axis=1)
    s = float(self_weight)
    if not 0.0 <= s <= 1.0:
        raise ValueError(f"self_weight must be in [0, 1], got {s}")
    pos = deg[deg > 0]
    if pos.size == 0:
        return np.eye(adj.shape[0])
    if not allow_irregular and not np.all(pos == pos[0]):
        raise ValueError(
            "uniform weighting needs a regular graph; use "
            "weighting='metropolis' (or allow_irregular for matchings)")
    denom = np.maximum(np.maximum(deg[:, None], deg[None, :]), 1)
    w = np.where(adj, (1.0 - s) / denom, 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def is_doubly_stochastic(w: np.ndarray, tol: float = 1e-9) -> bool:
    w = np.asarray(w, np.float64)
    return (np.all(w >= -tol)
            and np.allclose(w.sum(axis=0), 1.0, atol=1e-8)
            and np.allclose(w.sum(axis=1), 1.0, atol=1e-8))


def is_column_stochastic(w: np.ndarray, tol: float = 1e-9) -> bool:
    """Column sums 1 (every sender's outgoing shares, incl. its self-share,
    sum to 1) — the mass-conservation property push-sum mixing needs."""
    w = np.asarray(w, np.float64)
    return (np.all(w >= -tol)
            and np.allclose(w.sum(axis=0), 1.0, atol=1e-8))


def push_sum_weights(trust: np.ndarray) -> np.ndarray:
    """Column-stochastic mixing matrix from a row-stochastic trust matrix.

    Row i of ``trust`` is how client i splits its outgoing mass across the
    peers it trusts (plus itself). The mixing step is still the row gather
    ``x_i ← Σ_j W_ij x_j``, so the matrix handed to ``make_plan`` must carry
    sender j's share to receiver i at W[i, j] — i.e. ``trust.T``."""
    t = np.asarray(trust, np.float64)
    if not np.allclose(t.sum(axis=1), 1.0, atol=1e-8) or np.any(t < -1e-12):
        raise ValueError("trust matrix must be row stochastic")
    return t.T.copy()


def is_connected(adjacency: np.ndarray) -> bool:
    """BFS from node 0 (single-node graphs count as connected)."""
    adj = np.asarray(adjacency, bool)
    M = adj.shape[0]
    if M <= 1:
        return True
    seen = np.zeros(M, bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        nxt = adj[frontier].any(axis=0) & ~seen
        frontier = np.nonzero(nxt)[0].tolist()
        seen |= nxt
    return bool(seen.all())


def spectral_gap(w: np.ndarray) -> float:
    """1 − |λ₂| of a mixing matrix: the per-round contraction of the
    consensus error, the quantity accuracy-vs-topology sweeps plot.
    Symmetric W uses the Hermitian solver; directed (learned) W falls back
    to the general eigenvalue problem on |λ|."""
    w = np.asarray(w, np.float64)
    if w.shape[0] <= 1:
        return 1.0
    if np.allclose(w, w.T, atol=1e-12):
        lam = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    else:
        lam = np.sort(np.abs(np.linalg.eigvals(w)))[::-1]
    return float(1.0 - lam[1])


# ---------------------------------------------------------------------------
# The traced mixing step
# ---------------------------------------------------------------------------


@dataclass(eq=False)
class MixPlan:
    """A topology compiled for the scanned round body: numpy neighbor
    index/weight stacks (baked into the trace as constants) + the
    special-case flags the apply functions branch on at trace time."""

    topology: object              # the Topology / TimeVaryingTopology source
    M: int
    degree: int                   # max slots per row (padded with self-loops)
    period: int                   # 1 for static topologies
    nbr_np: np.ndarray            # (T, M, d) int32
    nbr_w_np: np.ndarray          # (T, M, d) float32, 0 on padding
    self_w_np: np.ndarray         # (T, M) float32
    uniform: Optional[Tuple[float, float]]   # (self_w, nbr_w) scalars
    ring: bool                    # shard-aligned halo exchange eligible
    drop_prob: float
    churn_prob: float
    # optional KernelConfig: opts the halo mix step's row blocking into the
    # dispatch autotuner (None => always the untiled lowering)
    kernels: Optional[object] = None
    # push-sum mode: W is column- but not row-stochastic (directed/learned
    # graphs). The gather arithmetic is unchanged; consumers must carry a
    # per-node weight scalar through the same mix and de-bias by it.
    push_sum: bool = False
    # (T, M, d) outgoing shares W[nbr_k, i] aligned with nbr_np's slots —
    # what row i SENDS to each listed neighbor. Only set under push-sum:
    # the fault fold must return a dropped link's mass to the sender's
    # diagonal (not the receiver's) to keep the realized matrix
    # column-stochastic.
    out_w_np: Optional[np.ndarray] = None

    @property
    def faulty(self) -> bool:
        return self.drop_prob > 0.0 or self.churn_prob > 0.0


def make_plan(topology, kernels=None, push_sum: Optional[bool] = None) -> MixPlan:
    """Compile a (possibly time-varying) topology into a MixPlan.

    Row-stochastic W compiles to the standard averaging plan. A W that is
    column- but not row-stochastic (directed/learned graphs) compiles to a
    push-sum plan automatically; pass ``push_sum=True`` to force push-sum
    on a doubly-stochastic W (it converges to the same fixed point — the
    weight scalar stays ≈ 1)."""
    topos = getattr(topology, "topologies", None) or [topology]
    M = topos[0].M
    d = max((int(t.degrees.max()) if t.M and t.num_edges else 0)
            for t in topos)
    T = len(topos)
    nbr = np.tile(np.arange(M, dtype=np.int32)[None, :, None], (T, 1, max(d, 1)))
    nbr_w = np.zeros((T, M, max(d, 1)), np.float32)
    self_w = np.ones((T, M), np.float32)
    for t, topo in enumerate(topos):
        w = topo.weights
        for i in range(M):
            js = np.nonzero(topo.adjacency[i])[0]
            nbr[t, i, : len(js)] = js
            nbr_w[t, i, : len(js)] = w[i, js].astype(np.float32)
            self_w[t, i] = np.float32(w[i, i])

    row_ok = all(np.allclose(t.weights.sum(axis=1), 1.0, atol=1e-6)
                 for t in topos)
    col_ok = all(np.allclose(t.weights.sum(axis=0), 1.0, atol=1e-6)
                 for t in topos)
    if push_sum is None:
        push_sum = col_ok and not row_ok
    if push_sum and not col_ok:
        raise ValueError(
            "push-sum mixing needs a column-stochastic W (every sender's "
            "outgoing shares must sum to 1)")
    if not push_sum and not row_ok:
        raise ValueError(
            "standard mixing needs a row-stochastic W; a directed "
            "column-stochastic W (learned graphs) mixes via push-sum — "
            "make_plan detects this automatically, so the weights here are "
            "neither row- nor column-stochastic")

    out_w = None
    uniform = None
    ring = False
    if push_sum:
        # the sender-side share stack: out_w[t, i, k] is what row i ships to
        # nbr[t, i, k] — W[nbr_k, i]. Slot padding (self-loop index, zero
        # in-weight) gets a zero out-share too: padded slots carry no mass.
        out_w = np.zeros_like(nbr_w)
        for t, topo in enumerate(topos):
            w = topo.weights
            for i in range(M):
                js = np.nonzero(topo.adjacency[i])[0]
                out_w[t, i, : len(js)] = w[js, i].astype(np.float32)
    else:
        # uniform fast path: one scalar self weight + one scalar edge weight
        # and a full (regular) slot occupancy everywhere — the precondition
        # for the coefficient-after-sum expression the bit-exact ring
        # contract needs
        pos_w = nbr_w[nbr_w > 0]
        if (d > 0 and pos_w.size == T * M * d
                and np.all(pos_w == pos_w.flat[0])
                and np.all(self_w == self_w.flat[0])):
            uniform = (float(self_w.flat[0]), float(pos_w.flat[0]))

        ring = bool(
            uniform is not None and d == 2 and T == 1 and M > 2
            and all(set(nbr[0, i]) == {(i - 1) % M, (i + 1) % M}
                    for i in range(M)))
    return MixPlan(topology=topology, M=M, degree=d, period=T,
                   nbr_np=nbr, nbr_w_np=nbr_w, self_w_np=self_w,
                   uniform=uniform, ring=ring,
                   drop_prob=float(getattr(topology, "drop_prob", 0.0)),
                   churn_prob=float(getattr(topology, "churn_prob", 0.0)),
                   kernels=kernels, push_sum=bool(push_sum), out_w_np=out_w)


def _round_slice(arr: np.ndarray, r, period: int):
    """Select the round's (M, ...) slab from a (T, M, ...) stack; static
    topologies skip the dynamic index entirely."""
    import jax
    import jax.numpy as jnp
    if period == 1:
        return jnp.asarray(arr[0])
    return jax.lax.dynamic_index_in_dim(jnp.asarray(arr), jnp.mod(r, period),
                                        0, keepdims=False)


def _fault_adjusted_rows(plan: MixPlan, nbr, r, key, keep=None):
    """(self_w, nbr_w) rows for round r with this round's fault realization
    folded in: dropped slots zeroed, their mass moved to the diagonal — the
    realized matrix stays symmetric doubly stochastic. Under push-sum the
    diagonal refund instead uses the OUTGOING shares (``plan.out_w_np``):
    with symmetric keep realizations a dropped link removes W[i,j]·x_j from
    receiver i and returns i's own undeliverable share W[j,i]·x_i to i, so
    every realized column still sums to one (mass conservation, the
    invariant push-sum's ratio estimate rests on). An explicit ``keep``
    (a correlated process realization from ``repro.resilience``) supersedes
    the plan's i.i.d. draw."""
    import jax.numpy as jnp
    from repro.topology.faults import draw_fault_masks
    w_row = _round_slice(plan.nbr_w_np, r, plan.period)
    s_row = _round_slice(plan.self_w_np, r, plan.period)
    if keep is None:
        if not plan.faulty:
            return s_row, w_row
        keep, _up = draw_fault_masks(key, plan.M, plan.drop_prob,
                                     plan.churn_prob)
    keep_slots = keep[jnp.arange(plan.M)[:, None], nbr]
    fold_w = (w_row if plan.out_w_np is None
              else _round_slice(plan.out_w_np, r, plan.period))
    s_row = s_row + jnp.sum(fold_w * (1.0 - keep_slots), axis=1)
    return s_row, w_row * keep_slots


def mix_stacked(tree, plan: MixPlan, r=0, key=None, keep=None):
    """One gossip round on a stacked (M, ...) pytree: t ← W_r t, with W_r
    the round's (fault-realized) mixing matrix, evaluated as a sparse
    neighbor gather. ``r`` and ``key`` may be traced (the engine passes the
    round index and the local-update key). ``keep`` is an optional external
    (M, M) edge realization (correlated fault process) that forces the
    general fault-folding path."""
    import jax
    import jax.numpy as jnp
    if plan.degree == 0 or plan.M <= 1:
        return tree

    if plan.ring and not plan.faulty and keep is None:
        # the pre-refactor ``_ring_mix`` lowering, verbatim — roll-based
        # neighbor reads keep the XLA fusion (and therefore the float
        # rounding) bit-identical to the historical DP-DSGT trajectories
        s, w = plan.uniform

        def mix_r(t):
            return s * t + w * (jnp.roll(t, 1, axis=0)
                                + jnp.roll(t, -1, axis=0))

        return jax.tree_util.tree_map(mix_r, tree)

    nbr = _round_slice(plan.nbr_np, r, plan.period)

    if plan.uniform is not None and not plan.faulty and keep is None:
        s, w = plan.uniform

        def mix_u(t):
            acc = t[nbr[:, 0]]
            for k in range(1, plan.degree):
                acc = acc + t[nbr[:, k]]
            return s * t + w * acc        # the same coefficient-after-sum shape

        return jax.tree_util.tree_map(mix_u, tree)

    s_row, w_row = _fault_adjusted_rows(plan, nbr, r, key, keep=keep)

    def mix_g(t):
        ex = (-1,) + (1,) * (t.ndim - 1)
        acc = s_row.reshape(ex) * t
        for k in range(plan.degree):
            acc = acc + w_row[:, k].reshape(ex) * t[nbr[:, k]]
        return acc.astype(t.dtype)

    return jax.tree_util.tree_map(mix_g, tree)


def plan_in_neighbors(plan: MixPlan, ids, rounds):
    """Host-side cohort closure: ``ids`` plus every positive-weight
    in-neighbor those rows read in any of ``rounds``'s period slices.
    Fault realizations only *remove* edges, so this is always a superset of
    the rows a realized chunk actually reads."""
    ids = np.asarray(ids, np.int64)
    if plan.degree == 0 or plan.M <= 1 or ids.size == 0:
        return ids
    if plan.period == 1:
        ts = [0]
    else:
        ts = sorted({int(r) % plan.period for r in np.asarray(rounds)})
    out = set(ids.tolist())
    for t in ts:
        nbr = plan.nbr_np[t][ids]
        live = plan.nbr_w_np[t][ids] > 0
        out.update(int(j) for j in nbr[live].ravel())
    return np.asarray(sorted(out), np.int64)


def mix_stacked_paged(tree, plan: MixPlan, r, key, pctx, keep=None):
    """Paged twin of ``mix_stacked``: one gossip round on a compact cohort
    (C, ...) pytree. Each cohort row applies the SAME per-row expression the
    resident step applies to its global row — neighbor reads are resolved
    through ``pctx.slot_of`` (global id → cohort slot), and fault-adjusted
    row weights are computed at full M (replicated arithmetic) then sliced at
    the cohort's rows — so participant rows are bit-identical to the resident
    mix (two-term float adds are bitwise commutative, which covers the
    resident ring's roll-based lowering). Rows whose neighbors fall outside
    the cohort read finite garbage (slot 0); the cohort planner guarantees
    those rows are non-participants, whose mixed values the schedule's
    ``merge_participation`` discards."""
    import jax
    import jax.numpy as jnp
    if plan.degree == 0 or plan.M <= 1:
        return tree
    nbr = _round_slice(plan.nbr_np, r, plan.period)      # (M, d) global ids
    rows = pctx.ids_clip                                 # (C,) global ids
    slot = pctx.slot_of[nbr[rows]]                       # (C, d) cohort slots

    if plan.uniform is not None and not plan.faulty and keep is None:
        s, w = plan.uniform

        def mix_u(t):
            acc = t[slot[:, 0]]
            for k in range(1, plan.degree):
                acc = acc + t[slot[:, k]]
            return s * t + w * acc

        return jax.tree_util.tree_map(mix_u, tree)

    s_full, w_full = _fault_adjusted_rows(plan, nbr, r, key, keep=keep)
    s_row, w_row = s_full[rows], w_full[rows]

    def mix_g(t):
        ex = (-1,) + (1,) * (t.ndim - 1)
        acc = s_row.reshape(ex) * t
        for k in range(plan.degree):
            acc = acc + w_row[:, k].reshape(ex) * t[slot[:, k]]
        return acc.astype(t.dtype)

    return jax.tree_util.tree_map(mix_g, tree)


# ---------------------------------------------------------------------------
# Push-sum mixing (directed / learned graphs, column-stochastic W)
# ---------------------------------------------------------------------------
#
# A learned collaboration graph is generally directed and only
# column-stochastic: sender j splits its unit mass across the peers it
# trusts. Plain averaging with such a W biases every estimate toward
# high-in-degree nodes. Push-sum (Kempe et al. 2003; gradient-push,
# Nedić & Olshevsky 2016) fixes this with one extra scalar per node: mix a
# weight w (initialized to 1) with the SAME matrix as the values and read
# the de-biased estimate x/w — on a strongly-connected W the ratio
# converges to the uniform average because both numerator and denominator
# pick up the same Perron re-weighting. When W happens to be doubly
# stochastic, w stays exactly 1 up to float rounding and push-sum reduces
# to the symmetric path.


def push_sum_mix(tree, weights, plan: MixPlan, r=0, key=None, keep=None):
    """One push-sum gossip round: returns ``(tree', weights')`` with both the
    stacked (M, ...) value tree and the (M,) weight scalars mixed by the
    round's realized matrix. The weights ride as one more leaf through
    ``mix_stacked`` so fault folding, time variation, and the round's keep
    mask apply to values and weights identically (the invariant de-biasing
    needs)."""
    mixed = mix_stacked({"v": tree, "w": weights}, plan, r, key, keep=keep)
    return mixed["v"], mixed["w"]


def push_sum_mix_sharded(tree, weights, plan: MixPlan, r, key, ctx,
                         keep=None, halo=None):
    """Sharded twin of ``push_sum_mix`` — same joint-leaf trick through
    ``mix_stacked_sharded``, so the halo/local/gather path selection and the
    MIX_STATS probe see one mix call for values + weights together."""
    mixed = mix_stacked_sharded({"v": tree, "w": weights}, plan, r, key, ctx,
                                keep=keep, halo=halo)
    return mixed["v"], mixed["w"]


def push_sum_mix_paged(tree, weights, plan: MixPlan, r, key, pctx, keep=None):
    """Paged twin of ``push_sum_mix`` for cohort-resident (C, ...) trees."""
    mixed = mix_stacked_paged({"v": tree, "w": weights}, plan, r, key, pctx,
                              keep=keep)
    return mixed["v"], mixed["w"]


def push_sum_debias(tree, weights):
    """The push-sum estimate: every stacked leaf divided by its row's weight
    scalar (x/w), cast back to the leaf dtype."""
    import jax
    import jax.numpy as jnp

    def one(t):
        ex = (-1,) + (1,) * (t.ndim - 1)
        return (t / jnp.asarray(weights).reshape(ex)).astype(t.dtype)

    return jax.tree_util.tree_map(one, tree)


# ---------------------------------------------------------------------------
# Sharded execution (inside a shard_map region over the client axis)
# ---------------------------------------------------------------------------

# Trace-time collective probe (``CHUNK_STATS``-style counters): every sharded
# mix records the path it took and the collectives it issued while tracing.
# The scanned round body traces once per compiled chunk, so a snapshot delta
# around a sharded run counts collectives PER ROUND — the sharded equivalence
# tier asserts 0 all_gathers/round for banded/clustered/torus families.
# A registry-backed Probe (still a plain dict to every existing caller).
# Nothing here auto-resets between Engine instances — counters accumulate
# for the life of the process — so per-run numbers go through the scoped
# API: ``repro.obs.probe_deltas("topology.mix")``.
MIX_STATS = Probe("topology.mix", {
    "calls": 0,
    "path_identity": 0, "path_local": 0, "path_halo": 0, "path_gather": 0,
    "all_gathers": 0,   # gather-fallback all_gather collectives (one per leaf)
    "ppermutes": 0,     # halo-exchange ppermute collectives (leaf × hop)
})


def mix_stats_snapshot():
    return MIX_STATS.snapshot()


def reset_mix_stats() -> None:
    MIX_STATS.reset()


def edges_shard_resident(plan: MixPlan, ctx) -> bool:
    """Host-side layout check: every positive-weight edge stays inside one
    mesh slice of ``ctx.m`` rows — mixing then needs no collective at all
    (the topology twin of P4's pod-resident groups)."""
    if plan.period != 1:
        return False
    m = ctx.m
    rows = np.arange(plan.M)[:, None]
    live = plan.nbr_w_np[0] > 0
    return bool(np.all(~live | (rows // m == plan.nbr_np[0] // m)))


@dataclass
class HaloSchedule:
    """A gather-free exchange plan for one (plan, mesh layout) pair: which
    local rows every slice ppermutes to each mesh displacement, and where
    every neighbor row lands in the per-slice receive buffer."""

    sends: Tuple                  # ((disp, (k_d,) int32 local rows), ...)
    H: int                        # total halo rows received per slice
    buf_idx: np.ndarray           # (T, M_pad, degree) int32 positions into
                                  # the (m + H, ...) [local ‖ halo] buffer


def _build_halo_schedule(plan: MixPlan, n: int, m: int) -> Optional[HaloSchedule]:
    """Derive the ppermute halo schedule from the graph's bandwidth under an
    ``n`` slices × ``m`` rows shard layout.

    For each mesh displacement ``d`` the send set is the UNION over slices of
    the local boundary rows some neighbor slice ``d`` hops ahead needs — the
    same local indices on every slice, which is what keeps the exchange SPMD
    (a single ppermute per displacement moves every slice's boundary). For
    time-varying plans the union also runs over the period, so the transfer
    pattern is trace-static and only the per-round row weights vary.

    Returns None when no exchange is needed (every edge shard-resident) or
    when the halo would be as wide as a gather (H ≥ M_pad − m: dense rows,
    e.g. Erdős–Rényi at small m) — callers fall back accordingly."""
    if n <= 1 or plan.degree == 0:
        return None
    M, deg, T = plan.M, plan.degree, plan.period
    M_pad = n * m
    send_sets = [set() for _ in range(n)]           # indexed by displacement
    for t in range(T):
        for i in range(M):
            p = i // m
            for k in range(deg):
                if plan.nbr_w_np[t, i, k] <= 0:
                    continue
                j = int(plan.nbr_np[t, i, k])
                q = j // m
                if q != p:
                    send_sets[(p - q) % n].add(j - q * m)
    sends = tuple((d, np.asarray(sorted(send_sets[d]), np.int32))
                  for d in range(1, n) if send_sets[d])
    H = sum(len(idx) for _, idx in sends)
    if H == 0 or H >= M_pad - m:
        return None
    offsets, pos_in, off = {}, {}, m
    for d, idx in sends:
        offsets[d] = off
        pos_in[d] = {int(v): i for i, v in enumerate(idx)}
        off += len(idx)
    buf_idx = np.zeros((T, M_pad, deg), np.int32)
    for t in range(T):
        for i in range(M_pad):
            p, li = divmod(i, m)
            for k in range(deg):
                if i >= M or plan.nbr_w_np[t, i, k] <= 0:
                    buf_idx[t, i, k] = li       # zero-weight slots self-loop
                    continue
                j = int(plan.nbr_np[t, i, k])
                q, lj = divmod(j, m)
                if q == p:
                    buf_idx[t, i, k] = lj
                else:
                    d = (p - q) % n
                    buf_idx[t, i, k] = offsets[d] + pos_in[d][lj]
    return HaloSchedule(sends=sends, H=H, buf_idx=buf_idx)


def halo_schedule(plan: MixPlan, ctx) -> Optional[HaloSchedule]:
    """The plan's halo schedule for ``ctx``'s layout (memoized on the plan:
    schedule construction is O(T·M·degree) host work)."""
    cache = plan.__dict__.setdefault("_halo_cache", {})
    key = (ctx.n, ctx.m)
    if key not in cache:
        cache[key] = _build_halo_schedule(plan, ctx.n, ctx.m)
    return cache[key]


def select_mix_path(plan: MixPlan, ctx) -> str:
    """Host-side dispatch predicate for the sharded mix — the single source
    of truth shared by ``mix_stacked_sharded`` and the overlap prefetch
    (``halo_start`` callers), and what tier-1 tests assert without tracing:
    ``identity`` | ``local`` | ``halo`` | ``gather``."""
    if plan.degree == 0 or plan.M <= 1:
        return "identity"
    if edges_shard_resident(plan, ctx):
        return "local"
    if halo_schedule(plan, ctx) is not None:
        return "halo"
    return "gather"


def _halo_exchange(t, sched: HaloSchedule, ctx):
    """Issue the schedule's ppermutes for one leaf: the (H, ...) halo block
    this slice receives, concatenated in send order."""
    import jax
    import jax.numpy as jnp
    parts = []
    for disp, idx in sched.sends:
        perm = [(s, (s + disp) % ctx.n) for s in range(ctx.n)]
        parts.append(jax.lax.ppermute(t[jnp.asarray(idx)], ctx.axis, perm))
        MIX_STATS["ppermutes"] += 1
    return jnp.concatenate(parts, axis=0)


def halo_start(tree, plan: MixPlan, ctx):
    """Kick off a round's boundary transfer ahead of time (the overlap half
    of the halo path): returns the halo-block tree that
    ``mix_stacked_sharded(..., halo=...)`` consumes. Rows are sent RAW and
    the (possibly fault-adjusted) row weights are applied at consume time,
    so a prefetched halo stays exact under ``keep`` masks. Only call when
    ``select_mix_path(plan, ctx) == "halo"``."""
    import jax
    sched = halo_schedule(plan, ctx)
    return jax.tree_util.tree_map(
        lambda t: _halo_exchange(t, sched, ctx), tree)


def _halo_tile(plan: MixPlan, ctx, t, sched) -> int:
    """Row-block width for the halo mix arithmetic on leaf ``t`` — resolved
    through the dispatch autotuner's cached search when the plan carries a
    KernelConfig (``make_plan(topology, kernels=...)``), untiled otherwise.
    Every width is bit-identical (per-row arithmetic); only the lowering's
    gather granularity changes."""
    if plan.kernels is None:
        return 0
    from repro.kernels.dispatch import mix_halo_tiles, resolve_backend
    feat = int(np.prod(t.shape[1:])) if t.ndim > 1 else 1
    (tm,) = mix_halo_tiles((ctx.m, sched.H, plan.degree, feat), t.dtype,
                           plan.kernels, resolve_backend(plan.kernels.backend))
    return int(tm)


def _row_blocks(m: int, tm: int):
    """Static row slices: one full slice when untiled, else ``tm``-row
    blocks (last one ragged)."""
    if tm <= 0 or tm >= m:
        return [slice(None)]
    return [slice(i0, min(i0 + tm, m)) for i0 in range(0, m, tm)]


def _halo_mix(tree, plan: MixPlan, r, key, ctx, keep=None, halo=None):
    """Gather-free sparse mix: ppermute only the boundary rows the schedule
    derived, then run the single-device per-row arithmetic against the
    (m + H, ...) receive buffer — value-identical reads in the identical
    slot-accumulation order, so the result matches the single-device step to
    the commutativity of each two-term float add. ``halo`` is an optional
    prefetched halo-block tree (issued by ``halo_start`` at the end of the
    previous round body — the double-buffered overlap path). The per-row
    arithmetic runs in row blocks sized by the dispatch autotuner when the
    plan carries a KernelConfig (``_halo_tile``); the untiled default is
    today's lowering, verbatim."""
    import jax
    import jax.numpy as jnp
    sched = halo_schedule(plan, ctx)
    local_idx = ctx.shard_rows(
        _round_slice(sched.buf_idx, r, plan.period))    # (m, degree) slots

    def apply(mix_fn):
        if halo is None:
            return jax.tree_util.tree_map(
                lambda t: mix_fn(t, _halo_exchange(t, sched, ctx)), tree)
        return jax.tree_util.tree_map(mix_fn, tree, halo)

    if plan.uniform is not None and not plan.faulty and keep is None:
        s, w = plan.uniform

        def mix_u(t, hblock):
            buf = jnp.concatenate([t, hblock], axis=0)

            def block(sl):
                acc = buf[local_idx[sl, 0]]
                for k in range(1, plan.degree):
                    acc = acc + buf[local_idx[sl, k]]
                return s * t[sl] + w * acc

            blocks = [block(sl)
                      for sl in _row_blocks(t.shape[0],
                                            _halo_tile(plan, ctx, t, sched))]
            return blocks[0] if len(blocks) == 1 else jnp.concatenate(
                blocks, axis=0)

        return apply(mix_u)

    M, d = plan.M, plan.degree
    s_full, w_full = _fault_adjusted_rows(
        plan, _round_slice(plan.nbr_np, r, plan.period), r, key, keep=keep)
    s_row = ctx.shard_rows(jnp.concatenate(
        [s_full, jnp.ones((ctx.M_pad - M,), s_full.dtype)]) if ctx.M_pad != M
        else s_full)
    w_row = ctx.shard_rows(jnp.concatenate(
        [w_full, jnp.zeros((ctx.M_pad - M, d), w_full.dtype)])
        if ctx.M_pad != M else w_full)

    def mix_g(t, hblock):
        buf = jnp.concatenate([t, hblock], axis=0)
        ex = (-1,) + (1,) * (t.ndim - 1)

        def block(sl):
            acc = s_row[sl].reshape(ex) * t[sl]
            for k in range(d):
                acc = acc + w_row[sl, k].reshape(ex) * buf[local_idx[sl, k]]
            return acc.astype(t.dtype)

        blocks = [block(sl)
                  for sl in _row_blocks(t.shape[0],
                                        _halo_tile(plan, ctx, t, sched))]
        return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks,
                                                                  axis=0)

    return apply(mix_g)


def _pad_rows_np(arr: np.ndarray, target: int, fill):
    if arr.shape[0] == target:
        return arr
    pad = np.full((target - arr.shape[0],) + arr.shape[1:], fill,
                  arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def _local_mix(tree, plan: MixPlan, r, key, ctx, keep=None):
    """Slice-local gather mix for shard-resident topologies: global neighbor
    indices are localized against the shard offset; padded rows self-loop
    with zero weight. Same per-row arithmetic as the single-device paths."""
    import jax.numpy as jnp
    import jax
    M, d = plan.M, plan.degree
    nbr_pad = _pad_rows_np(plan.nbr_np[0].astype(np.int32), ctx.M_pad, 0)
    for i in range(M, ctx.M_pad):
        nbr_pad[i] = i          # padded slots reference themselves
    local_nbr = (ctx.shard_rows(jnp.asarray(nbr_pad))
                 - ctx.shard_offset())

    if plan.uniform is not None and not plan.faulty and keep is None:
        s, w = plan.uniform

        def mix_u(t):
            acc = t[local_nbr[:, 0]]
            for k in range(1, d):
                acc = acc + t[local_nbr[:, k]]
            return s * t + w * acc

        return jax.tree_util.tree_map(mix_u, tree)

    s_full, w_full = _fault_adjusted_rows(plan, jnp.asarray(plan.nbr_np[0]),
                                          r, key, keep=keep)
    s_row = ctx.shard_rows(jnp.concatenate(
        [s_full, jnp.ones((ctx.M_pad - M,), s_full.dtype)]) if ctx.M_pad != M
        else s_full)
    w_row = ctx.shard_rows(jnp.concatenate(
        [w_full, jnp.zeros((ctx.M_pad - M, d), w_full.dtype)])
        if ctx.M_pad != M else w_full)

    def mix_g(t):
        ex = (-1,) + (1,) * (t.ndim - 1)
        acc = s_row.reshape(ex) * t
        for k in range(d):
            acc = acc + w_row[:, k].reshape(ex) * t[local_nbr[:, k]]
        return acc.astype(t.dtype)

    return jax.tree_util.tree_map(mix_g, tree)


def mix_stacked_sharded(tree, plan: MixPlan, r, key, ctx, keep=None,
                        halo=None):
    """Sharded twin of ``mix_stacked`` (call inside the shard_map region) —
    path selection is host-side (``select_mix_path``) and recorded by the
    ``MIX_STATS`` probe:

      all live edges shard-resident → slice-local gather (no collective);
      bounded-bandwidth graph       → ppermute halo exchange of exactly the
                                      boundary rows (``halo_schedule``). This
                                      subsumes the old shard-aligned-ring
                                      special case and composes with fault
                                      ``keep`` masks: dropped mass moves to
                                      the diagonal locally, no collective
                                      beyond the same boundary rows;
      anything else                 → all_gather → mix → re-shard, which is
                                      bit-exact with the single-device step
                                      by construction.

    Fault draws are replicated (every shard draws the identical (M, M) keep
    matrix from the same key) so realized topologies agree across layouts;
    an external correlated ``keep`` realization is replicated by the same
    argument (the fault carry is stepped identically on every slice).
    ``halo`` is an optional prefetched halo-block tree from ``halo_start``
    (the compute/communication overlap path); only the halo path consumes it.
    """
    import jax
    MIX_STATS["calls"] += 1
    path = select_mix_path(plan, ctx)
    MIX_STATS["path_" + path] += 1
    if path == "identity":
        return tree
    if path == "local":
        return _local_mix(tree, plan, r, key, ctx, keep=keep)
    if path == "halo":
        return _halo_mix(tree, plan, r, key, ctx, keep=keep, halo=halo)
    MIX_STATS["all_gathers"] += len(jax.tree_util.tree_leaves(tree))
    full = ctx.gather(tree)
    return ctx.scatter_like(mix_stacked(full, plan, r, key, keep=keep), full)
