"""Per-link byte/hop accounting over a communication graph.

Extends ``repro.core.p2p.P2PNetwork``'s flat message log with topology
awareness: messages between non-adjacent nodes are relayed along shortest
paths, every physical link traversal is logged as its own ``Message`` (with
its hop position), and gossip rounds log one payload per alive directed
edge. Everything here is host-side — it runs at the engine's eval
boundaries, mirroring exactly the cohorts/faults the traced rounds realized
(``repro.topology.faults.host_fault_masks``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def shortest_hops(adjacency: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """All-pairs BFS. Returns ``(dist, next_hop)``: dist[i, j] = hop count
    (-1 if unreachable), next_hop[i, j] = the neighbor of i on one shortest
    i→j path (i itself when j == i or unreachable)."""
    adj = np.asarray(adjacency, bool)
    M = adj.shape[0]
    dist = np.full((M, M), -1, np.int32)
    next_hop = np.tile(np.arange(M, dtype=np.int32)[:, None], (1, M))
    for s in range(M):
        dist[s, s] = 0
        frontier = [s]
        while frontier:
            nxt = []
            for u in frontier:
                for v in np.nonzero(adj[u])[0]:
                    if dist[s, v] < 0:
                        dist[s, v] = dist[s, u] + 1
                        # first hop out of s toward v: inherit u's, unless u
                        # IS s (then the first hop is v itself)
                        next_hop[s, v] = v if u == s else next_hop[s, u]
                        nxt.append(int(v))
            frontier = nxt
    return dist, next_hop


def route(next_hop: np.ndarray, dist: np.ndarray, src: int,
          dst: int) -> List[Tuple[int, int]]:
    """The link sequence of one shortest src→dst path; a single direct
    (src, dst) link when dst is unreachable (accounting degrades to the
    topology-free behavior rather than dropping the message)."""
    if src == dst:
        return []
    if dist[src, dst] < 0:
        return [(src, dst)]
    path, u = [], src
    while u != dst:
        v = int(next_hop[u, dst])
        path.append((u, v))
        u = v
    return path


def send_routed(net, src: int, dst: int, payload, kind: str, rnd: int,
                dist: Optional[np.ndarray],
                next_hop: Optional[np.ndarray]) -> int:
    """Log one logical message as its physical link traversals. Without a
    routing table this is exactly ``net.send`` (one direct message)."""
    if next_hop is None:
        return net.send(src, dst, payload, kind, rnd=rnd)
    total = 0
    for hop, (u, v) in enumerate(route(next_hop, dist, src, dst)):
        total += net.send(u, v, payload, kind, rnd=rnd, hop=hop)
    return total


def log_gossip_round(net, topology, stacked_params, rnd: int,
                     mask=None, keep: Optional[np.ndarray] = None,
                     kind: str = "gossip") -> int:
    """One gossip round's messages: every alive directed edge (i → j)
    carries i's own parameter slice. ``mask`` is the round's participation
    cohort (absent endpoints exchange nothing — matching the schedule's
    freeze semantics), ``keep`` the realized fault matrix from
    ``host_fault_masks`` (dropped links carry nothing). Directed (learned)
    graphs only pay for edges that carry weight: adjacency is the symmetric
    support union, so an i → j message exists iff receiver j actually reads
    i (W[j, i] > 0) — a no-op for symmetric families. Returns total bytes.
    """
    import jax
    topo = topology
    if hasattr(topo, "topologies"):          # time-varying: the round's slice
        topo = topo.topologies[rnd % len(topo.topologies)]
    total = 0
    for i, j in topo.edges():
        if mask is not None and (mask[i] <= 0 or mask[j] <= 0):
            continue
        if keep is not None and keep[i, j] <= 0:
            continue
        if topo.weights[j, i] <= 0:          # directed: j never reads i
            continue
        own = jax.tree_util.tree_map(lambda t: t[i], stacked_params)
        total += net.send(i, j, own, kind, rnd=rnd)
    return total


def per_link_summary(net, kind: Optional[str] = None) -> Dict[str, float]:
    """Aggregate the per-link ledger into sweep-record scalars."""
    links = net.per_link(kind)
    if not links:
        return {"links_used": 0, "bytes_total": 0, "bytes_per_link_max": 0,
                "hops_total": 0}
    byte_counts = list(links.values())
    return {"links_used": len(links),
            "bytes_total": int(sum(byte_counts)),
            "bytes_per_link_max": int(max(byte_counts)),
            "hops_total": net.total_hops(kind)}
