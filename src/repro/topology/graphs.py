"""Communication graphs for the P2P layer.

The paper's evaluation only ever exercises two implicit topologies — the
hardcoded ring inside DP-DSGT and the all-to-all inside a P4 group — yet the
collaboration graph is a first-class object in decentralized learning
(Bellet et al. 2018; MAPL 2024): its spectral gap bounds gossip mixing time
and therefore how fast personalization information propagates. This module
makes the graph explicit: a ``Topology`` is a symmetric adjacency plus a
doubly-stochastic mixing matrix W, hashable BY VALUE so it can key the
engine's compiled-chunk cache, with optional link-drop / node-churn fault
rates that the mixing step draws in-jit each round (``repro.topology.faults``).

Time-varying randomized gossip (pairwise averaging over a fresh random
matching each round) is a ``TimeVaryingTopology``: a periodic sequence of
static topologies the mixing plan indexes with ``r % period`` inside the
scanned round body.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.topology.mixing import (is_connected, metropolis_weights,
                                   spectral_gap, uniform_weights)


@dataclass(eq=False)
class Topology:
    """A static communication graph + its mixing matrix.

    ``adjacency``: (M, M) bool, symmetric, zero diagonal.
    ``weights``:   (M, M) float64 doubly-stochastic symmetric W (diagonal
                   included) — the matrix gossip applies each round.
    ``drop_prob``: per-round probability an (undirected) link fails.
    ``churn_prob``: per-round probability a node is offline.

    Hashable by value (name, M, W bytes, fault rates) so strategies can put
    a topology in their chunk-cache fingerprint: equal topologies share
    compiled chunks, different ones can never collide.
    """

    name: str
    adjacency: np.ndarray
    weights: np.ndarray
    drop_prob: float = 0.0
    churn_prob: float = 0.0

    def __post_init__(self):
        adj = np.asarray(self.adjacency, bool)
        w = np.asarray(self.weights, np.float64)
        if adj.shape != w.shape or adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
            raise ValueError(f"adjacency/weights shape mismatch: "
                             f"{adj.shape} vs {w.shape}")
        if not np.array_equal(adj, adj.T):
            raise ValueError("adjacency must be symmetric")
        if np.any(np.diag(adj)):
            raise ValueError("adjacency must have a zero diagonal")
        self.adjacency = adj
        self.weights = w

    # ------------------------------------------------------------ properties
    @property
    def M(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1).astype(np.int64)

    @property
    def num_edges(self) -> int:
        """Undirected edge count."""
        return int(self.adjacency.sum()) // 2

    def edges(self) -> List[Tuple[int, int]]:
        """Directed edge list (both orientations) — the per-round message
        pattern one gossip exchange induces."""
        src, dst = np.nonzero(self.adjacency)
        return list(zip(src.tolist(), dst.tolist()))

    def is_connected(self) -> bool:
        return is_connected(self.adjacency)

    def spectral_gap(self) -> float:
        """1 − |λ₂(W)| — the gossip convergence rate (larger = faster)."""
        return spectral_gap(self.weights)

    def with_faults(self, drop_prob: float = 0.0,
                    churn_prob: float = 0.0) -> "Topology":
        return replace(self, drop_prob=float(drop_prob),
                       churn_prob=float(churn_prob))

    def describe(self) -> dict:
        """Host-side summary for sweep records / benchmark JSON."""
        return {"name": self.name, "clients": self.M,
                "edges": self.num_edges,
                "mean_degree": float(np.mean(self.degrees)) if self.M else 0.0,
                "spectral_gap": round(self.spectral_gap(), 6),
                "connected": self.is_connected(),
                "drop_prob": self.drop_prob, "churn_prob": self.churn_prob}

    # --------------------------------------------------------- value hashing
    def fingerprint(self) -> Tuple:
        # adjacency bytes must be part of the key: two graphs can share W
        # (e.g. any builder at self_weight=1.0 yields W = I) while differing
        # in support — and therefore in byte accounting, routing, and fault
        # masks. Hashing W alone let them collide in the compiled-chunk cache.
        return ("topology", self.name, self.M, self.adjacency.tobytes(),
                self.weights.tobytes(), self.drop_prob, self.churn_prob)

    def __hash__(self):
        return hash(self.fingerprint())

    def __eq__(self, other):
        return (isinstance(other, Topology)
                and other.fingerprint() == self.fingerprint())


@dataclass(eq=False)
class TimeVaryingTopology:
    """A periodic sequence of static topologies: round r mixes over
    ``topologies[r % period]`` (randomized-gossip matchings, alternating
    graph colorings, ...). Fault rates apply uniformly per round."""

    name: str
    topologies: Sequence[Topology] = field(default_factory=list)
    drop_prob: float = 0.0
    churn_prob: float = 0.0

    def __post_init__(self):
        if not self.topologies:
            raise ValueError("TimeVaryingTopology needs >= 1 topologies")
        Ms = {t.M for t in self.topologies}
        if len(Ms) != 1:
            raise ValueError(f"member topologies disagree on M: {sorted(Ms)}")

    @property
    def M(self) -> int:
        return self.topologies[0].M

    @property
    def period(self) -> int:
        return len(self.topologies)

    def union_adjacency(self) -> np.ndarray:
        out = np.zeros((self.M, self.M), bool)
        for t in self.topologies:
            out |= t.adjacency
        return out

    def is_connected(self) -> bool:
        """Connectivity of the union graph — what gossip needs over a full
        period for information to reach everyone."""
        return is_connected(self.union_adjacency())

    def spectral_gap(self) -> float:
        """Gap of the period-averaged mixing matrix (the expected one-round
        contraction of the randomized sequence)."""
        return spectral_gap(
            np.mean([t.weights for t in self.topologies], axis=0))

    def with_faults(self, drop_prob: float = 0.0,
                    churn_prob: float = 0.0) -> "TimeVaryingTopology":
        return replace(self, drop_prob=float(drop_prob),
                       churn_prob=float(churn_prob))

    def describe(self) -> dict:
        return {"name": self.name, "clients": self.M, "period": self.period,
                "edges": int(self.union_adjacency().sum()) // 2,
                "spectral_gap": round(self.spectral_gap(), 6),
                "connected": self.is_connected(),
                "drop_prob": self.drop_prob, "churn_prob": self.churn_prob}

    def fingerprint(self) -> Tuple:
        return (("time-varying", self.name, self.drop_prob, self.churn_prob)
                + tuple(t.fingerprint() for t in self.topologies))

    def __hash__(self):
        return hash(self.fingerprint())

    def __eq__(self, other):
        return (isinstance(other, TimeVaryingTopology)
                and other.fingerprint() == self.fingerprint())


# ---------------------------------------------------------------------------
# Builders. Every builder returns a symmetric, (where possible) connected
# graph with a doubly-stochastic W: ``weighting="uniform"`` uses the lazy
# self-weight rule (regular graphs only — the DP-DSGT ring's historical
# 1/2–1/4–1/4 row is self_weight=0.5), ``weighting="metropolis"`` works on
# any graph.
# ---------------------------------------------------------------------------


def _weights_for(adj: np.ndarray, weighting: str, self_weight: float):
    if weighting == "uniform":
        return uniform_weights(adj, self_weight)
    if weighting == "metropolis":
        return metropolis_weights(adj)
    raise ValueError(f"unknown weighting {weighting!r}; "
                     "expected uniform | metropolis")


def _adj_from_offsets(M: int, offsets: Sequence[int]) -> np.ndarray:
    """Circulant adjacency: i ~ (i ± o) mod M for each offset."""
    adj = np.zeros((M, M), bool)
    idx = np.arange(M)
    for o in offsets:
        o = int(o) % M
        if o == 0:
            continue
        adj[idx, (idx + o) % M] = True
        adj[(idx + o) % M, idx] = True
    np.fill_diagonal(adj, False)
    return adj


def ring(M: int, self_weight: float = 0.5, *,
         weighting: str = "uniform") -> Topology:
    """The cycle graph — DP-DSGT's historical topology. The default
    ``self_weight=0.5`` uniform weighting reproduces the pre-refactor
    ``_ring_mix`` row (1/2 self, 1/4 per neighbor) exactly."""
    adj = _adj_from_offsets(M, [1]) if M > 1 else np.zeros((M, M), bool)
    return Topology(f"ring{M}", adj, _weights_for(adj, weighting, self_weight))


def fully_connected(M: int, *, weighting: str = "metropolis",
                    self_weight: float = 0.5) -> Topology:
    adj = ~np.eye(M, dtype=bool) if M > 1 else np.zeros((M, M), bool)
    return Topology(f"full{M}", adj, _weights_for(adj, weighting, self_weight))


def torus(rows: int, cols: Optional[int] = None, *,
          weighting: str = "metropolis", self_weight: float = 0.5) -> Topology:
    """2-D wraparound grid (4-regular when both dims > 2)."""
    cols = cols if cols is not None else rows
    M = rows * cols
    adj = np.zeros((M, M), bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for rr, cc in ((r, (c + 1) % cols), ((r + 1) % rows, c)):
                j = rr * cols + cc
                if i != j:
                    adj[i, j] = adj[j, i] = True
    return Topology(f"torus{rows}x{cols}", adj,
                    _weights_for(adj, weighting, self_weight))


def k_regular(M: int, k: int = 4, *, weighting: str = "metropolis",
              self_weight: float = 0.5) -> Topology:
    """Circulant k-regular graph with maximally spread offsets — the
    deterministic expander family (offsets ~ j·M/(k+1) instead of the
    nearest-neighbor lattice, so the diameter shrinks like M/k and the
    spectral gap grows with k)."""
    if k >= M:
        return fully_connected(M, weighting=weighting, self_weight=self_weight)
    # each offset in [1, (M-1)//2] contributes 2 to the degree, the antipodal
    # M/2 (even M) exactly 1; offset 1 anchors connectivity (gcd 1 with M)
    # and the rest spread across the half-circle for expansion
    n_off = max(1, k // 2)
    half = max(1, (M - 1) // 2)
    offsets, seen = [1], {1}
    for j in range(1, n_off):
        o = max(2, min(half, round(1 + j * (half - 1) / max(n_off - 1, 1))))
        while o in seen and o < half:
            o += 1
        seen.add(o)
        offsets.append(o)
    if k % 2 == 1 and M % 2 == 0 and M // 2 not in seen:
        offsets.append(M // 2)   # odd degree: the antipodal matching
    adj = _adj_from_offsets(M, offsets)
    return Topology(f"kreg{M}_{k}", adj,
                    _weights_for(adj, weighting, self_weight))


def exponential(M: int, *, weighting: str = "metropolis",
                self_weight: float = 0.5) -> Topology:
    """Symmetrized exponential graph (offsets 1, 2, 4, ... — ProxyFL's
    directed schedule as a static undirected topology): O(log M) degree,
    near-constant spectral gap."""
    offsets, o = [], 1
    while o <= M // 2:
        offsets.append(o)
        o *= 2
    adj = _adj_from_offsets(M, offsets or [1])
    return Topology(f"exp{M}", adj, _weights_for(adj, weighting, self_weight))


def erdos_renyi(M: int, p: float = 0.3, seed: int = 0, *,
                weighting: str = "metropolis", self_weight: float = 0.5,
                ensure_connected: bool = True) -> Topology:
    """G(M, p); with ``ensure_connected`` the draw is retried on a shifted
    seed and finally unioned with a ring (the standard connectivity patch)."""
    rng = np.random.default_rng(seed)
    for _ in range(64):
        u = rng.random((M, M))
        adj = np.triu(u < p, 1)
        adj = adj | adj.T
        if not ensure_connected or is_connected(adj):
            break
    else:
        adj = adj | _adj_from_offsets(M, [1])
    return Topology(f"er{M}_p{p:g}", adj,
                    _weights_for(adj, weighting, self_weight))


def small_world(M: int, k: int = 4, rewire_prob: float = 0.2, seed: int = 0, *,
                weighting: str = "metropolis", self_weight: float = 0.5,
                ensure_connected: bool = True) -> Topology:
    """Watts–Strogatz: ring lattice with k/2 neighbors per side, each edge
    rewired with probability ``rewire_prob`` (kept symmetric)."""
    rng = np.random.default_rng(seed)
    adj = _adj_from_offsets(M, range(1, max(1, k // 2) + 1))
    src, dst = np.nonzero(np.triu(adj, 1))
    for i, j in zip(src.tolist(), dst.tolist()):
        if rng.random() >= rewire_prob:
            continue
        candidates = [c for c in range(M)
                      if c != i and not adj[i, c]]
        if not candidates:
            continue
        new = int(rng.choice(candidates))
        adj[i, j] = adj[j, i] = False
        adj[i, new] = adj[new, i] = True
    if ensure_connected and not is_connected(adj):
        adj = adj | _adj_from_offsets(M, [1])
    return Topology(f"sw{M}_k{k}_p{rewire_prob:g}", adj,
                    _weights_for(adj, weighting, self_weight))


def group_clustered(groups: Sequence[Sequence[int]], M: Optional[int] = None,
                    *, bridge: bool = True, weighting: str = "metropolis",
                    self_weight: float = 0.5) -> Topology:
    """Complete subgraph inside every group (P4's "communicate only within
    your group" as an explicit graph); ``bridge`` adds a ring over the
    groups' first members so the global graph stays connected (the relay
    path inter-group messages would physically take)."""
    M = M if M is not None else (max(max(g) for g in groups) + 1)
    adj = np.zeros((M, M), bool)
    for g in groups:
        for a in g:
            for b in g:
                if a != b:
                    adj[a, b] = True
    if bridge and len(groups) > 1:
        heads = [g[0] for g in groups]
        for a, b in zip(heads, heads[1:] + heads[:1]):
            if a != b:
                adj[a, b] = adj[b, a] = True
    return Topology(f"groups{M}x{len(groups)}", adj,
                    _weights_for(adj, weighting, self_weight))


def gossip_matchings(M: int, period: int = 8, seed: int = 0, *,
                     self_weight: float = 0.5) -> TimeVaryingTopology:
    """Randomized pairwise gossip: each round of the period is a fresh
    random (near-)perfect matching; matched pairs average with weight
    ``1 - self_weight`` (0.5 = classic symmetric gossip). Odd M leaves one
    node idle per round (identity row — W stays doubly stochastic)."""
    rng = np.random.default_rng(seed)
    topos = []
    for t in range(max(1, period)):
        perm = rng.permutation(M)
        adj = np.zeros((M, M), bool)
        for a in range(0, M - 1, 2):
            i, j = int(perm[a]), int(perm[a + 1])
            adj[i, j] = adj[j, i] = True
        topos.append(Topology(f"match{M}_{t}", adj,
                              uniform_weights(adj, self_weight,
                                              allow_irregular=True)))
    return TimeVaryingTopology(f"gossip{M}_T{period}", topos)


# ---------------------------------------------------------------------------
# Config factory
# ---------------------------------------------------------------------------

def make_topology(cfg, M: int, groups=None):
    """Build the configured topology for M clients (``repro.config.
    TopologyConfig``). ``family="none"`` returns None — each strategy keeps
    its built-in pattern (DP-DSGT's ring, P4's group mean)."""
    fam = cfg.family
    if fam in ("none", None, ""):
        return None
    kw = dict(weighting=cfg.weighting, self_weight=cfg.self_weight)
    if fam == "ring":
        topo = ring(M, cfg.self_weight, weighting=cfg.weighting)
    elif fam == "full":
        topo = fully_connected(M, **kw)
    elif fam == "torus":
        rows = int(np.sqrt(M))
        while M % rows:
            rows -= 1
        topo = torus(rows, M // rows, **kw)
    elif fam == "kregular":
        topo = k_regular(M, cfg.k, **kw)
    elif fam == "exponential":
        topo = exponential(M, **kw)
    elif fam == "erdos":
        topo = erdos_renyi(M, cfg.p, cfg.seed, **kw)
    elif fam == "smallworld":
        topo = small_world(M, cfg.k, cfg.p, cfg.seed, **kw)
    elif fam == "group":
        if groups is None:
            raise ValueError("topology family 'group' needs formed groups")
        topo = group_clustered(groups, M, bridge=cfg.bridge, **kw)
    elif fam == "gossip":
        topo = gossip_matchings(M, cfg.period, cfg.seed,
                                self_weight=cfg.self_weight)
    else:
        raise ValueError(f"unknown topology family {fam!r}")
    if cfg.drop_prob > 0 or cfg.churn_prob > 0:
        topo = topo.with_faults(cfg.drop_prob, cfg.churn_prob)
    return topo
