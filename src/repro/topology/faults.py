"""Link-level fault injection, drawn in-jit like ``ClientSampling`` cohorts.

Per round, the gossip step draws

  * an (M, M) symmetric link-keep matrix — each undirected edge fails
    independently with ``drop_prob`` (one draw per edge, mirrored across the
    diagonal so (i→j) and (j→i) fail together: a dead link is dead in both
    directions);
  * an (M,) node-up mask — each node is offline with ``churn_prob``; an
    offline node's links all drop, so it neither sends nor receives and its
    mixing row degenerates to the identity.

The effective keep matrix multiplies the link draw by both endpoints' up
bits, staying symmetric; the mixing step moves every dropped slot's weight
onto the diagonal, so each realized matrix remains doubly stochastic — a
faulty gossip round still preserves the global mean (tested in the
``tests/test_topology.py`` property tier).

The draws key off ``fold_in(key, FAULT_STREAM)`` of the round's local-update
key, a stream nothing else consumes — fault-free runs are bit-identical to
history, and host-side byte accounting (``Strategy.log_communication``)
re-derives the exact realization from the engine's phase key. The same
function serves both: it is ordinary jax, eager on the host and traced in
the chunk.
"""
from __future__ import annotations

FAULT_STREAM = 0x70


def fault_key(key):
    """The per-round fault stream (disjoint from the batch/local/aggregate/
    cohort streams 0–3 and from the per-client key split)."""
    import jax
    return jax.random.fold_in(key, FAULT_STREAM)


def draw_fault_masks(key, M: int, drop_prob: float, churn_prob: float):
    """Returns ``(keep, up)``: the (M, M) float32 effective edge-keep matrix
    (symmetric; both-endpoints-up already folded in; diagonal 1 when both up)
    and the (M,) float32 node-up mask. Static zero rates skip their draw so
    the fault-free trace contains no PRNG ops at all."""
    import jax
    import jax.numpy as jnp
    kd, kc = jax.random.split(fault_key(key))
    if drop_prob > 0.0:
        u = jax.random.uniform(kd, (M, M))
        tri = jnp.triu(u, 1)
        u_sym = tri + tri.T              # one draw per undirected edge
        keep = (u_sym >= drop_prob).astype(jnp.float32)
        keep = jnp.where(jnp.eye(M, dtype=bool), 1.0, keep)
    else:
        keep = jnp.ones((M, M), jnp.float32)
    if churn_prob > 0.0:
        up = (jax.random.uniform(kc, (M,)) >= churn_prob).astype(jnp.float32)
    else:
        up = jnp.ones((M,), jnp.float32)
    keep = keep * up[:, None] * up[None, :]
    return keep, up


def host_fault_masks(phase_key, r: int, stream: int, M: int,
                     drop_prob: float, churn_prob: float):
    """Host-side twin for byte accounting: re-derive the exact keep/up
    realization the traced round used, from the engine's phase key and the
    stream the consuming hook draws on (1 = local_update for gossip mixes,
    2 = aggregate for P4's group faults)."""
    import jax
    import numpy as np
    rk = jax.random.fold_in(phase_key, r)
    keep, up = draw_fault_masks(jax.random.fold_in(rk, stream), M,
                                drop_prob, churn_prob)
    return np.asarray(keep), np.asarray(up)
