"""The paper's contribution: P4 = private decentralized grouping (Phase 1)
+ DP proxy/private knowledge-distillation co-training (Phase 2)."""
from repro.core.scattering import scatternet_features, scatter_feature_dim
from repro.core.dp import (clip_by_global_norm, noble_sigma, add_noise,
                           dp_gradients, rdp_epsilon, calibrate_sigma)
from repro.core.distill import proxy_loss, private_loss
from repro.core.grouping import (pairwise_l1, greedy_group_formation,
                                 random_groups, group_matrix)
from repro.core.p4 import P4Strategy, P4Trainer, make_p4_lm_step
