"""Phase 1 — decentralized group formation (paper §3.3, Eqs. 3–5).

Dissimilarity metric: ℓ1 norm between flattened model weights after the
first (DP) local step — sharing those weights costs no extra privacy budget
because they are already DP-protected (paper's argument).

The greedy decentralized procedure (verbatim from the paper):
  1. every client samples H random peers and measures ℓ1 dissimilarity;
  2. mutually-most-similar pairs form 2-member groups; unpaired clients join
     their most similar *ungrouped* peer; leftovers pair randomly;
  3. groups measure group-to-group dissimilarity (min over cross-member
     pairs, i.e. max similarity) using only similarities their members
     already computed, and merge greedily until |g| = T.

The M×M distance computation goes through ``repro.kernels.dispatch``
(symmetry-aware Pallas kernel on TPU, blocked pure-jnp reference on CPU).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import KernelConfig
from repro.utils.pytree import tree_flatten_concat


def flatten_clients(stacked_params) -> jnp.ndarray:
    """Stacked client params (M, ...) pytree -> (M, D) weight matrix."""
    return jax.vmap(tree_flatten_concat)(stacked_params)


def pairwise_l1(weights: jnp.ndarray,
                kernels: Optional[KernelConfig] = None) -> jnp.ndarray:
    """weights: (M, D) -> (M, M) ℓ1 distances (Eq. 3), backend-dispatched."""
    from repro.kernels import dispatch
    return dispatch.pairwise_l1(weights, kernels=kernels)


def greedy_group_formation(dist: np.ndarray, group_size: int,
                           sample_peers: int = 35, seed: int = 0,
                           neighborhoods: Optional[np.ndarray] = None,
                           ) -> List[List[int]]:
    """The paper's three-step greedy procedure. ``dist`` is the full M×M
    matrix; sampling masks it to H peers per client (decentralized view).

    ``neighborhoods`` (optional (M, M) boolean adjacency) restricts each
    client's peer sampling to its communication-graph neighbors — clients can
    only measure dissimilarity against peers they can actually reach, so group
    formation respects a configured topology instead of assuming a clique.
    """
    rng = np.random.default_rng(seed)
    M = dist.shape[0]
    H = min(sample_peers, M - 1)

    # -- sampled visibility mask (each client only knows H random peers) ----
    known = np.zeros((M, M), bool)
    for i in range(M):
        if neighborhoods is not None:
            cands = [j for j in range(M)
                     if j != i and bool(neighborhoods[i, j])]
        else:
            cands = [j for j in range(M) if j != i]
        h = min(H, len(cands))
        if h > 0:
            peers = rng.choice(cands, h, replace=False)
            known[i, peers] = True
    known |= known.T                      # measurements are symmetric
    masked = np.where(known, dist, np.inf)

    # -- step 2: mutual pairs ------------------------------------------------
    ungrouped = set(range(M))
    groups: List[List[int]] = []
    best = np.argmin(masked + np.where(np.eye(M, dtype=bool), np.inf, 0), axis=1)
    for i in range(M):
        j = int(best[i])
        if i < j and best[j] == i and i in ungrouped and j in ungrouped:
            groups.append([i, j])
            ungrouped -= {i, j}
    # unpaired clients join most-similar ungrouped peer
    for i in sorted(ungrouped):
        if i not in ungrouped:
            continue
        cands = [j for j in sorted(ungrouped) if j != i]
        if not cands:
            break
        j = min(cands, key=lambda j: masked[i, j])
        if not np.isfinite(masked[i, j]):
            j = int(rng.choice(cands))
        groups.append([i, j])
        ungrouped -= {i, j}
    for i in sorted(ungrouped):          # odd leftover joins a random pair
        if groups:
            groups[rng.integers(len(groups))].append(i)
        else:
            # no pair ever formed (M == 1, or every peer unreachable under a
            # restricted neighborhood): a degenerate singleton group is the
            # only valid answer — rng.integers(0) would raise
            groups.append([i])

    # -- step 3: merge groups until size T ----------------------------------
    def gdist(a: Sequence[int], b: Sequence[int]) -> float:
        # paper: group similarity ≈ max member-pair similarity (min distance)
        vals = [masked[i, j] for i in a for j in b if np.isfinite(masked[i, j])]
        return min(vals) if vals else np.inf

    while True:
        mergeable = [g for g in groups if len(g) < group_size]
        merged = False
        for g in list(mergeable):
            if g not in groups:
                continue
            partners = [h for h in groups
                        if h is not g and len(h) + len(g) <= group_size]
            if not partners:
                continue
            finite = [h for h in partners if np.isfinite(gdist(g, h))]
            h = (min(finite, key=lambda h: gdist(g, h)) if finite
                 else partners[rng.integers(len(partners))])
            groups.remove(g)
            groups.remove(h)
            groups.append(sorted(g + h))
            merged = True
        if not merged:
            break
    return [sorted(g) for g in groups]


def random_groups(M: int, group_size: int, seed: int = 0) -> List[List[int]]:
    """Ablation baseline (paper §4.4 i)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(M)
    return [sorted(perm[i : i + group_size].tolist())
            for i in range(0, M, group_size)]


def group_matrix(groups: List[List[int]], M: int) -> np.ndarray:
    """Binary symmetric collaboration matrix G (paper Eq. 4)."""
    G = np.zeros((M, M), np.int32)
    for g in groups:
        for i in g:
            for j in g:
                if i != j:
                    G[i, j] = 1
    return G


def group_ids(groups: List[List[int]], M: int) -> np.ndarray:
    ids = np.zeros((M,), np.int32)
    for gi, g in enumerate(groups):
        for i in g:
            ids[i] = gi
    return ids
