"""Differential privacy machinery (paper §3.3 Phase 2, Eqs. 10–12).

* ``clip_by_global_norm`` / ``add_noise`` — Eqs. 10–11.
* ``noble_sigma`` — Eq. 12 (Noble et al. 2022 bound, with l = M' = 1 in the
  P2P setting, as the paper sets them).
* ``rdp_epsilon`` / ``calibrate_sigma`` — Rényi-DP accountant for the
  subsampled Gaussian mechanism (Mironov 2017), used by the FedAvg/Scaffold
  baselines exactly as the paper describes (§4.2.1).
* ``dp_gradients`` — per-example (vmap, optionally chunked) or microbatch
  (lax.scan) clipped + noised gradients. Per-example is the paper-faithful
  path; microbatch is the LM-scale realization (DESIGN.md §2). The flat
  clip-scale-accumulate hot loop goes through ``repro.kernels.dispatch``
  (compiled Pallas on TPU, jnp reference on CPU, tile autotuning) as a fused
  pipeline that reads the (B, D) per-example matrix at most twice and draws
  the Eq. 11 noise once on the flat (D,) buffer.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import KernelConfig
from repro.utils.pytree import (global_norm, param_count, tree_flatten_concat,
                                tree_unflatten_concat)


# ---------------------------------------------------------------------------
# Eq. 10 — clipping
# ---------------------------------------------------------------------------

def clip_by_global_norm(tree, clip: float):
    """g ← g · min(1, C/‖g‖₂) (paper Eq. 10)."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# Eq. 11 — noise
# ---------------------------------------------------------------------------

def add_noise(tree, key, sigma: float, clip: float, denom: float):
    """H̃ = mean(g̃) + (2C/denom)·N(0, σ²)  (paper Eq. 11, denom = s·R).

    Per-leaf draws, deliberately: this serves the microbatch LM-scale path,
    where leaves are sharded model-parameter-sized arrays — flattening the
    tree into one (D,) vector would materialize an extra fp32 copy of the
    model and force a cross-shard gather. The per-example path noises on its
    already-flat buffer instead (repro.kernels.dispatch.dp_clip_flat).

    The scale is an explicit f32 product so a traced σ (the engine's runtime
    noise multiplier) rounds identically to a trace-baked constant σ."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    scale = jnp.float32(2.0 * clip / denom) * jnp.asarray(sigma, jnp.float32)
    noised = [
        g + (scale * jax.random.normal(k, g.shape, jnp.float32)).astype(g.dtype)
        for g, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, noised)


# ---------------------------------------------------------------------------
# Eq. 12 — Noble et al. σ bound (P2P: l = M' = 1)
# ---------------------------------------------------------------------------

def noble_sigma(epsilon: float, delta: float, *, sample_rate: float = 1.0,
                rounds: int = 100, local_steps: int = 1, client_ratio: float = 1.0,
                num_aggregated: int = 1) -> float:
    """σ_g = s·sqrt(l·T·K·log(2Tl/δ)·log(2/δ)) / (ε·sqrt(M'))  (Eq. 12)."""
    s, T, K, l, M = sample_rate, rounds, local_steps, client_ratio, num_aggregated
    return float(s * math.sqrt(l * T * K * math.log(2 * T * l / delta)
                               * math.log(2 / delta)) / (epsilon * math.sqrt(M)))


# ---------------------------------------------------------------------------
# RDP accountant (subsampled Gaussian) — closed form here; the stateful
# multi-segment ledger built on rdp_increment/rdp_to_epsilon lives in
# repro.engine.accounting.PrivacyLedger
# ---------------------------------------------------------------------------

_ORDERS = tuple([1.5, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 48, 64, 128])
RDP_ORDERS = _ORDERS


def _rdp_gaussian(sigma: float, alpha: float) -> float:
    return alpha / (2.0 * sigma ** 2)


def _log_comb(n, k):
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def _rdp_subsampled(q: float, sigma: float, alpha: int) -> float:
    """Mironov et al. computable bound for Poisson-subsampled Gaussian,
    integer α ≥ 2."""
    if q == 1.0:
        return _rdp_gaussian(sigma, alpha)
    if q == 0.0:
        return 0.0
    # log of sum_{k=0}^{alpha} C(alpha,k) (1-q)^{alpha-k} q^k exp(k(k-1)/(2σ²))
    logs = []
    for k in range(alpha + 1):
        log_term = (_log_comb(alpha, k) + (alpha - k) * math.log1p(-q)
                    + k * math.log(q) + (k * (k - 1)) / (2.0 * sigma ** 2))
        logs.append(log_term)
    m = max(logs)
    total = m + math.log(sum(math.exp(l - m) for l in logs))
    return total / (alpha - 1)


def rdp_increment(q: float, sigma: float, alpha: float) -> float:
    """Per-step RDP of the subsampled Gaussian at order ``alpha``.

    Additive across steps and across segments with different q — the unit
    the PrivacyLedger accumulates. Orders unusable under subsampling (the
    computable bound needs integer α ≥ 2 when q < 1) return ``inf`` so they
    drop out of the min without special-casing at the call site."""
    if q >= 1.0:
        return _rdp_gaussian(sigma, alpha)
    if alpha == int(alpha) and alpha >= 2:
        return _rdp_subsampled(q, sigma, int(alpha))
    return math.inf


def rdp_to_epsilon(rdp: float, alpha: float, delta: float) -> float:
    """RDP(α) → (ε, δ)-DP via the Balle et al. / Canonne conversion."""
    if not math.isfinite(rdp):
        return math.inf
    return rdp + math.log1p(-1.0 / alpha) - math.log(delta * alpha) / (alpha - 1)


def rdp_epsilon(sigma: float, q: float, steps: int, delta: float) -> float:
    """(ε, δ)-DP of ``steps`` compositions of the subsampled Gaussian."""
    return min(rdp_to_epsilon(steps * rdp_increment(q, sigma, alpha),
                              alpha, delta)
               for alpha in _ORDERS)


def calibrate_sigma(target_eps: float, delta: float, q: float, steps: int,
                    lo: float = 0.2, hi: float = 200.0) -> float:
    """Binary-search the smallest σ meeting (ε, δ) after ``steps`` rounds."""
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if rdp_epsilon(mid, q, steps, delta) > target_eps:
            lo = mid
        else:
            hi = mid
    return hi


# ---------------------------------------------------------------------------
# DP gradients — per-example (paper-faithful) and microbatch (LM-scale)
# ---------------------------------------------------------------------------

def _per_example_grad_fn(loss_fn: Callable):
    def one(p, ex):
        ex = jax.tree_util.tree_map(lambda t: t[None], ex)
        return jax.grad(loss_fn)(p, ex)
    return one


def dp_gradients(loss_fn: Callable, params, batch, key, *, clip: float,
                 sigma: float, microbatches: int = 0,
                 per_example_chunk: int = 0,
                 kernels: Optional[KernelConfig] = None):
    """Clipped + noised gradient of ``loss_fn(params, batch) -> scalar``.

    microbatches == 0 — exact per-example DP-SGD: vmap the gradient over the
    leading batch axis, then the fused dispatch pipeline (Eqs. 10–11):
    flatten→norm→scale→accumulate→noise, reading the (B, D) per-example
    matrix at most twice and drawing noise once on the flat (D,) buffer.
    ``per_example_chunk = c`` (c must divide B) scans B/c chunks of c
    vmapped examples into a flat (D,) accumulator — identical semantics, but
    peak memory is c× the parameter size instead of B×, so batch size is no
    longer capped by the per-example gradient stack.

    microbatches == k — LM-scale approximation: split the batch into k
    microbatches (lax.scan), clip each microbatch-mean gradient, average,
    noise. Exact per-example grads on a 72B model are memory-infeasible; this
    is the standard large-scale DP realization (DESIGN.md §2).

    ``kernels`` selects the kernel backend (repro.kernels.dispatch); None
    uses the default policy (compiled Pallas on TPU, jnp reference on CPU).
    """
    from repro.kernels import dispatch
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]

    if microbatches == 0:
        one = _per_example_grad_fn(loss_fn)
        c = per_example_chunk
        if c:
            # c must divide B (c == B degenerates to the full vmap below);
            # silently ignoring a bad chunk size would fall back to B× memory
            assert c <= n and n % c == 0, (n, c)
        if c and c < n:
            # chunked-vmap: per-example clipping is independent across
            # examples, so chunk clip-sums add exactly
            from repro.kernels.dp_clip.ref import add_flat_noise
            chunks = jax.tree_util.tree_map(
                lambda t: t.reshape((n // c, c) + t.shape[1:]), batch)

            def body(acc, bchunk):
                per_ex = jax.vmap(one, in_axes=(None, 0))(params, bchunk)
                flat = jax.vmap(tree_flatten_concat)(per_ex)     # (c, D)
                # denom folded into the per-example scales: chunk sums are
                # already /n, so their total is the mean — no extra (D,) pass
                return acc + dispatch.clip_accumulate(flat, clip,
                                                      denom=float(n),
                                                      kernels=kernels), None

            D = param_count(params)
            mean, _ = jax.lax.scan(body, jnp.zeros((D,), jnp.float32), chunks)
            out = add_flat_noise(mean, key, sigma, clip, float(n))
            return tree_unflatten_concat(out, params)
        per_ex = jax.vmap(one, in_axes=(None, 0))(params, batch)
        return dispatch.dp_clip(per_ex, clip, key, sigma=sigma,
                                denom=float(n), kernels=kernels)

    k = microbatches
    assert n % k == 0, (n, k)
    from repro.sharding.rules import shard_act
    mb = jax.tree_util.tree_map(
        lambda t: shard_act(t.reshape((k, n // k) + t.shape[1:]),
                            (None, "batch") + (None,) * (t.ndim - 1)),
        batch)

    def body(acc, mbatch):
        g = jax.grad(loss_fn)(params, mbatch)
        g, _ = clip_by_global_norm(g, clip)
        return jax.tree_util.tree_map(lambda a, b: a + b, acc, g), None

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    summed, _ = jax.lax.scan(body, zeros, mb)
    clipped_mean = jax.tree_util.tree_map(lambda s: s / k, summed)
    return add_noise(clipped_mean, key, sigma, clip, float(k))
