"""Deep-mutual-learning losses between proxy and private models
(paper Eqs. 6–9; Zhang et al. 2018 [60]).

The proxy model f_w is the ONLY thing shared with the group (trained with DP);
the private model f_θ never leaves the client and never sees DP noise — the
paper's central decoupling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import kl_divergence, softmax_cross_entropy


def _ce(logits, labels):
    return softmax_cross_entropy(logits, labels)


def proxy_loss(proxy_logits, private_logits, labels, alpha: float,
               temperature: float = 1.0):
    """Eq. 8: L_w = (1−α)·CE(f_w, y) + α·KL(f_w ‖ f_θ). The private logits are
    the *target* (stop-gradient), per deep mutual learning."""
    ce = _ce(proxy_logits, labels)
    kl = kl_divergence(proxy_logits, jax.lax.stop_gradient(private_logits),
                       temperature)
    return (1.0 - alpha) * ce + alpha * kl


def private_loss(private_logits, proxy_logits, labels, beta: float,
                 temperature: float = 1.0):
    """Eq. 9: L_θ = (1−β)·CE(f_θ, y) + β·KL(f_θ ‖ f_w)."""
    ce = _ce(private_logits, labels)
    kl = kl_divergence(private_logits, jax.lax.stop_gradient(proxy_logits),
                       temperature)
    return (1.0 - beta) * ce + beta * kl
