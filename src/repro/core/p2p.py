"""Simulated P2P transport — reproduces the paper's §4.5 overhead metrics.

The paper measures run time / memory / communication bandwidth on two
Raspberry Pis over websockets with pickle serialization. Here the transport
is an in-process message bus with the same serialization, so message *sizes*
are faithful and phase run times are measurable on this host (power draw is
hardware-gated → N/A; see DESIGN.md gate table).

Also implements the rotating-aggregator schedule of Phase 2 (Figure 1): every
``aggregator_rotation`` rounds the aggregating member advances round-robin so
communication load is spread across the group.
"""
from __future__ import annotations

import pickle
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import numpy as np


@dataclass
class Message:
    src: int
    dst: int
    kind: str
    nbytes: int
    rnd: int = -1      # round the message belongs to; -1 = not round-stamped


@dataclass
class P2PNetwork:
    num_clients: int
    log: List[Message] = field(default_factory=list)

    def send(self, src: int, dst: int, payload: Any, kind: str,
             rnd: int = -1) -> int:
        """Serialize exactly as the paper (pickle of numpy weights)."""
        host = jax.tree_util.tree_map(np.asarray, payload)
        nbytes = len(pickle.dumps(host, protocol=4))
        self.log.append(Message(src, dst, kind, nbytes, rnd))
        return nbytes

    def total_bytes(self, kind: str | None = None) -> int:
        return sum(m.nbytes for m in self.log if kind is None or m.kind == kind)

    def num_messages(self, kind: str | None = None) -> int:
        return sum(1 for m in self.log if kind is None or m.kind == kind)


def aggregator_for_round(group: List[int], rnd: int, rotation: int = 1) -> int:
    """Rotating aggregator (paper §3.3: 'one client can volunteer ... which
    can change during training to distribute communication overhead')."""
    return group[(rnd // max(rotation, 1)) % len(group)]


def simulate_group_round(net: P2PNetwork, group: List[int], proxy_params,
                         rnd: int, rotation: int = 1) -> Dict[str, float]:
    """Phase-2 communication pattern for one group and one round: members
    send proxy updates to the aggregator; aggregator broadcasts the mean."""
    agg = aggregator_for_round(group, rnd, rotation)
    for i in group:
        if i != agg:
            net.send(i, agg, proxy_params, "proxy_update", rnd=rnd)
    for i in group:
        if i != agg:
            net.send(agg, i, proxy_params, "aggregated_model", rnd=rnd)
    return {"aggregator": agg, "messages": 2 * (len(group) - 1)}


def simulate_phase1(net: P2PNetwork, client_weights, sample_pairs) -> float:
    """Phase-1 communication: each sampled pair exchanges model weights once
    (initiator sends; paper §4.5 measures the 622.82 kB weight message).

    ``client_weights`` is the stacked (M, ...) client pytree; each initiator
    i sends ONLY its own (D,) slice — sending the full stack would log M×
    the paper's per-message figure."""
    t0 = time.perf_counter()
    for (i, j) in sample_pairs:
        own = jax.tree_util.tree_map(lambda t: t[i], client_weights)
        net.send(i, j, own, "phase1_weights")
    return time.perf_counter() - t0
