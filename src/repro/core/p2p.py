"""Simulated P2P transport — reproduces the paper's §4.5 overhead metrics.

The paper measures run time / memory / communication bandwidth on two
Raspberry Pis over websockets with pickle serialization. Here the transport
is an in-process message bus with the same serialization, so message *sizes*
are faithful and phase run times are measurable on this host (power draw is
hardware-gated → N/A; see DESIGN.md gate table).

Also implements the rotating-aggregator schedule of Phase 2 (Figure 1): every
``aggregator_rotation`` rounds the aggregating member advances round-robin so
communication load is spread across the group.
"""
from __future__ import annotations

import pickle
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import numpy as np


@dataclass
class Message:
    src: int
    dst: int
    kind: str
    nbytes: int
    rnd: int = -1      # round the message belongs to; -1 = not round-stamped
    hop: int = 0       # position along a multi-hop relay route; 0 = first link


@dataclass
class P2PNetwork:
    num_clients: int
    log: List[Message] = field(default_factory=list)

    def send(self, src: int, dst: int, payload: Any, kind: str,
             rnd: int = -1, hop: int = 0) -> int:
        """Serialize exactly as the paper (pickle of numpy weights). One call
        = one physical link traversal; relayed messages log one call per hop
        (``repro.topology.accounting.send_routed``)."""
        host = jax.tree_util.tree_map(np.asarray, payload)
        nbytes = len(pickle.dumps(host, protocol=4))
        self.log.append(Message(src, dst, kind, nbytes, rnd, hop))
        return nbytes

    def total_bytes(self, kind: str | None = None) -> int:
        return sum(m.nbytes for m in self.log if kind is None or m.kind == kind)

    def num_messages(self, kind: str | None = None) -> int:
        return sum(1 for m in self.log if kind is None or m.kind == kind)

    # ------------------------------------------------- per-link accounting
    def per_link(self, kind: str | None = None) -> Dict[tuple, int]:
        """Bytes per directed physical link — the load-balance view a real
        deployment cares about (a relay-heavy topology concentrates traffic
        on bridge links even when per-client message counts look even)."""
        out: Dict[tuple, int] = {}
        for m in self.log:
            if kind is None or m.kind == kind:
                out[(m.src, m.dst)] = out.get((m.src, m.dst), 0) + m.nbytes
        return out

    def total_hops(self, kind: str | None = None) -> int:
        """Physical link traversals (every Message is exactly one)."""
        return self.num_messages(kind)

    def relayed_messages(self, kind: str | None = None) -> int:
        """Traversals beyond each logical message's first hop — the pure
        relay overhead a sparse topology pays over all-to-all."""
        return sum(1 for m in self.log
                   if (kind is None or m.kind == kind) and m.hop > 0)


def aggregator_for_round(group: List[int], rnd: int, rotation: int = 1) -> int:
    """Rotating aggregator (paper §3.3: 'one client can volunteer ... which
    can change during training to distribute communication overhead')."""
    return group[(rnd // max(rotation, 1)) % len(group)]


def simulate_group_round(net: P2PNetwork, group: List[int], proxy_params,
                         rnd: int, rotation: int = 1) -> Dict[str, float]:
    """Phase-2 communication pattern for one group and one round: members
    send proxy updates to the aggregator; aggregator broadcasts the mean."""
    agg = aggregator_for_round(group, rnd, rotation)
    for i in group:
        if i != agg:
            net.send(i, agg, proxy_params, "proxy_update", rnd=rnd)
    for i in group:
        if i != agg:
            net.send(agg, i, proxy_params, "aggregated_model", rnd=rnd)
    return {"aggregator": agg, "messages": 2 * (len(group) - 1)}


def simulate_phase1(net: P2PNetwork, client_weights, sample_pairs,
                    topology=None) -> float:
    """Phase-1 communication: each sampled pair exchanges model weights once
    (initiator sends; paper §4.5 measures the 622.82 kB weight message).

    ``client_weights`` is the stacked (M, ...) client pytree; each initiator
    i sends ONLY its own (D,) slice — sending the full stack would log M×
    the paper's per-message figure.

    ``topology`` (a ``repro.topology.Topology``) routes each exchange over
    the physical graph: non-adjacent pairs relay along shortest paths and
    every link traversal is logged (per-link byte/hop accounting)."""
    dist = next_hop = None
    if topology is not None:
        from repro.topology.accounting import shortest_hops
        dist, next_hop = shortest_hops(topology.adjacency)
    t0 = time.perf_counter()
    for (i, j) in sample_pairs:
        own = jax.tree_util.tree_map(lambda t: t[i], client_weights)
        if next_hop is None:
            net.send(i, j, own, "phase1_weights")
        else:
            from repro.topology.accounting import send_routed
            send_routed(net, i, j, own, "phase1_weights", -1, dist, next_hop)
    return time.perf_counter() - t0
