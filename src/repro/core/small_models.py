"""The paper's evaluation models (§4.1): a linear classifier (one layer +
softmax) and the Tramèr–Boneh CNN [47], both consuming either ScatterNet
features or raw images."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.module import ParamSpec, init_params


def linear_specs(feat_dim: int, num_classes: int):
    return {
        "w": ParamSpec((feat_dim, num_classes), ("embed", "vocab"), init="fan_in"),
        "b": ParamSpec((num_classes,), ("vocab",), init="zeros"),
    }


def linear_apply(params, x):
    """x: (B, feat) -> logits (B, classes)."""
    return jnp.einsum("bf,fc->bc", x, params["w"].astype(jnp.float32)) + params["b"]


def cnn_specs(in_ch: int, num_classes: int, width: int = 32):
    """Small CNN (conv-relu-pool ×2 + linear), applied to (B, C, H, W)."""
    return {
        "c1": ParamSpec((width, in_ch, 3, 3), (None, None, None, None), init="fan_in"),
        "c2": ParamSpec((2 * width, width, 3, 3), (None, None, None, None), init="fan_in"),
        "w": ParamSpec((0, num_classes), ("embed", "vocab"), init="fan_in"),  # resolved lazily
        "b": ParamSpec((num_classes,), ("vocab",), init="zeros"),
    }


def make_cnn(in_shape: Tuple[int, int, int], num_classes: int, width: int = 32):
    """Returns (specs, apply) with the linear head sized for ``in_shape``
    (C, H, W)."""
    C, H, W = in_shape
    h2, w2 = H // 4 or 1, W // 4 or 1
    feat = 2 * width * h2 * w2
    specs = {
        "c1": ParamSpec((width, C, 3, 3), (None, None, None, None), init="fan_in"),
        "c2": ParamSpec((2 * width, width, 3, 3), (None, None, None, None), init="fan_in"),
        "w": ParamSpec((feat, num_classes), ("embed", "vocab"), init="fan_in"),
        "b": ParamSpec((num_classes,), ("vocab",), init="zeros"),
    }

    def apply(params, x):
        """x: (B, C, H, W) [or (B, C*H*W) flattened] -> logits."""
        if x.ndim == 2:
            x = x.reshape(x.shape[0], C, H, W)
        def conv(t, k):
            return jax.lax.conv_general_dilated(
                t, k, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW"))
        x = jax.nn.relu(conv(x, params["c1"].astype(jnp.float32)))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
        x = jax.nn.relu(conv(x, params["c2"].astype(jnp.float32)))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
        x = x.reshape(x.shape[0], -1)
        return jnp.einsum("bf,fc->bc", x, params["w"].astype(jnp.float32)) + params["b"]

    return specs, apply


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
