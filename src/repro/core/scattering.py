"""ScatterNet handcrafted features (paper §4.2; Oyallon & Mallat 2015).

Depth-2 scattering with Morlet wavelets over 8 orientations and J=2 scales:

  S0 = φ * x                         (1 channel)
  S1 = φ * |ψ_{j,θ} * x|             (J·A = 16 channels)
  S2 = φ * |ψ_{1,θ2} * |ψ_{0,θ1}*x|| (A·A = 64 channels, j2 > j1)

→ 81 channels per input channel (matches the paper: 81 grayscale / 243 RGB),
spatially downsampled 2^J = 4× → (K, H/4, W/4).

TPU adaptation (DESIGN.md §2): direct convolution with precomputed real/imag
Morlet filterbanks via lax.conv_general_dilated (MXU conv units) instead of
kymatio's FFT path — at 28/32 px, direct conv is faster on TPU and avoids
complex-FFT lowering. The filterbank is cached per image geometry.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

ANGLES = 8
J = 2


def _morlet(size: int, scale: float, theta: float, xi: float = 3 * np.pi / 4):
    """Real/imag Morlet wavelet on a size×size grid at given scale/orientation."""
    half = size // 2
    y, x = np.mgrid[-half : half + 1, -half : half + 1].astype(np.float64)[:, :size, :size]
    rx = x * np.cos(theta) + y * np.sin(theta)
    ry = -x * np.sin(theta) + y * np.cos(theta)
    sigma = 0.8 * scale
    env = np.exp(-(rx ** 2 + ry ** 2 / (0.5 ** 2)) / (2 * sigma ** 2))
    wave = np.exp(1j * (xi / scale) * rx)
    psi = env * wave
    psi -= env * (env * wave).sum() / max(env.sum(), 1e-12)   # zero-mean correction
    psi /= max(np.abs(psi).sum(), 1e-12)
    return psi.real.astype(np.float32), psi.imag.astype(np.float32)


def _gaussian(size: int, scale: float):
    half = size // 2
    y, x = np.mgrid[-half : half + 1, -half : half + 1].astype(np.float64)[:, :size, :size]
    sigma = 0.8 * scale
    g = np.exp(-(x ** 2 + y ** 2) / (2 * sigma ** 2))
    return (g / g.sum()).astype(np.float32)


@functools.lru_cache(maxsize=8)
def _filterbank(size: int = 13):
    """Returns (psi_re, psi_im) each (J*A, size, size) and phi (size, size)."""
    re, im = [], []
    for j in range(J):
        for a in range(ANGLES):
            theta = np.pi * a / ANGLES
            r, i = _morlet(size, 2.0 ** j, theta)
            re.append(r)
            im.append(i)
    phi = _gaussian(size, 2.0 ** J)
    return np.stack(re), np.stack(im), phi


def _conv_same(x, filt):
    """x: (B, C, H, W); filt: (K, h, w) applied per input channel.
    Returns (B, C*K, H, W)."""
    B, C, H, W = x.shape
    K = filt.shape[0]
    kern = jnp.asarray(filt)[:, None, :, :]                    # (K, 1, h, w)
    kern = jnp.tile(kern, (C, 1, 1, 1))                        # (C*K, 1, h, w)
    return jax.lax.conv_general_dilated(
        x, kern, window_strides=(1, 1), padding="SAME",
        feature_group_count=C,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _lowpass_down(x, phi, stride: int):
    B, C, H, W = x.shape
    kern = jnp.asarray(phi)[None, None, :, :]
    kern = jnp.tile(kern, (C, 1, 1, 1))
    return jax.lax.conv_general_dilated(
        x, kern, window_strides=(stride, stride), padding="SAME",
        feature_group_count=C,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def scatter_feature_dim(image_shape: Tuple[int, int, int]) -> int:
    H, W, C = image_shape
    K = 1 + J * ANGLES + ANGLES * ANGLES * (J * (J - 1) // 2)  # 81
    return C * K * (H // 4) * (W // 4)


def scatternet_features(images, flatten: bool = True, normalize: bool = True):
    """images: (B, H, W, C) -> (B, C*81, H/4, W/4) [or flattened].

    Channel-wise normalization uses per-batch statistics — the paper computes
    these locally per client (no privacy cost, §4.2).
    """
    x = jnp.transpose(images, (0, 3, 1, 2)).astype(jnp.float32)  # (B,C,H,W)
    B, C, H, W = x.shape
    psi_re, psi_im, phi = _filterbank()
    A = ANGLES

    # order 1: modulus of wavelet responses, all J*A bands
    re = _conv_same(x, psi_re)                                  # (B, C*JA, H, W)
    im = _conv_same(x, psi_im)
    u1 = jnp.sqrt(re ** 2 + im ** 2 + 1e-12)                    # (B, C*JA, H, W)

    s0 = _lowpass_down(x, phi, 4)                               # (B, C, H/4, W/4)
    s1 = _lowpass_down(u1, phi, 4)                              # (B, C*JA, ...)

    # order 2: scale-0 bands re-filtered by scale-1 wavelets
    u1_j0 = u1.reshape(B, C, J * A, H, W)[:, :, :A].reshape(B, C * A, H, W)
    re2 = _conv_same(u1_j0, psi_re[A:])                         # scale-1 filters
    im2 = _conv_same(u1_j0, psi_im[A:])
    u2 = jnp.sqrt(re2 ** 2 + im2 ** 2 + 1e-12)                  # (B, C*A*A, H, W)
    s2 = _lowpass_down(u2, phi, 4)

    feats = jnp.concatenate([s0, s1, s2], axis=1)               # (B, C*81, H/4, W/4)
    if normalize:
        mu = jnp.mean(feats, axis=(0, 2, 3), keepdims=True)
        sd = jnp.std(feats, axis=(0, 2, 3), keepdims=True)
        feats = (feats - mu) / (sd + 1e-5)
    if flatten:
        feats = feats.reshape(B, -1)
    return feats
