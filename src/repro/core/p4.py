"""P4 — the paper's full algorithm (Phases 1+2), plus its LM-scale form.

Small-scale (paper-faithful): ``P4Trainer`` simulates M clients as stacked
(M, ...) parameter pytrees; local steps are vmapped across clients, group
aggregation is a segment-mean over proxy parameters, grouping is the greedy
decentralized procedure on first-step weights.

LM-scale (framework feature): ``make_p4_lm_step`` builds one jitted step over
G client *groups* (G = the ``pod`` mesh axis in multi-pod runs — DESIGN.md §4):
parameters carry a leading G dim sharded over ``pod``; vmap over G makes every
gradient reduction group-internal by construction, exactly the paper's
"communicate only within your group" topology.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
from repro.core import distill, dp as dp_lib
from repro.core.grouping import (flatten_clients, greedy_group_formation,
                                 group_ids, pairwise_l1, random_groups)
from repro.core.small_models import accuracy, linear_apply, linear_specs, make_cnn
from repro.engine import (Engine, FederatedData, PrivacyLedger, ShardedEngine,
                          Strategy, make_schedule, register_strategy,
                          runtime_sigma)
from repro.models.module import init_params


def group_mean(stacked_tree, ids: jnp.ndarray, num_groups: int):
    """Per-group mean of a stacked (M, ...) pytree, broadcast back to (M, ...)."""
    M = ids.shape[0]
    counts = jax.ops.segment_sum(jnp.ones((M,), jnp.float32), ids, num_groups)

    def f(x):
        sums = jax.ops.segment_sum(x, ids, num_groups)
        mean = sums / counts.reshape((-1,) + (1,) * (x.ndim - 1))
        return mean[ids].astype(x.dtype)

    return jax.tree_util.tree_map(f, stacked_tree)


def masked_group_mean(stacked_tree, ids: jnp.ndarray, num_groups: int, mask):
    """Group mean over the participating cohort only: absent members neither
    contribute to nor receive their group's mean (their slot keeps its own
    value). A group with no present members is left untouched."""
    counts = jax.ops.segment_sum(mask, ids, num_groups)

    def f(x):
        w = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        sums = jax.ops.segment_sum(x * w, ids, num_groups)
        denom = jnp.maximum(counts, 1.0).reshape((-1,) + (1,) * (x.ndim - 1))
        mean = (sums / denom)[ids].astype(x.dtype)
        return jnp.where(w > 0, mean, x)

    return jax.tree_util.tree_map(f, stacked_tree)


@dataclass(eq=False)  # hashable by identity (methods are jitted with static self)
class P4Trainer:
    feat_dim: int
    num_classes: int
    cfg: RunConfig
    model: str = "linear"                 # linear | cnn
    cnn_shape: Optional[Tuple[int, int, int]] = None  # (C, H, W) for model=cnn

    def __post_init__(self):
        if self.model == "linear":
            self.specs = linear_specs(self.feat_dim, self.num_classes)
            self.apply_fn = linear_apply
        else:
            self.specs, self.apply_fn = make_cnn(self.cnn_shape, self.num_classes)
        dpc = self.cfg.dp
        if dpc.noise_multiplier > 0:
            self.sigma = dpc.noise_multiplier
        elif dpc.enabled:
            delta = dpc.delta or 1e-3
            self.sigma = dp_lib.noble_sigma(
                dpc.epsilon, delta, sample_rate=dpc.sample_rate,
                rounds=dpc.rounds, local_steps=dpc.local_steps)
        else:
            self.sigma = 0.0

    # ------------------------------------------------------------------
    def init_clients(self, key, M: int):
        """COMMON initialization across clients (standard FL): Phase 1's ℓ1
        metric then measures data-driven weight divergence, not random-init
        distance — with per-client inits the metric is pure noise."""
        k1, k2 = jax.random.split(key)
        def bcast(k):
            p = init_params(self.specs, k)
            return jax.tree_util.tree_map(
                lambda t: jnp.broadcast_to(t[None], (M,) + t.shape), p)
        return {"private": bcast(k1), "proxy": bcast(k2)}

    # ------------------------------------------------------------------
    def _client_step(self, private, proxy, x, y, key, lr):
        """One local step for ONE client (vmapped across M)."""
        p4c, dpc = self.cfg.p4, self.cfg.dp

        private_logits = self.apply_fn(private, x)
        proxy_logits = self.apply_fn(proxy, x)

        # private model: clean gradient of Eq. 9
        def private_obj(theta):
            lg = self.apply_fn(theta, x)
            return distill.private_loss(lg, proxy_logits, y, p4c.beta,
                                        p4c.distill_temperature)
        g_priv = jax.grad(private_obj)(private)

        # proxy model: DP gradient of Eq. 8
        def proxy_obj(w, batch):
            lg = self.apply_fn(w, batch["x"])
            tgt = self.apply_fn(jax.lax.stop_gradient(private), batch["x"])
            return distill.proxy_loss(lg, tgt, batch["y"], p4c.alpha,
                                      p4c.distill_temperature)
        if dpc.enabled:
            g_prox = dp_lib.dp_gradients(
                proxy_obj, proxy, {"x": x, "y": y}, key,
                clip=dpc.clip_norm, sigma=runtime_sigma(self.sigma),
                microbatches=dpc.microbatches,
                per_example_chunk=dpc.per_example_chunk,
                kernels=self.cfg.kernels)
        else:
            g_prox = jax.grad(lambda w: proxy_obj(w, {"x": x, "y": y}))(proxy)

        new_private = jax.tree_util.tree_map(lambda p, g: p - lr * g, private, g_priv)
        new_proxy = jax.tree_util.tree_map(lambda p, g: p - lr * g, proxy, g_prox)
        metrics = {
            "private_loss": distill.private_loss(private_logits, proxy_logits, y,
                                                 p4c.beta),
            "proxy_loss": distill.proxy_loss(proxy_logits, private_logits, y,
                                             p4c.alpha),
        }
        return new_private, new_proxy, metrics

    # ------------------------------------------------------------------
    def _local_round_keyed(self, states, xs, ys, keys):
        """K local steps, one PRNG key per client row (the seam the sharded
        engine drives with the global key split's shard slice). Returns
        per-client metric vectors."""
        lr = self.cfg.train.learning_rate
        K = self.cfg.dp.local_steps

        def one_client(private, proxy, x, y, ckey):
            def body(carry, k):
                pr, px = carry
                pr, px, _ = self._client_step(pr, px, x, y,
                                              jax.random.fold_in(ckey, k), lr)
                return (pr, px), None
            (pr, px), _ = jax.lax.scan(body, (private, proxy), jnp.arange(K))
            _, _, metrics = self._client_step(pr, px, x, y,
                                              jax.random.fold_in(ckey, K), 0.0)
            return pr, px, metrics

        priv, prox, metrics = jax.vmap(one_client)(
            states["private"], states["proxy"], xs, ys, keys)
        return {"private": priv, "proxy": prox}, metrics

    def _local_round_impl(self, states, xs, ys, key):
        """K local steps for all clients. xs: (M, B, feat), ys: (M, B).
        Unjitted body — traced either by the jitted ``local_round`` below or
        inside the engine's scanned round loop."""
        M = ys.shape[0]
        return self._local_round_keyed(states, xs, ys,
                                       jax.random.split(key, M))

    @functools.partial(jax.jit, static_argnums=0)
    def local_round(self, states, xs, ys, key):
        return self._local_round_impl(states, xs, ys, key)

    # ------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=(0, 3))
    def aggregate(self, states, ids, num_groups: int):
        """Group-internal proxy aggregation (rotating aggregator in p2p.py)."""
        return {"private": states["private"],
                "proxy": group_mean(states["proxy"], ids, num_groups)}

    # ------------------------------------------------------------------
    def form_groups(self, states, seed: int = 0,
                    topology=None) -> List[List[int]]:
        """Phase-1 grouping. ``topology`` (optional) restricts each client's
        peer sampling to its communication-graph neighborhood — clients only
        measure similarity against peers they can reach (union adjacency for
        time-varying graphs)."""
        p4c = self.cfg.p4
        M = jax.tree_util.tree_leaves(states["proxy"])[0].shape[0]
        if p4c.similarity == "random":
            return random_groups(M, p4c.group_size, seed)
        weights = flatten_clients(states["proxy"])
        dist = np.asarray(pairwise_l1(weights, kernels=self.cfg.kernels))
        nbhd = None
        if topology is not None:
            nbhd = (topology.union_adjacency()
                    if hasattr(topology, "union_adjacency")
                    else topology.adjacency)
        return greedy_group_formation(dist, p4c.group_size,
                                      p4c.sample_peers, seed,
                                      neighborhoods=nbhd)

    # ------------------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def evaluate(self, states, xs, ys):
        """Per-client test accuracy of the PERSONALIZED (private) model."""
        def one(private, x, y):
            return accuracy(self.apply_fn(private, x), y)
        return jax.vmap(one)(states["private"], xs, ys)

    # ------------------------------------------------------------------
    def fit(self, train_x, train_y, test_x, test_y, *, rounds: Optional[int] = None,
            key=None, eval_every: int = 20, batch_size: Optional[int] = None,
            groups: Optional[List[List[int]]] = None, seed: int = 0,
            bootstrap_rounds: int = 4, network=None, checkpoint_dir=None,
            resume: bool = False, target_epsilon: Optional[float] = None,
            mesh=None):
        """Full P4 on the federation engine: a full-batch bootstrap phase
        (no aggregation, no eval), host-side grouping on the DP weights, then
        the co-training phase as one scan-chunked engine run.

        bootstrap_rounds > 1 trades a few pre-grouping rounds for grouping
        SNR: DP noise on the weights grows √k while the data-driven weight
        divergence grows k, so the ℓ1 metric's signal-to-noise improves √k
        (EXPERIMENTS.md §Paper-validation discusses the feasibility envelope
        n·√k the paper's own setup implicitly satisfies with R=200–300).

        ``network`` (a P2PNetwork) and ``checkpoint_dir`` are forwarded to the
        engine as hooks: §4.5 byte accounting and save/resume come from the
        same loop as training.

        The co-train phase runs under ``cfg.schedule``: its RoundSchedule
        (full / client-sampling / async) and, when DP is on and
        ``cfg.schedule.accountant == "rdp"``, a PrivacyLedger whose cumulative
        (ε, δ) is recorded in ``history.metrics`` at every eval round —
        bootstrap rounds are accounted at q = 1 (full batch, full
        participation). ``target_epsilon`` calibrates σ against the ledger for
        the whole run instead of using Eq. 12's σ.

        ``mesh`` (a mesh with a ``clients`` axis, e.g. ``make_client_mesh()``)
        runs BOTH phases on the ShardedEngine: state/data stacks sharded over
        the client axis, group aggregation as collectives (shard-resident
        groups aggregate without any gather — the small-scale twin of
        ``make_p4_lm_step``'s pod-manual layout). Histories are bit-identical
        to the single-device engine (tests/test_sharded_engine.py)."""
        rounds = rounds or self.cfg.dp.rounds
        key = key if key is not None else jax.random.PRNGKey(self.cfg.train.seed)
        M, R = train_y.shape
        bs = batch_size or max(8, int(self.cfg.dp.sample_rate * R))
        data = FederatedData(train_x, train_y, test_x, test_y)
        strategy = P4Strategy(trainer=self)
        nb = max(1, bootstrap_rounds)
        dpc = self.cfg.dp

        schedule = make_schedule(self.cfg.schedule)
        ledger = None
        if dpc.enabled and self.cfg.schedule.accountant == "rdp":
            ledger = PrivacyLedger(sigma=self.sigma, delta=dpc.delta or 1.0 / R,
                                   sample_rate=bs / R,
                                   client_rate=schedule.client_fraction(M),
                                   local_steps=dpc.local_steps)
            if target_epsilon is not None:
                # σ must be live before the bootstrap traces (the strategy
                # closes over trainer.sigma); the bootstrap segment runs at
                # q = 1, so calibrate over both segments
                self.sigma = ledger.calibrate_segments(
                    target_epsilon, [(nb, 1.0), (rounds - nb, None)])
        elif target_epsilon is not None:
            raise ValueError("target_epsilon needs dp.enabled and "
                             "schedule.accountant='rdp'")

        def make_engine(**kw):
            if mesh is not None:
                return ShardedEngine(strategy, mesh=mesh, **kw)
            return Engine(strategy, **kw)

        # bootstrap local steps on the FULL local dataset (paper §3.3: weights
        # after first local training; Eq. 11's noise scales with 1/n, so the
        # full batch + k rounds maximize the grouping signal-to-noise)
        bootstrap = make_engine(eval_every=eval_every)
        states, _ = bootstrap.fit(data, rounds=nb, key=jax.random.fold_in(key, 0),
                                  batch_size=None, evaluate=False)
        if ledger is not None:
            ledger.advance(nb, q=1.0)   # full batch, full participation
        # topology-aware formation: when the run has an explicit graph that
        # exists BEFORE grouping (any family but "group", which is derived
        # from the groups themselves), Phase-1 peer sampling is restricted
        # to graph neighborhoods — clients can only measure peers they reach
        topo_cfg = getattr(self.cfg, "topology", None)
        pre_topo = None
        if topo_cfg is not None and topo_cfg.family not in ("none", "group"):
            from repro.topology import make_topology
            pre_topo = make_topology(topo_cfg, M)
        if groups is None:
            groups = self.form_groups(states, seed, topology=pre_topo)
        strategy.set_groups(groups, M)
        if topo_cfg is not None and topo_cfg.family != "none":
            if pre_topo is not None:
                strategy.set_topology(pre_topo)
            else:
                from repro.topology import make_topology
                strategy.set_topology(make_topology(topo_cfg, M,
                                                    groups=groups))

        # cfg.faults drives the co-train phase only: the bootstrap is the
        # grouping signal, and a faulted bootstrap would conflate grouping
        # noise with the resilience behavior under study
        from repro.resilience import make_fault_process
        faults = make_fault_process(self.cfg.faults, M) \
            if getattr(self.cfg, "faults", None) is not None else None
        engine = make_engine(eval_every=eval_every, network=network,
                             checkpoint_dir=checkpoint_dir, schedule=schedule,
                             ledger=ledger, faults=faults)
        states, history = engine.fit(data, rounds=rounds,
                                     key=jax.random.fold_in(key, 1),
                                     batch_size=bs, start_round=nb,
                                     state=states, resume=resume)
        return states, groups, history


# ---------------------------------------------------------------------------
# Engine strategy: P4's co-training round as init/local_update/aggregate hooks
# ---------------------------------------------------------------------------

@register_strategy("p4")
@dataclass(eq=False)
class P4Strategy(Strategy):
    """P4 as an engine Strategy. Grouping is set between the bootstrap and
    co-training phases via ``set_groups`` (host-side — the greedy procedure
    is inherently sequential); until then ``aggregate`` is the identity."""
    trainer: P4Trainer = None
    groups: Optional[List[List[int]]] = None
    ids: Optional[jnp.ndarray] = None
    num_groups: int = 0

    @property
    def apply_fn(self):
        return self.trainer.apply_fn

    def set_groups(self, groups: List[List[int]], M: int) -> None:
        self.groups = groups
        self.ids = jnp.asarray(group_ids(groups, M))
        self.num_groups = len(groups)
        # padded member table for the in-jit rotating-aggregator lookup the
        # topology fault masks need: members[g, (r // rotation) % size_g]
        tmax = max(len(g) for g in groups)
        members = np.zeros((len(groups), tmax), np.int32)
        sizes = np.zeros((len(groups),), np.int32)
        for gi, g in enumerate(groups):
            members[gi, : len(g)] = g
            sizes[gi] = len(g)
        self._group_members = jnp.asarray(members)
        self._group_sizes = jnp.asarray(sizes)
        self.failover_count = 0  # rounds a group ran on a stand-in aggregator
        self.cache_token += 1    # aggregate() changed: invalidate engine chunks

    # ------------------------------------------------------------- topology
    def set_topology(self, topology) -> None:
        """Install the physical communication graph: group messages route
        along its shortest paths (per-link byte/hop accounting) and, with
        fault rates, member↔aggregator exchanges drop in-jit per round."""
        super().set_topology(topology)
        self._routing = None
        if topology is not None:
            from repro.topology.accounting import shortest_hops
            adj = (topology.union_adjacency()
                   if hasattr(topology, "topologies") else topology.adjacency)
            self._routing = shortest_hops(adj)

    def _has_faults(self) -> bool:
        t = self.topology
        return t is not None and (t.drop_prob > 0 or t.churn_prob > 0)

    def _aggregator_ids(self, r):
        """(M,) aggregator id per client at round r (traced) — the in-jit
        twin of ``p2p.aggregator_for_round`` over each client's own group."""
        rotation = max(self.trainer.cfg.p4.aggregator_rotation, 1)
        idx = (r // rotation) % self._group_sizes
        agg_per_group = self._group_members[
            jnp.arange(self.num_groups), idx]
        return agg_per_group[self.ids]

    def _fault_mask(self, r, key):
        """(M,) float32: 1 iff the client can reach this round's group
        aggregator — both endpoints up and the link alive. A churned
        aggregator takes its whole group's round down (every member masks to
        0, so the group mean leaves everyone untouched)."""
        from repro.topology.faults import draw_fault_masks
        M = self.ids.shape[0]
        t = self.topology
        keep, up = draw_fault_masks(key, M, t.drop_prob, t.churn_prob)
        agg = self._aggregator_ids(r)
        rows = jnp.arange(M)
        return jnp.where(rows == agg, up, keep[rows, agg])

    def _process_fault_mask(self, r, af):
        """(M,) reach mask under a correlated fault realization — with
        DETERMINISTIC FAILOVER: when the scheduled rotating aggregator is
        down, the next-up member (in rotation order) takes over; a group
        whose up-fraction is below the model's quorum — or with no member up
        at all — falls back to local-only for the round (mask 0 everywhere,
        so the masked group mean leaves every member untouched)."""
        real, quorum = af.real, af.model.quorum
        M = self.ids.shape[0]
        rotation = max(self.trainer.cfg.p4.aggregator_rotation, 1)
        members, sizes = self._group_members, self._group_sizes
        G, tmax = members.shape
        size = jnp.maximum(sizes, 1)
        idx = (r // rotation) % size                         # scheduled slot
        js = jnp.arange(tmax)
        cand_slot = (idx[:, None] + js[None, :]) % size[:, None]
        cand = members[jnp.arange(G)[:, None], cand_slot]    # (G, tmax)
        valid = (js[None, :] < sizes[:, None]).astype(jnp.float32)
        cand_up = real.up[cand] * valid
        first = jnp.argmax(cand_up, axis=1)      # first up in rotation order
        has_up = jnp.max(cand_up, axis=1)
        agg_g = cand[jnp.arange(G), first]
        up_counts = jax.ops.segment_sum(real.up, self.ids, self.num_groups)
        frac_up = up_counts / size.astype(jnp.float32)
        group_ok = ((has_up > 0) & (frac_up >= quorum)).astype(jnp.float32)
        agg = agg_g[self.ids]
        rows = jnp.arange(M)
        reach = jnp.where(rows == agg, real.up[rows], real.keep[rows, agg])
        return reach * group_ok[self.ids]

    def _context_fault_mask(self, r):
        """The correlated-process reach mask when the engine has a fault
        process installed (trace-time context), else None. Supersedes the
        topology's i.i.d. rates."""
        from repro.resilience import current_faults
        af = current_faults()
        if af is None or self.ids is None:
            return None
        return self._process_fault_mask(r, af)

    def init(self, key, data: FederatedData, batch_size):
        return self.trainer.init_clients(key, data.num_clients)

    def local_update(self, states, xs, ys, r, key):
        states, metrics = self.trainer._local_round_impl(states, xs, ys, key)
        return states, {k: jnp.mean(v) for k, v in metrics.items()}

    def local_update_keyed(self, states, xs, ys, r, keys):
        return self.trainer._local_round_keyed(states, xs, ys, keys)

    def aggregate(self, states, r, key):
        if self.ids is None:          # bootstrap phase: no groups yet
            return states
        cfm = self._context_fault_mask(r)
        if cfm is not None:
            return {"private": states["private"],
                    "proxy": masked_group_mean(states["proxy"], self.ids,
                                               self.num_groups, cfm)}
        if self._has_faults():
            # fault-injected round: only members whose link to this round's
            # aggregator survived exchange proxies (same masked-mean math as
            # partial participation — a dropped member keeps its own proxy)
            fm = self._fault_mask(r, key)
            return {"private": states["private"],
                    "proxy": masked_group_mean(states["proxy"], self.ids,
                                               self.num_groups, fm)}
        return {"private": states["private"],
                "proxy": group_mean(states["proxy"], self.ids, self.num_groups)}

    def aggregate_masked(self, states, r, key, mask):
        """Partial participation: the group mean runs over the round's cohort
        only — absent members' proxies are neither read nor overwritten.
        Link faults compose multiplicatively with the cohort mask."""
        if self.ids is None:
            return states
        cfm = self._context_fault_mask(r)
        if cfm is not None:
            mask = mask * cfm
        elif self._has_faults():
            mask = mask * self._fault_mask(r, key)
        return {"private": states["private"],
                "proxy": masked_group_mean(states["proxy"], self.ids,
                                           self.num_groups, mask)}

    # ------------------------------------------------------- sharded engine
    def _groups_shard_resident(self, ctx) -> bool:
        """Host-side layout check: True iff every group's members live on one
        mesh slice — the paper's "communicate only within your group" becomes
        structural and aggregation needs NO collective at all (the
        small-scale twin of make_p4_lm_step's pod-manual layout)."""
        if self.groups is None:
            return False
        return all(len({i // ctx.m for i in g}) == 1 for g in self.groups)

    def _local_ids(self, ctx):
        """This shard's group ids; padded slots get the out-of-range id
        ``num_groups`` so segment sums drop them."""
        padded = np.full((ctx.M_pad,), self.num_groups, np.int32)
        padded[: ctx.M] = np.asarray(self.ids)
        return ctx.shard_rows(jnp.asarray(padded))

    def sharded_aggregate(self, states, r, key, ctx):
        if self.ids is None:
            return states
        if self._groups_shard_resident(ctx):
            # group-local layout: members and their mean never leave the
            # slice. masked_group_mean with the validity mask reproduces
            # group_mean's arithmetic bit-for-bit for real rows (counts are
            # identical, x·1.0 is exact) while padded rows keep their value.
            # Fault draws are replicated (same key on every slice), so the
            # sliced fault mask realizes the identical topology everywhere.
            cfm = self._context_fault_mask(r)
            if cfm is not None:
                local = ctx.shard_rows(cfm)
            elif self._has_faults():
                local = ctx.shard_rows(self._fault_mask(r, key))
            else:
                local = ctx.valid_mask()
            return {"private": states["private"],
                    "proxy": masked_group_mean(states["proxy"],
                                               self._local_ids(ctx),
                                               self.num_groups, local)}
        full = ctx.gather(states)
        return ctx.scatter_like(self.aggregate(full, r, key), full)

    def sharded_aggregate_masked(self, states, r, key, ctx, mask, local_mask):
        if self.ids is None:
            return states
        if self._groups_shard_resident(ctx):
            # local_mask is already zero on padded slots
            local = local_mask
            cfm = self._context_fault_mask(r)
            if cfm is not None:
                local = local * ctx.shard_rows(cfm)
            elif self._has_faults():
                local = local * ctx.shard_rows(self._fault_mask(r, key))
            return {"private": states["private"],
                    "proxy": masked_group_mean(states["proxy"],
                                               self._local_ids(ctx),
                                               self.num_groups, local)}
        full = ctx.gather(states)
        return ctx.scatter_like(self.aggregate_masked(full, r, key, mask),
                                full)

    def fingerprint(self):
        """Value-based chunk-cache key: only trace-relevant config enters, so
        an ε/σ sweep's points (which differ in dp.epsilon and the calibrated
        σ — both runtime) share compiled chunks whenever the formed groups
        coincide."""
        t, cfg = self.trainer, self.trainer.cfg
        groups = (None if self.groups is None
                  else tuple(tuple(g) for g in self.groups))
        topo = None if self.topology is None else self.topology.fingerprint()
        return ("p4", self.cache_token, t.model, t.feat_dim, t.num_classes,
                t.cnn_shape, cfg.p4, cfg.kernels, cfg.train.learning_rate,
                cfg.dp.enabled, cfg.dp.clip_norm, cfg.dp.local_steps,
                cfg.dp.microbatches, cfg.dp.per_example_chunk,
                isinstance(t.sigma, (int, float)) and t.sigma > 0,
                groups, self.num_groups, topo)

    def runtime_params(self):
        sigma = self.trainer.sigma
        if isinstance(sigma, (int, float)) and sigma > 0:
            return {"sigma": float(sigma)}
        return {}

    def set_sigma(self, sigma: float) -> None:
        """Target-ε calibration lands on the trainer (its σ is what
        ``_client_step`` reads at trace time — as the engine's runtime value,
        so recalibration does NOT invalidate compiled chunks)."""
        self.trainer.sigma = float(sigma)

    def eval_params(self, states):
        """Per-client PERSONALIZED (private) model."""
        return states["private"]

    def _host_failover_plan(self, r: int, hf):
        """Numpy twin of ``_process_fault_mask``'s aggregator selection: per
        group ``(aggregator, ok, failed_over)`` for byte accounting and the
        fault sweep's failover counts."""
        rotation = max(self.trainer.cfg.p4.aggregator_rotation, 1)
        plan = []
        for g in self.groups:
            size = len(g)
            idx = (r // rotation) % size
            agg, failed_over = None, False
            for j in range(size):
                cand = g[(idx + j) % size]
                if hf.up[cand] > 0:
                    agg, failed_over = cand, j > 0
                    break
            frac_up = float(sum(hf.up[i] for i in g)) / size
            ok = agg is not None and frac_up >= hf.model.quorum
            plan.append((agg, ok, failed_over))
        return plan

    def log_communication(self, net, states, r: int, mask=None,
                          phase_key=None, faults=None) -> None:
        """§4.5 Phase-2 accounting: members → rotating aggregator → members,
        one per-client proxy payload per message (matches
        ``p2p.simulate_group_round`` for the same groups — tested). Under a
        sampling schedule only the round's cohort exchanges messages: an
        absent client contributes zero bytes, and a group with fewer than two
        present members has nothing to aggregate.

        With a topology installed, messages route over the physical graph's
        shortest paths (one ``Message`` per link traversal — per-link
        byte/hop accounting), the aggregator is this round's full-group
        rotation (the same one the traced fault mask addresses), and the
        round's fault realization — re-derived from ``phase_key`` — zeroes
        the dropped member↔aggregator exchanges.

        With a correlated fault process (``faults`` — the engine's replayed
        ``HostFaults``), the aggregator is the traced failover choice
        (next-up member in rotation order), below-quorum groups fall silent
        (local-only), and ``self.failover_count`` tallies rounds a group ran
        on a stand-in aggregator."""
        if not self.groups:
            return
        rotation = self.trainer.cfg.p4.aggregator_rotation
        if faults is not None:
            from repro.topology.accounting import send_routed
            dist, next_hop = (self._routing if getattr(self, "_routing", None)
                              else (None, None))
            from repro.resilience.processes import FAULT_STATS
            for g, (agg, ok, failed_over) in zip(
                    self.groups, self._host_failover_plan(r, faults)):
                if not ok:
                    FAULT_STATS["quorum_silent_rounds"] += 1
                    continue
                senders = [i for i in g
                           if i != agg and (mask is None or mask[i] > 0)
                           and faults.keep[i, agg] > 0]
                if not senders:
                    continue
                if failed_over:
                    self.failover_count = getattr(self, "failover_count",
                                                  0) + 1
                    FAULT_STATS["failover_rounds"] += 1
                payload = jax.tree_util.tree_map(lambda t: t[g[0]],
                                                 states["proxy"])
                for i in senders:
                    send_routed(net, i, agg, payload, "proxy_update", r,
                                dist, next_hop)
                for i in senders:
                    send_routed(net, agg, i, payload, "aggregated_model", r,
                                dist, next_hop)
            return
        if self.topology is None:
            from repro.core.p2p import simulate_group_round
            for g in self.groups:
                present = g if mask is None else [i for i in g if mask[i] > 0]
                if len(present) < 2:
                    continue
                payload = jax.tree_util.tree_map(lambda t: t[g[0]],
                                                 states["proxy"])
                simulate_group_round(net, present, payload, rnd=r,
                                     rotation=rotation)
            return
        from repro.core.p2p import aggregator_for_round
        from repro.topology.accounting import send_routed
        keep = up = None
        if self._has_faults() and phase_key is not None:
            from repro.topology.faults import host_fault_masks
            keep, up = host_fault_masks(phase_key, r, 2, self.ids.shape[0],
                                        self.topology.drop_prob,
                                        self.topology.churn_prob)
        dist, next_hop = self._routing
        for g in self.groups:
            agg = aggregator_for_round(g, r, rotation)
            if up is not None and up[agg] <= 0:
                continue                  # churned aggregator: group idles
            present = [i for i in g
                       if (mask is None or mask[i] > 0)
                       and (i == agg or keep is None or keep[i, agg] > 0)]
            if len(present) < 2 or agg not in present:
                continue
            payload = jax.tree_util.tree_map(lambda t: t[g[0]],
                                             states["proxy"])
            for i in present:
                if i != agg:
                    send_routed(net, i, agg, payload, "proxy_update", r,
                                dist, next_hop)
            for i in present:
                if i != agg:
                    send_routed(net, agg, i, payload, "aggregated_model", r,
                                dist, next_hop)


# ---------------------------------------------------------------------------
# LM-scale P4 step (dry-run / production form)
# ---------------------------------------------------------------------------

def make_p4_lm_step(api_private, api_proxy, train_cfg: TrainConfig,
                    dp_cfg: DPConfig, p4_cfg: P4Config):
    """One jitted co-training step over G client groups (leading dim).

    params = {"private": (G, ...), "proxy": (G, ...)}; batch tokens (G, b, s).
    The G axis is sharded over "pod"; vmap over G keeps every reduction
    group-internal. Proxy gradients are microbatch-clipped + noised (the
    LM-scale DP realization); private gradients are clean.
    """
    from repro.models import transformer
    from repro.models.layers import kl_divergence, softmax_cross_entropy
    from repro.optim import make_optimizer

    cfg_t, cfg_w = api_private.cfg, api_proxy.cfg
    opt = make_optimizer(train_cfg)
    sigma = dp_cfg.noise_multiplier or dp_lib.noble_sigma(
        dp_cfg.epsilon, dp_cfg.delta or 1e-5, sample_rate=dp_cfg.sample_rate,
        rounds=dp_cfg.rounds, local_steps=dp_cfg.local_steps)

    def _logits(params, cfg, batch):
        lg, aux, _ = transformer.forward(params, cfg, batch)
        return lg, aux

    def per_group(theta, w, opt_t, opt_w, batch, key):
        tokens = batch["tokens"]
        # targets for mutual distillation (constant w.r.t. the other model)
        theta_logits = jax.lax.stop_gradient(_logits(theta, cfg_t, batch)[0])
        w_logits = jax.lax.stop_gradient(_logits(w, cfg_w, batch)[0])

        def private_obj(p, b):
            lg, aux = _logits(p, cfg_t, b)
            ce = softmax_cross_entropy(lg[:, :-1], b["tokens"][:, 1:])
            kl = kl_divergence(lg, b["w_logits"])
            return (1 - p4_cfg.beta) * ce + p4_cfg.beta * kl + aux

        def proxy_obj(p, b):
            lg, aux = _logits(p, cfg_w, b)
            ce = softmax_cross_entropy(lg[:, :-1], b["tokens"][:, 1:])
            kl = kl_divergence(lg, b["theta_logits"])
            return (1 - p4_cfg.alpha) * ce + p4_cfg.alpha * kl + aux

        bt = dict(batch, w_logits=w_logits)
        bw = dict(batch, theta_logits=theta_logits)
        g_theta = jax.grad(private_obj)(theta, bt)
        g_w = dp_lib.dp_gradients(proxy_obj, w, bw, key, clip=dp_cfg.clip_norm,
                                  sigma=sigma,
                                  microbatches=max(dp_cfg.microbatches, 1))
        new_theta, new_opt_t = opt.update(g_theta, opt_t, theta)
        new_w, new_opt_w = opt.update(g_w, opt_w, w)
        loss = softmax_cross_entropy(theta_logits[:, :-1], tokens[:, 1:])
        return new_theta, new_w, new_opt_t, new_opt_w, loss

    def _vmapped(params, opt_states, batch, key):
        G = batch["tokens"].shape[0]
        keys = jax.random.split(key, G)
        new_theta, new_w, opt_t, opt_w, loss = jax.vmap(per_group)(
            params["private"], params["proxy"],
            opt_states["private"], opt_states["proxy"], batch, keys)
        return ({"private": new_theta, "proxy": new_w},
                {"private": opt_t, "proxy": opt_w}, loss)

    def step(params, opt_states, batch, key):
        """Groups stacked on dim 0. If a mesh with a ``pod`` axis is active,
        the group dim is made MANUAL via partial shard_map — group-locality
        becomes structural (no partitioner guessing; §Perf hillclimb 3:
        vmap-only lowering leaked ~13 GB/step of embedding-gather traffic
        across pods, shard_map removes it by construction)."""
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import _CTX, shard_map_compat
        ctx = getattr(_CTX, "val", None)
        mesh = ctx[0] if ctx else None
        # NOTE: partial-manual shard_map over "pod" is the structurally right
        # tool but crashes this XLA version's SPMD partitioner (fatal check in
        # spmd_partitioner_util.cc) when nested auto axes remain — kept behind
        # a flag; the shipping fix is untied embeddings + unsharded gather
        # table (§Perf hillclimb 3, iter 3). The small-scale twin of this
        # layout is the ShardedEngine client mesh (same compat wrapper).
        if (p4_cfg.manual_pod and mesh is not None
                and "pod" in getattr(mesh, "axis_names", ())):
            pspec = lambda tree: jax.tree_util.tree_map(lambda _: P("pod"), tree)

            def body(p, o, b, k):
                new_p, new_o, loss = _vmapped(p, o, b, k)
                return new_p, new_o, jax.lax.pmean(jnp.mean(loss), "pod")

            new_params, new_opt, loss = shard_map_compat(
                body, mesh,
                in_specs=(pspec(params), pspec(opt_states), pspec(batch), P()),
                out_specs=(pspec(params), pspec(opt_states), P()),
                manual_axes={"pod"},
            )(params, opt_states, batch, key)
            return new_params, new_opt, {"loss": loss}
        new_params, new_opt, loss = _vmapped(params, opt_states, batch, key)
        return new_params, new_opt, {"loss": jnp.mean(loss)}

    return step
