"""Synthetic LM token pipeline for the assigned-architecture drivers.

Generates structured token streams (order-k Markov chains over the vocab) so
the ~100M-parameter end-to-end training example has a learnable signal and a
measurable falling loss, not uniform noise.
"""
from __future__ import annotations

import numpy as np


def synth_token_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int,
                      period: int = 17):
    """Tokens with periodic + local structure: t[i] depends on t[i-1] and a
    global phase; next-token entropy is well below log(vocab)."""
    base = rng.integers(0, vocab, size=(batch, 1))
    steps = rng.integers(1, 7, size=(batch, seq))
    phase = (np.arange(seq) % period)[None, :]
    toks = (base + np.cumsum(steps, axis=1) + 3 * phase) % vocab
    return toks.astype(np.int32)


def token_stream(seed: int, batch: int, seq: int, vocab: int):
    rng = np.random.default_rng(seed)
    while True:
        yield synth_token_batch(rng, batch, seq, vocab)


def synth_token_batch_device(key, batch: int, seq: int, vocab: int,
                             period: int = 17):
    """Same structured stream as :func:`synth_token_batch`, but drawn with
    ``jax.random`` so it can live INSIDE a jitted step — the engine's scanned
    LM loop (``repro.engine.make_scan_steps``) never touches the host."""
    import jax
    import jax.numpy as jnp
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (batch, 1), 0, vocab)
    steps = jax.random.randint(k2, (batch, seq), 1, 7)
    phase = (jnp.arange(seq) % period)[None, :]
    return ((base + jnp.cumsum(steps, axis=1) + 3 * phase) % vocab).astype(jnp.int32)
