"""Client-side batching utilities for the P4 experiments."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def train_test_split(idx: np.ndarray, test_frac: float = 0.2, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(idx)
    n_test = max(1, int(len(perm) * test_frac))
    return perm[n_test:], perm[:n_test]


def client_batches(images: np.ndarray, labels: np.ndarray, idx: np.ndarray,
                   batch_size: int, rng: np.random.Generator):
    """One epoch of shuffled batches for a client's index set."""
    perm = rng.permutation(idx)
    for i in range(0, len(perm) - batch_size + 1, batch_size):
        sel = perm[i : i + batch_size]
        yield images[sel], labels[sel]


def stack_client_data(images, labels, client_idx: List[np.ndarray], n: int):
    """(M, n, ...) stacked arrays for vmapped multi-client training
    (clients are vmapped on the host CPU; on the production mesh each pod
    hosts a client group — see DESIGN.md §4)."""
    xs, ys = [], []
    for idx in client_idx:
        take = np.resize(idx, n)
        xs.append(images[take])
        ys.append(labels[take])
    return np.stack(xs), np.stack(ys)
