from repro.data.synthetic import make_image_task_pool, DATASET_STATS
from repro.data.partition import shard_partition, alpha_partition
from repro.data.pipeline import client_batches, train_test_split
from repro.data.tokens import synth_token_batch
