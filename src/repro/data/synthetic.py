"""Synthetic image-classification pools standing in for FEMNIST / CIFAR.

The container is offline (repro band 2 — data gate), so we generate
class-structured image data with the *same metadata* as the paper's Table 1
(L classes, M clients, R samples/client, 28×28×1 or 32×32×3) and the same
non-IID partitioners. Images are built from per-class template mixtures +
deformations so that (a) classes are separable but not trivially, (b) the
ScatterNet features genuinely help (templates carry multi-scale structure),
and (c) client heterogeneity drives the same accuracy ordering the paper
reports. Absolute accuracies are NOT comparable to the paper; orderings and
deltas are (EXPERIMENTS.md §Paper-validation).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# Paper Table 1.
DATASET_STATS = {
    "femnist": dict(L=47, M=200, R=300, shape=(28, 28, 1)),
    "cifar10": dict(L=10, M=260, R=200, shape=(32, 32, 3)),
    "cifar100": dict(L=100, M=60, R=250, shape=(32, 32, 3)),
}


def _class_templates(rng: np.random.Generator, L: int, shape: Tuple[int, int, int],
                     n_proto: int = 3):
    """Per-class prototype images with multi-scale structure: random low-
    frequency blobs + oriented gratings (scattering-friendly)."""
    H, W, C = shape
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    yy, xx = yy / H - 0.5, xx / W - 0.5
    protos = np.zeros((L, n_proto, H, W, C), np.float32)
    for l in range(L):
        for p in range(n_proto):
            img = np.zeros((H, W), np.float32)
            # 2-3 Gaussian blobs
            for _ in range(rng.integers(2, 4)):
                cy, cx = rng.uniform(-0.35, 0.35, 2)
                s = rng.uniform(0.05, 0.2)
                a = rng.uniform(0.5, 1.5)
                img += a * np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * s * s)))
            # one oriented grating (class-specific frequency/orientation)
            th = rng.uniform(0, np.pi)
            fr = rng.uniform(4, 12)
            ph = rng.uniform(0, 2 * np.pi)
            img += 0.7 * np.cos(2 * np.pi * fr * (xx * np.cos(th) + yy * np.sin(th)) + ph)
            img = (img - img.mean()) / (img.std() + 1e-6)
            for c in range(C):
                protos[l, p, :, :, c] = img * rng.uniform(0.7, 1.3)
    return protos


def make_image_task_pool(dataset: str, seed: int = 0, samples_per_class: int = 600,
                         noise: float = 0.35, M: int | None = None, R: int | None = None):
    """Returns (images (Ntot, H, W, C) float32 in [-x, x], labels (Ntot,) int32,
    stats dict). Samples are grouped so partitioners can draw per class."""
    stats = dict(DATASET_STATS[dataset])
    if M is not None:
        stats["M"] = M
    if R is not None:
        stats["R"] = R
    L = stats["L"]
    shape = stats["shape"]
    rng = np.random.default_rng(seed)
    protos = _class_templates(rng, L, shape)
    n_proto = protos.shape[1]
    images, labels = [], []
    for l in range(L):
        w = rng.dirichlet(np.ones(n_proto), size=samples_per_class).astype(np.float32)
        base = np.einsum("np,phwc->nhwc", w, protos[l])
        # random shifts (±2 px) as cheap deformation
        shifted = np.empty_like(base)
        for i in range(samples_per_class):
            dy, dx = rng.integers(-2, 3, 2)
            shifted[i] = np.roll(np.roll(base[i], dy, axis=0), dx, axis=1)
        x = shifted + noise * rng.standard_normal(base.shape).astype(np.float32)
        images.append(x)
        labels.append(np.full((samples_per_class,), l, np.int32))
    return np.concatenate(images), np.concatenate(labels), stats
