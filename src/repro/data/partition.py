"""The paper's two non-IID partitioners (§4.1).

shard-based (Li et al. [29]): L classes × P shards each; every client gets N
random classes with one random shard per class → M = L·P/N clients.

alpha-based (Hsu et al. [20] / Noble et al. [40]): per client, γ% of samples
drawn IID from all classes, (1−γ)% from one client-specific class.
"""
from __future__ import annotations

from typing import List

import numpy as np


def shard_partition(labels: np.ndarray, num_clients: int, classes_per_client: int,
                    samples_per_client: int, seed: int = 0) -> List[np.ndarray]:
    """Returns per-client index arrays. N = classes_per_client."""
    rng = np.random.default_rng(seed)
    L = int(labels.max()) + 1
    by_class = [rng.permutation(np.where(labels == l)[0]) for l in range(L)]
    per_class = samples_per_client // classes_per_client
    # P shards per class so that M * N = L * P
    P = int(np.ceil(num_clients * classes_per_client / L))
    shard_list = [(l, s) for l in range(L) for s in range(P)]
    rng.shuffle(shard_list)
    clients = []
    ptr = 0
    for _ in range(num_clients):
        idxs = []
        for _ in range(classes_per_client):
            l, s = shard_list[ptr % len(shard_list)]
            ptr += 1
            cls_idx = by_class[l]
            start = (s * per_class) % max(len(cls_idx) - per_class, 1)
            idxs.append(cls_idx[start : start + per_class])
        clients.append(np.concatenate(idxs))
    return clients


def alpha_partition(labels: np.ndarray, num_clients: int, gamma: float,
                    samples_per_client: int, seed: int = 0) -> List[np.ndarray]:
    """γ of each client's data IID over all classes; 1−γ from its own class."""
    rng = np.random.default_rng(seed)
    L = int(labels.max()) + 1
    all_idx = np.arange(len(labels))
    by_class = [np.where(labels == l)[0] for l in range(L)]
    clients = []
    for c in range(num_clients):
        own = c % L
        n_iid = int(round(gamma * samples_per_client))
        n_own = samples_per_client - n_iid
        iid_part = rng.choice(all_idx, n_iid, replace=True)
        own_part = rng.choice(by_class[own], n_own, replace=True)
        clients.append(np.concatenate([iid_part, own_part]))
    return clients
