"""Gated MLPs (SwiGLU) — the dense FFN used by every assigned transformer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import ParamSpec


def swiglu_specs(d_model: int, d_ff: int):
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "ffn"), init="fan_in"),
        "w_in": ParamSpec((d_model, d_ff), ("embed", "ffn"), init="fan_in"),
        "w_out": ParamSpec((d_ff, d_model), ("ffn", "embed"), init="fan_in"),
    }


def swiglu(params, x):
    dtype = x.dtype
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dtype))
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * h, params["w_out"].astype(dtype))
