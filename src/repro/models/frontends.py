"""Modality frontend STUBS (the one allowed carve-out, per the brief).

[vlm]   qwen2-vl: the ViT + merger is NOT implemented — ``input_specs()``
        provides precomputed patch embeddings (batch, vision_tokens, d_model)
        plus the (t, h, w) M-RoPE position streams the real merger would emit.
[audio] musicgen: the EnCodec conv codec is NOT implemented — ``input_specs()``
        provides precomputed frame embeddings (batch, seq, d_model); labels
        are the K-codebook token grid with the delay pattern applied in-loss.

These helpers generate *synthetic* frontend outputs with the right shapes and
plausible statistics for smoke tests / examples; the dry-run uses
ShapeDtypeStructs only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def synth_vision_embeds(key, cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    v = cfg.vision_tokens
    return jax.random.normal(key, (batch, v, cfg.d_model), jnp.float32).astype(dtype)


def synth_mrope_positions(cfg: ModelConfig, batch: int, seq: int, grid=(8, 8)):
    """(3, batch, seq) t/h/w positions: a vision grid followed by text tokens.

    Mirrors qwen2-vl's rule: vision patches advance (h, w) within a frame at a
    fixed t; text positions advance all three streams together starting after
    the vision block.
    """
    v = min(cfg.vision_tokens, seq)
    gh, gw = grid
    idx = jnp.arange(seq)
    t = jnp.where(idx < v, 0, idx - v + 1)
    h = jnp.where(idx < v, (idx // gw) % gh, idx - v + 1)
    w = jnp.where(idx < v, idx % gw, idx - v + 1)
    pos = jnp.stack([t, h, w]).astype(jnp.int32)                # (3, seq)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


def synth_audio_frames(key, cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32).astype(dtype)


def apply_delay_pattern(codes, pad_id: int = 0):
    """MusicGen delay pattern: codebook k is shifted right by k steps.

    codes: (batch, seq, K) -> delayed (batch, seq, K)."""
    b, s, K = codes.shape
    outs = []
    for k in range(K):
        shifted = jnp.pad(codes[:, : s - k, k], ((0, 0), (k, 0)), constant_values=pad_id)
        outs.append(shifted)
    return jnp.stack(outs, axis=-1)
