"""Minimal pytree-module system.

A model is described by a nested dict of :class:`ParamSpec` (shape + logical
dims + init), from which we derive, in one place:

  * concrete parameters            (``init_params``)
  * ``jax.ShapeDtypeStruct`` trees (``abstract_params``)   — for the dry-run
  * ``PartitionSpec`` trees        (``partition_specs``)   — from logical dims

This removes the usual duplication between "the model code" and "the sharding
map": every parameter names its logical dimensions exactly once.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dims: Tuple[Optional[str], ...]          # logical dim names (len == len(shape))
    init: str = "normal"                     # normal | zeros | ones | fan_in
    scale: float = 0.02
    dtype: Optional[str] = None              # override model param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaves_with_path(tree):
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)


def stack_specs(tree, num: int, dim_name: str = "layers"):
    """Prepend a stacking dimension (for ``lax.scan`` over layers)."""
    return jax.tree_util.tree_map(
        lambda s: dataclasses.replace(
            s, shape=(num,) + s.shape, dims=(dim_name,) + s.dims
        ),
        tree,
        is_leaf=is_spec,
    )


def init_params(spec_tree, key, param_dtype: str = "float32"):
    paths, treedef = _leaves_with_path(spec_tree)
    keys = jax.random.split(key, max(len(paths), 1))
    out = []
    for (path, spec), k in zip(paths, keys):
        dtype = jnp.dtype(spec.dtype or param_dtype)
        if spec.init == "zeros":
            v = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            v = jnp.ones(spec.shape, dtype)
        elif spec.init == "fan_in":
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            v = (jax.random.normal(k, spec.shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)
        else:  # normal
            v = (jax.random.normal(k, spec.shape, jnp.float32) * spec.scale).astype(dtype)
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(spec_tree, param_dtype: str = "float32"):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or param_dtype)),
        spec_tree,
        is_leaf=is_spec,
    )


def partition_specs(spec_tree, rules: dict):
    """Logical dims -> PartitionSpec via ``rules`` (dim name -> mesh axis or None).

    A mesh axis may appear only once in a spec; later duplicates are dropped
    (replicated) — this is what makes e.g. expert-parallel over the same axis
    as FSDP compose safely.
    """

    def one(spec: ParamSpec) -> PartitionSpec:
        used, axes = set(), []
        for d in spec.dims:
            ax = rules.get(d) if d is not None else None
            if ax is None:
                axes.append(None)
                continue
            ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
            ax_t = tuple(a for a in ax_t if a not in used)
            if not ax_t:
                axes.append(None)
            else:
                used.update(ax_t)
                axes.append(ax_t[0] if len(ax_t) == 1 else ax_t)
        return PartitionSpec(*axes)

    return jax.tree_util.tree_map(one, spec_tree, is_leaf=is_spec)


def named_shardings(spec_tree, rules: dict, mesh):
    pspecs = partition_specs(spec_tree, rules)
    return jax.tree_util.tree_map(
        lambda p: jax.sharding.NamedSharding(mesh, p), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )
