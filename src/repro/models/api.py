"""Public model API: build a model from a ModelConfig and get uniform
init / loss / prefill / decode entry points plus dry-run input specs.

Every step builder is a *pure function factory* — the returned callables are
jit-able and are exactly what ``launch/dryrun.py`` lowers onto the production
mesh and what ``launch/train.py`` executes for real.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import InputShape, MeshConfig, ModelConfig, TrainConfig
from repro.models import transformer
from repro.models.layers import softmax_cross_entropy
from repro.models.module import (abstract_params, init_params, partition_specs)
from repro.sharding.rules import batch_axes, logical_spec, make_rules
from jax.sharding import PartitionSpec as P


@dataclass
class ModelAPI:
    cfg: ModelConfig
    specs: Dict[str, Any]
    init: Callable
    abstract: Callable
    loss_fn: Callable          # (params, batch) -> (loss, metrics)
    prefill_fn: Callable       # (params, batch) -> (last_logits, cache)
    decode_fn: Callable        # (params, caches, batch) -> (logits, new_caches)
    init_caches: Callable      # (batch, max_seq) -> cache pytree


# ---------------------------------------------------------------------------
# Losses per family
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        logits, aux, _ = transformer.forward(params, cfg, batch)
        if cfg.family == "audio":
            from repro.models.frontends import apply_delay_pattern
            codes = apply_delay_pattern(batch["codes"])          # (b, s, K)
            ce = softmax_cross_entropy(logits[:, :-1], codes[:, 1:])
        else:
            tokens = batch["tokens"]
            mask = None
            if cfg.family == "vlm":
                # only text positions contribute to the loss
                v = cfg.vision_tokens
                s = tokens.shape[1]
                mask = (jnp.arange(1, s) >= v).astype(jnp.float32)[None, :]
                mask = jnp.broadcast_to(mask, (tokens.shape[0], s - 1))
            ce = softmax_cross_entropy(logits[:, :-1], tokens[:, 1:], mask=mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def make_prefill_fn(cfg: ModelConfig):
    """Prefill: full forward that also emits decode caches (KV for attention
    layers, final recurrent state for SSM/hybrid layers)."""
    def prefill_fn(params, batch):
        logits, _, caches = transformer.forward(
            params, cfg, batch, caches=None, return_kv=True, last_token_only=True)
        return logits, caches
    return prefill_fn


def make_decode_fn(cfg: ModelConfig):
    def decode_fn(params, caches, batch):
        logits, _, new_caches = transformer.forward(
            params, cfg, batch, caches=caches, cache_index=batch["index"],
            last_token_only=True)
        return logits, new_caches
    return decode_fn


def build_model(cfg: ModelConfig) -> ModelAPI:
    cfg.validate()
    specs = transformer.model_specs(cfg)
    return ModelAPI(
        cfg=cfg,
        specs=specs,
        init=lambda key: init_params(specs, key, cfg.param_dtype),
        abstract=lambda: abstract_params(specs, cfg.param_dtype),
        loss_fn=make_loss_fn(cfg),
        prefill_fn=make_prefill_fn(cfg),
        decode_fn=make_decode_fn(cfg),
        init_caches=lambda b, s: transformer.init_caches(cfg, b, s),
    )


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract model inputs for (arch × input-shape)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch: Dict[str, Any] = {"index": _sds((), jnp.int32)}
        if cfg.family == "audio":
            batch["frames"] = _sds((b, 1, cfg.d_model), cfg.dtype)
        else:
            batch["tokens"] = _sds((b, 1), jnp.int32)
        if cfg.family == "vlm":
            batch["vision_embeds"] = _sds((b, 0, cfg.d_model), cfg.dtype)
            batch["mrope_positions"] = _sds((3, b, 1), jnp.int32)
        return batch
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = _sds((b, s, cfg.d_model), cfg.dtype)
        batch["codes"] = _sds((b, s, cfg.audio_codebooks), jnp.int32)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = _sds((b, cfg.vision_tokens, cfg.d_model), cfg.dtype)
        batch["mrope_positions"] = _sds((3, b, s), jnp.int32)
    return batch


def input_shardings(cfg: ModelConfig, shape: InputShape, mesh_cfg: MeshConfig,
                    rules: Optional[dict] = None) -> Dict[str, Any]:
    """PartitionSpecs matching :func:`input_specs` (batch over data axes)."""
    rules = rules or make_rules(cfg, mesh_cfg, kind=shape.kind)
    bspec = logical_spec(("batch",), rules)[0]
    out: Dict[str, Any] = {}
    b = shape.global_batch
    def bsh(*rest):
        # batch=1 (long_500k) cannot shard over the data axes — replicate.
        return P(bspec if b > 1 else None, *rest)
    if shape.kind == "decode":
        out["index"] = P()
        if cfg.family == "audio":
            out["frames"] = bsh(None, None)
        else:
            out["tokens"] = bsh(None)
        if cfg.family == "vlm":
            out["vision_embeds"] = bsh(None, None)
            out["mrope_positions"] = P(None, bspec if b > 1 else None, None)
        return out
    if cfg.family == "audio":
        out["frames"] = bsh(None, None)
        out["codes"] = bsh(None, None)
    else:
        out["tokens"] = bsh(None)
    if cfg.family == "vlm":
        out["vision_embeds"] = bsh(None, None)
        out["mrope_positions"] = P(None, bspec if b > 1 else None, None)
    return out


def cache_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract decode caches (ShapeDtypeStructs) for serve_step lowering."""
    caches = jax.eval_shape(
        lambda: transformer.init_caches(cfg, shape.global_batch, shape.seq_len))
    return caches


def cache_shardings(cfg: ModelConfig, shape: InputShape, mesh_cfg: MeshConfig,
                    rules: Optional[dict] = None):
    rules = rules or make_rules(cfg, mesh_cfg, kind="decode")
    b = shape.global_batch
    baxes = rules["batch"] if b > 1 else None
    kv_heads_ax = rules.get("kv_heads")
    kv_seq_ax = rules.get("kv_seq") if b == 1 or kv_heads_ax is None else None

    b_global = shape.global_batch

    def spec_for(leaf_shape):
        # KV cache layout: (L, b, S, kvh, hd)
        if len(leaf_shape) == 5 and leaf_shape[2] == shape.seq_len:
            return P(None, baxes, kv_seq_ax, kv_heads_ax, None)
        # recurrent states: (units, [per,] b, ...) — find the batch dim
        axes = [None] * len(leaf_shape)
        if b_global > 1:
            for i in range(1, len(leaf_shape)):
                if leaf_shape[i] == b_global:
                    axes[i] = baxes
                    break
        return P(*axes)

    caches = cache_specs(cfg, shape)
    return jax.tree_util.tree_map(lambda l: spec_for(l.shape), caches)


# ---------------------------------------------------------------------------
# Step builders (what the dry-run lowers / the trainer executes)
# ---------------------------------------------------------------------------

def make_train_step(api: ModelAPI, train_cfg: TrainConfig):
    """Standard (non-P4) train step: grads -> optimizer update. This is the
    paper-baseline step for the 40-combination dry-run table."""
    from repro.optim import make_optimizer
    opt = make_optimizer(train_cfg)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(api.loss_fn, has_aux=True)(params, batch)
        new_params, new_opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt_state, metrics

    return train_step, opt


def make_serve_step(api: ModelAPI):
    """One decode step: append token, attend against cache, emit next logits."""
    def serve_step(params, caches, batch):
        logits, new_caches = api.decode_fn(params, caches, batch)
        next_token = jnp.argmax(logits[:, -1], axis=-1)
        return next_token, logits, new_caches
    return serve_step


def make_prefill_step(api: ModelAPI, shape: InputShape):
    return api.prefill_fn


def param_shardings(api: ModelAPI, mesh, rules):
    pspecs = partition_specs(api.specs, rules)
    return jax.tree_util.tree_map(
        lambda p: jax.sharding.NamedSharding(mesh, p), pspecs,
        is_leaf=lambda x: isinstance(x, P))
