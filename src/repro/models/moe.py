"""Mixture-of-Experts layer with capacity-based sorted dispatch.

Dispatch is gather/scatter based (no (tokens × experts × capacity) one-hot
tensors): token→expert assignments are ranked per-expert with a stable sort,
tokens beyond each expert's capacity are dropped (standard GShard semantics),
and expert FFNs run as one batched (E, C, d) × (E, d, f) einsum.

Sharding: the expert dim shards over the ``data`` axis when divisible
(expert parallelism — llama4's 128 and moonshot's 64 experts over 16-way
data); otherwise expert-internal dims shard over ``model`` (mixtral's 8
experts, tensor-parallel within each expert). The token gather across the
data axis is the all-to-all the roofline analysis attributes to MoE.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.module import ParamSpec
from repro.sharding.rules import shard_act


def moe_specs(cfg: ModelConfig, d_model=None):
    d = d_model or cfg.d_model
    E, f = cfg.moe.num_experts, cfg.d_ff
    spec = {
        "router": ParamSpec((d, E), ("embed", "experts_router"), init="fan_in"),
        "w_gate": ParamSpec((E, d, f), ("experts", "embed", "ffn"), init="fan_in"),
        "w_in": ParamSpec((E, d, f), ("experts", "embed", "ffn"), init="fan_in"),
        "w_out": ParamSpec((E, f, d), ("experts", "ffn", "embed"), init="fan_in"),
    }
    if cfg.moe.shared_expert:
        spec["shared"] = {
            "w_gate": ParamSpec((d, f), ("embed", "ffn"), init="fan_in"),
            "w_in": ParamSpec((d, f), ("embed", "ffn"), init="fan_in"),
            "w_out": ParamSpec((f, d), ("ffn", "embed"), init="fan_in"),
        }
    return spec


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    E, k = cfg.moe.num_experts, cfg.moe.experts_per_token
    cf = cfg.moe.capacity_factor or 1.25
    cap = int(tokens * k * cf / E)
    return max(8, ((cap + 7) // 8) * 8)  # 8-aligned for TPU lanes


def moe_apply(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (out, aux_loss).

    dispatch="global" (default): one global sort over all tokens — exact
    GShard capacity semantics, but under batch sharding the index-gather
    forces an all-gather of the full token buffer per layer (the dominant
    collective for MoE archs, see EXPERIMENTS.md §Roofline).

    dispatch="local": tokens are dispatched within their data shard with
    per-shard capacity C/S. When expert weights are NOT expert-parallel
    (mixtral: 8 experts < 16-way data axis, weights sharded over
    d_model/d_ff only), no token ever crosses a shard boundary — the MoE
    layer costs the same collectives as a dense TP layer (§Perf hillclimb 2).
    """
    b, s, d = x.shape
    T = b * s
    C = _capacity(T, cfg)
    xf = x.reshape(T, d)

    if cfg.moe.dispatch == "local":
        out, aux = _moe_local(params, xf, cfg, C)
        if out is not None:
            return out.reshape(b, s, d), aux
    out, aux = _moe_tokens(params, xf, cfg, C)
    return out.reshape(b, s, d), aux


def _moe_local(params, xf, cfg: ModelConfig, C: int):
    """shard_map realization of local dispatch: tokens never leave their data
    shard; expert FFNs stay tensor-parallel over ``model`` with an explicit
    psum; the only data-axis collective left is the (FSDP-style) weight
    gather at region entry."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import _CTX
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return None, None
    mesh, rules = ctx
    data_axes = rules.get("batch") or ("data",)
    data_axes = (data_axes,) if isinstance(data_axes, str) else tuple(data_axes)
    S = 1
    for a in data_axes:
        S *= int(mesh.shape.get(a, 1))
    msz = int(mesh.shape.get("model", 1))
    T, d = xf.shape
    E, f = cfg.moe.num_experts, cfg.d_ff
    if S == 1 or T % S or C % S or f % msz:
        return None, None

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map  # noqa: F811

    w_specs = {
        "router": P(),                        # (d, E) small — replicate
        "w_gate": P(None, None, "model"),     # ff tensor-parallel
        "w_in": P(None, None, "model"),
        "w_out": P(None, "model", None),
    }
    if cfg.moe.shared_expert:
        w_specs["shared"] = {"w_gate": P(None, "model"), "w_in": P(None, "model"),
                             "w_out": P("model", None)}
    local_params = {k: params[k] for k in w_specs}

    def body(p, x_local):
        out, aux = _moe_tokens_tp(p, x_local, cfg, C // S, model_axis="model")
        return out, jax.lax.pmean(aux, data_axes)

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(w_specs, P(data_axes, None)),
        out_specs=(P(data_axes, None), P()),
        check_vma=False,
    )(local_params, xf)
    return out, aux


def _moe_tokens_tp(params, xf, cfg: ModelConfig, C: int, model_axis: str):
    """_moe_tokens with the ffn contraction psum made explicit (shard_map)."""
    T, d = xf.shape
    E, k = cfg.moe.num_experts, cfg.moe.experts_per_token
    dtype = xf.dtype
    logits = jnp.einsum("td,de->te", xf, params["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce_frac = jnp.mean((jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)).sum(1), axis=0)
    aux = cfg.moe.aux_loss_weight * E * jnp.sum(me * ce_frac)

    flat_e = expert_ids.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - starts[se]
    keep = pos < C
    slot = se * C + jnp.where(keep, pos, 0)
    slot_tok = jnp.zeros((E * C,), jnp.int32).at[jnp.where(keep, slot, E * C - 1)].max(
        jnp.where(keep, st, 0).astype(jnp.int32), mode="drop")
    slot_used = jnp.zeros((E * C,), jnp.bool_).at[slot].max(keep, mode="drop")

    xs = xf[slot_tok].reshape(E, C, d)
    xs = xs * slot_used.reshape(E, C, 1).astype(dtype)
    g = jnp.einsum("ecd,edf->ecf", xs, params["w_gate"].astype(dtype))
    h = jnp.einsum("ecd,edf->ecf", xs, params["w_in"].astype(dtype))
    ys = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                    params["w_out"].astype(dtype))
    # TP combine in the activation dtype (bf16): halves the psum bytes; the
    # fp32 variant measured +17% memory term for no accuracy win at bf16
    # activations (EXPERIMENTS.md §Perf hillclimb 2, iter 3)
    ys = jax.lax.psum(ys, model_axis)
    ys = ys.reshape(E * C, d)

    out = jnp.zeros((T, d), dtype)
    w = jnp.where(keep, sg, 0.0).astype(dtype)
    out = out.at[st].add(ys[slot] * w[:, None], mode="drop")

    if cfg.moe.shared_expert:
        sh = params["shared"]
        sg_ = jax.nn.silu(jnp.einsum("td,df->tf", xf, sh["w_gate"].astype(dtype)))
        hh = jnp.einsum("td,df->tf", xf, sh["w_in"].astype(dtype))
        shared_out = jnp.einsum("tf,fd->td", sg_ * hh, sh["w_out"].astype(dtype))
        out = out + jax.lax.psum(shared_out.astype(jnp.float32), model_axis).astype(dtype)

    return out, aux


def _moe_tokens(params, xf, cfg: ModelConfig, C: int):
    """Capacity dispatch + expert FFN for flat tokens xf: (T, d)."""
    T, d = xf.shape
    E, k = cfg.moe.num_experts, cfg.moe.experts_per_token
    dtype = xf.dtype

    logits = jnp.einsum("td,de->te", xf, params["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch/Mixtral form).
    me = jnp.mean(probs, axis=0)                                # mean prob per expert
    ce_frac = jnp.mean(
        (jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)).sum(1), axis=0)  # token frac
    aux = cfg.moe.aux_loss_weight * E * jnp.sum(me * ce_frac)

    # ---- sorted capacity dispatch ------------------------------------------
    flat_e = expert_ids.reshape(-1)                             # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position of each assignment within its expert
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")   # (E,)
    pos = jnp.arange(T * k) - starts[se]
    keep = pos < C
    slot = se * C + jnp.where(keep, pos, 0)
    # token index per (expert, capacity) slot; empty slots -> token 0, weight 0
    slot_tok = jnp.zeros((E * C,), jnp.int32).at[jnp.where(keep, slot, E * C - 1)].max(
        jnp.where(keep, st, 0).astype(jnp.int32), mode="drop")
    slot_used = jnp.zeros((E * C,), jnp.bool_).at[slot].max(keep, mode="drop")

    xs = xf[slot_tok].reshape(E, C, d)                          # gather (all-to-all)
    xs = shard_act(xs, ("experts", "capacity", "embed_act"))
    xs = xs * slot_used.reshape(E, C, 1).astype(dtype)
    g = jnp.einsum("ecd,edf->ecf", xs, params["w_gate"].astype(dtype))
    h = jnp.einsum("ecd,edf->ecf", xs, params["w_in"].astype(dtype))
    ys = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, params["w_out"].astype(dtype))
    ys = ys.reshape(E * C, d)

    # ---- combine ------------------------------------------------------------
    out = jnp.zeros((T, d), dtype)
    w = jnp.where(keep, sg, 0.0).astype(dtype)
    contrib = ys[slot] * w[:, None]
    out = out.at[st].add(contrib, mode="drop")

    if cfg.moe.shared_expert:
        sh = params["shared"]
        sg_ = jax.nn.silu(jnp.einsum("td,df->tf", xf, sh["w_gate"].astype(dtype)))
        hh = jnp.einsum("td,df->tf", xf, sh["w_in"].astype(dtype))
        out = out + jnp.einsum("tf,fd->td", sg_ * hh, sh["w_out"].astype(dtype))

    return out, aux
