"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (arXiv:2409.12191) splits the head_dim/2 frequency channels into
(temporal, height, width) sections and rotates each section by a different
position stream; text tokens use identical (t,h,w) positions, so M-RoPE
degenerates to RoPE on pure text.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: broadcastable (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                     # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, sections, theta: float = 10000.0):
    """M-RoPE. x: (batch, seq, heads, head_dim); positions_thw: (3, batch, seq);
    sections: per-stream channel counts summing to head_dim // 2."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(hd, theta)                               # (half,)
    # Build a per-channel position by selecting the (t|h|w) stream per section.
    angle_parts = []
    off = 0
    for i, sec in enumerate(sections):
        pos = positions_thw[i]                                  # (batch, seq)
        angle_parts.append(pos[..., None].astype(jnp.float32) * freqs[off:off + sec])
        off += sec
    angles = jnp.concatenate(angle_parts, axis=-1)              # (batch, seq, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
