"""Mamba2 (State Space Duality) block — used by zamba2-7b [arXiv:2411.15242].

Chunked SSD algorithm (Dao & Gu 2024): within a chunk the recurrence is
evaluated as a masked quadratic form (MXU-friendly batched matmuls); across
chunks a lax.scan carries the (heads, head_dim, state) SSM state. Decode is
the O(1) recurrent update.

TPU adaptation (DESIGN.md §2): chunk length defaults to 128 so the intra-chunk
(c × c) decay-masked matmuls are MXU-aligned; the causal depthwise conv is a
width-4 sliding dot (unrolled shifts, no conv lowering needed).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.module import ParamSpec


def mamba2_specs(cfg: ModelConfig):
    d = cfg.d_model
    ssm = cfg.ssm
    d_inner = ssm.expand * d
    H = ssm.num_heads
    P = ssm.head_dim or d_inner // H
    N = ssm.state_dim
    cw = ssm.conv_width
    # in_proj emits [x (H*P), z (H*P), B (H*N), C (H*N), dt (H)]
    return {
        "in_proj": ParamSpec((d, 2 * H * P + 2 * H * N + H), ("embed", "d_inner"), init="fan_in"),
        "conv_w": ParamSpec((cw, H * P + 2 * H * N), ("conv", "d_inner"), init="normal", scale=0.1),
        "A_log": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((H,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "norm_scale": ParamSpec((H * P,), ("d_inner",), init="ones"),
        "out_proj": ParamSpec((H * P, d), ("d_inner", "embed"), init="fan_in"),
    }


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    H = cfg.ssm.num_heads
    P = cfg.ssm.head_dim or d_inner // H
    N = cfg.ssm.state_dim
    return H, P, N


def _split_proj(proj, H, P, N):
    xz, rest = jnp.split(proj, [2 * H * P], axis=-1)
    x, z = jnp.split(xz, 2, axis=-1)
    B, C, dt = jnp.split(rest, [H * N, 2 * H * N], axis=-1)
    return x, z, B, C, dt


def _causal_conv(u, w, state=None):
    """Depthwise causal conv via shifted adds. u: (b, s, ch), w: (cw, ch).

    state: (b, cw-1, ch) trailing context (decode); returns (y, new_state).
    """
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)                   # (b, s+cw-1, ch)
    y = sum(full[:, i : i + u.shape[1], :] * w[i][None, None, :] for i in range(cw))
    new_state = full[:, -(cw - 1):, :] if cw > 1 else None
    return y, new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None, unroll: bool = False):
    """SSD scan. x: (b, s, H, P), dt: (b, s, H), A: (H,) (negative),
    B, C: (b, s, H, N). Returns (y (b,s,H,P), final_state (b,H,P,N))."""
    b, s, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    # discretization
    dA = dt * A[None, None, :]                                  # (b, s, H) log-decay
    xb = (x * dt[..., None]).reshape(b, nc, chunk, H, P)
    Bc = B.reshape(b, nc, chunk, H, N)
    Cc = C.reshape(b, nc, chunk, H, N)
    dAc = dA.reshape(b, nc, chunk, H)
    cum = jnp.cumsum(dAc, axis=2)                               # (b, nc, c, H)
    total = cum[:, :, -1]                                       # (b, nc, H)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :]                                  # (b,nc,c,1,H)
    lj = cum[:, :, None, :, :]                                  # (b,nc,1,c,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    G = jnp.einsum("bnchm,bnkhm->bnckh", Cc, Bc)                # (b,nc,c,c,H)
    y_intra = jnp.einsum("bnckh,bnckh,bnkhp->bnchp", G, Lmat, xb)

    # chunk-final states: S_n = sum_j exp(total - cum_j) B_j x_j^T
    decay_to_end = jnp.exp(total[:, :, None] - cum)             # (b,nc,c,H)
    S_chunk = jnp.einsum("bnch,bnchm,bnchp->bnhpm", decay_to_end, Bc, xb)

    # inter-chunk scan
    def body(S, inp):
        S_c, tot, Cb, cumb = inp
        y_off = jnp.einsum("bchm,bhpm,bch->bchp", Cb, S, jnp.exp(cumb))
        S_new = S * jnp.exp(tot)[:, :, None, None] + S_c
        return S_new, y_off

    S0 = (jnp.zeros((b, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    xs = (
        S_chunk.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
        total.transpose(1, 0, 2),
        Cc.transpose(1, 0, 2, 3, 4),
        cum.transpose(1, 0, 2, 3),
    )
    # NOTE: stays a scan even in cost-lowering mode — its once-counted body is
    # corrected analytically in launch/dryrun.py (_inner_scan_correction).
    S_final, y_off = lax.scan(body, S0, xs)
    y_off = y_off.transpose(1, 0, 2, 3, 4)                      # (b,nc,c,H,P)
    y = (y_intra + y_off.astype(y_intra.dtype)).reshape(b, s, H, P)
    return y, S_final


def mamba2_apply(params, x_in, cfg: ModelConfig, cache=None, return_state: bool = False):
    """x_in: (b, s, d_model). cache (decode): {"conv": (b,cw-1,ch), "ssm": (b,H,P,N)}.

    return_state (prefill): start from zero state and return the final state
    as a fresh cache. Returns (out, new_cache)."""
    H, P, N = _dims(cfg)
    b, s, _ = x_in.shape
    dtype = x_in.dtype
    proj = jnp.einsum("bsd,de->bse", x_in, params["in_proj"].astype(dtype))
    x, z, B, C, dt = _split_proj(proj, H, P, N)
    conv_in = jnp.concatenate([x, B, C], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"].astype(dtype), conv_state)
    conv_out = jax.nn.silu(conv_out)
    x, B, C = jnp.split(conv_out, [H * P, H * P + H * N], axis=-1)
    x = x.reshape(b, s, H, P)
    B = B.reshape(b, s, H, N)
    C = C.reshape(b, s, H, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))           # (H,) negative

    if cache is not None and s == 1:
        # O(1) recurrent decode step
        S = cache["ssm"].astype(jnp.float32)                    # (b, H, P, N)
        dA = jnp.exp(dt[:, 0] * A[None, :])                     # (b, H)
        dBx = jnp.einsum("bhm,bhp,bh->bhpm", B[:, 0].astype(jnp.float32),
                          x[:, 0].astype(jnp.float32), dt[:, 0])
        S_new = S * dA[:, :, None, None] + dBx
        y = jnp.einsum("bhm,bhpm->bhp", C[:, 0].astype(jnp.float32), S_new)
        y = y[:, None]                                          # (b,1,H,P)
        new_cache = {"conv": new_conv, "ssm": S_new.astype(cache["ssm"].dtype)}
    else:
        init = cache["ssm"] if cache is not None else None
        y, S_final = _ssd_chunked(x.astype(jnp.float32), dt, A,
                                  B.astype(jnp.float32), C.astype(jnp.float32),
                                  cfg.ssm.chunk_size, init,
                                  unroll=cfg.unroll_inner)
        if cache is not None or return_state:
            new_cache = {"conv": new_conv, "ssm": S_final}
        else:
            new_cache = None

    y = y + x.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, H * P).astype(dtype)
    # gated RMSNorm (mamba2 style)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * lax.rsqrt(var + cfg.norm_eps)
         * params["norm_scale"].astype(jnp.float32)).astype(dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dtype))
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, num_layers: int, dtype=jnp.float32):
    H, P, N = _dims(cfg)
    ch = H * P + 2 * H * N
    cw = cfg.ssm.conv_width
    return {
        "conv": jnp.zeros((num_layers, batch, cw - 1, ch), dtype),
        "ssm": jnp.zeros((num_layers, batch, H, P, N), dtype),
    }
