"""Attention: GQA + RoPE/M-RoPE + qk-norm + sliding window + KV-cache decode.

Three execution paths:
  * ``full``     — materializes (sq, skv) logits; short sequences / tests.
  * ``chunked``  — lax.map over q-chunks, lax.scan over kv-chunks with online
                   softmax (flash-attention algorithm in pure JAX). This is the
                   path the multi-pod dry-run lowers — (S×S) logits are never
                   materialized, which is what makes 32k prefill fit.
  * ``decode``   — one query token against a (possibly windowed) KV cache.

The Pallas TPU kernel (repro.kernels.flash_attention) implements the chunked
algorithm with explicit VMEM BlockSpecs; on-CPU it is validated in interpret
mode against repro.kernels.flash_attention.ref.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.module import ParamSpec
from repro.models import rope as rope_lib
from repro.models.layers import rmsnorm
from repro.sharding.rules import shard_act

NEG_INF = -2.0e38


def n_q_heads(cfg: ModelConfig) -> int:
    """Query head count incl. perf padding (pad_attn_heads_to, DESIGN.md)."""
    return max(cfg.pad_attn_heads_to, cfg.num_heads)


def attention_specs(cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    hq = n_q_heads(cfg)
    spec = {
        "wq": ParamSpec((d, hq, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "wk": ParamSpec((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wv": ParamSpec((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wo": ParamSpec((hq, hd, d), ("heads", "head_dim", "embed"), init="fan_in"),
    }
    if cfg.qk_norm:
        spec["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        spec["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return spec


def _mask(q_pos, k_pos, window: int):
    """Causal (+ optional sliding window) mask. q_pos (sq,), k_pos (skv,)."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _qk_logits(q, k, softcap: float):
    """q: (b, sq, kvh, g, d); k: (b, skv, kvh, d) -> (b, kvh, g, sq, skv)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _pv(p, v):
    """p: (b, kvh, g, sq, skv); v: (b, skv, kvh, d) -> (b, sq, kvh, g, d)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _project_qkv(params, x, cfg: ModelConfig, positions, mrope_positions):
    dtype = x.dtype
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.qk_norm:  # qwen3-style per-head RMS norm on q/k
        q = rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": params["k_norm"]}, k, cfg.norm_eps)
    if cfg.mrope_sections and mrope_positions is not None:
        q = rope_lib.apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = rope_lib.apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
        k = rope_lib.apply_rope(k, positions, cfg.rope_theta)
    q = shard_act(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_act(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard_act(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _full_attention(q, k, v, cfg, q_pos, k_pos, window):
    b, sq, hq, hd = q.shape
    kvh = k.shape[2]
    g = hq // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = _qk_logits(qg, k, cfg.attn_logit_softcap) / jnp.sqrt(hd).astype(jnp.float32)
    m = _mask(q_pos, k_pos, window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _pv(p, v)
    return o.reshape(b, sq, hq, hd)


def _chunked_attention(q, k, v, cfg, window, q_chunk=512, kv_chunk=1024,
                       dynamic_skip=False):
    """Flash-attention algorithm in pure JAX (online softmax over KV chunks).

    dynamic_skip: causal(+window) KV-chunk skipping via dynamic loop bounds —
    halves attention work, but reverse-mode AD forbids dynamic trip counts,
    so it's enabled only on non-differentiated paths (prefill/serve)."""
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    g = hq // kvh
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, q_chunk, skv, kv_chunk)
    nq, nkv = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qg = q.reshape(b, nq, q_chunk, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)

    def per_q_chunk(args):
        qi, qc = args                                   # qc: (b, qcs, kvh, g, hd)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def body(ki, carry):
            m, l, acc = carry
            kc = lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
            vc = lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
            s = _qk_logits(qc, kc, cfg.attn_logit_softcap) * scale
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(q_pos, k_pos, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc).astype(jnp.float32)
            return (m_new, l_new, acc_new)

        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32)
        if dynamic_skip:
            # fully-masked KV chunks are never computed (≈2× attention work)
            hi = jnp.minimum(((qi + 1) * q_chunk + kv_chunk - 1) // kv_chunk, nkv)
            if window:
                lo = jnp.maximum(qi * q_chunk - window + 1, 0) // kv_chunk
            else:
                lo = jnp.zeros((), jnp.int32)
            m, l, acc = lax.fori_loop(lo, hi, body, (m0, l0, a0))
        else:
            # differentiable path: static trip count, mask-only causality
            def scan_body(carry, ki):
                return body(ki, carry), None
            (m, l, acc), _ = lax.scan(scan_body, (m0, l0, a0), jnp.arange(nkv))
        o = acc / jnp.maximum(l, 1e-37)[..., None]      # (b, kvh, g, qcs, hd)
        return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (b, qcs, kvh, g, hd)

    outs = lax.map(per_q_chunk, (jnp.arange(nq), qg))    # (nq, b, qcs, kvh, g, hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, hd)
    return out


def attention(params, x, cfg: ModelConfig, *, positions=None, mrope_positions=None,
              window: int = 0, cache=None, cache_index=None, chunked=None,
              return_kv: bool = False, kv_dtype=jnp.bfloat16):
    """Returns (output (b, s, d_model), new_cache or None).

    cache: {"k": (b, S, kvh, hd), "v": ...} — serve path writes the new token
    at ``cache_index`` then attends over positions <= cache_index.
    return_kv (prefill): also return the rotated k/v so the caller can build
    the serving cache.
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions, mrope_positions)

    if cache is not None:
        # --- decode: one (or few) new token(s) against the cache -----------
        S = cache["k"].shape[1]
        idx = cache_index if cache_index is not None else S - 1
        new_k = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        new_v = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        kvh = new_k.shape[2]
        hq = q.shape[2]
        g = hq // kvh
        qg = q.reshape(b, s, kvh, g, hd)
        sc = _qk_logits(qg, new_k, cfg.attn_logit_softcap) / jnp.sqrt(hd).astype(jnp.float32)
        k_pos = jnp.arange(S)
        valid = k_pos <= idx
        if window:
            valid &= k_pos > (idx - window)
        sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        o = _pv(p, new_v).reshape(b, s, hq, hd)
        out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(o.dtype))
        return out, {"k": new_k, "v": new_v}

    use_chunked = (chunked if chunked is not None
                   else (s > 2048 and not cfg.force_full_attention))
    if use_chunked:
        # prefill/serve (return_kv) is never differentiated -> block skipping
        o = _chunked_attention(q, k, v, cfg, window, dynamic_skip=return_kv)
    else:
        pos = jnp.arange(s)
        o = _full_attention(q, k, v, cfg, pos, pos, window)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(o.dtype))
    if return_kv:
        return out, {"k": k.astype(kv_dtype), "v": v.astype(kv_dtype)}
    return out, None


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
                  num_layers: Optional[int] = None):
    """Stacked-over-layers KV cache matching the scan layout of the decoder."""
    L = num_layers if num_layers is not None else cfg.num_layers
    hd = cfg.resolved_head_dim
    shape = (L, batch, max_seq, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
