"""xLSTM blocks (sLSTM + mLSTM) — xlstm-125m [arXiv:2405.04517].

mLSTM: matrix-memory LSTM with exponential gating. Implemented in the
*chunkwise-parallel* form (stabilized, like the official mlstm chunkwise
kernels): within a chunk the output is a decay-masked quadratic form
(MXU matmuls), across chunks a lax.scan carries (C, n, m). Decode is the
O(1) recurrence — this is what makes long_500k a legal shape for this arch.

sLSTM: scalar-memory LSTM with per-head block-diagonal recurrence — strictly
sequential, lax.scan over time (one While loop in HLO regardless of length).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.module import ParamSpec

EPS = 1e-6


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    return {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "wk": ParamSpec((d, H, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "wv": ParamSpec((d, H, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "w_if": ParamSpec((d, H, 2), ("embed", "heads", None), init="normal", scale=0.01),
        "b_if": ParamSpec((H, 2), ("heads", None), init="zeros"),
        "w_o": ParamSpec((d, d), ("embed", "d_inner"), init="fan_in"),
        "out_proj": ParamSpec((d, d), ("d_inner", "embed"), init="fan_in"),
    }


def _mlstm_chunk(q, k, v, logi, logf, carry, chunk_idx):
    """One chunk. q,k,v: (b,H,c,hd); logi,logf: (b,H,c);
    carry = (C (b,H,hd,hd), n (b,H,hd), m (b,H))."""
    C_prev, n_prev, m_prev = carry
    b, H, c, hd = q.shape
    F = jnp.cumsum(logf, axis=-1)                                # (b,H,c)
    # D_ij = F_i - F_j + logi_j  (j <= i)
    D = F[..., :, None] - F[..., None, :] + logi[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(mask, D, -jnp.inf)
    m_intra = jnp.max(D, axis=-1)                                # (b,H,c)
    m_inter = F + m_prev[..., None]                              # carried-state decay
    m_tot = jnp.maximum(m_intra, m_inter)                        # (b,H,c)
    w_intra = jnp.exp(D - m_tot[..., None])                      # (b,H,c,c)
    w_inter = jnp.exp(m_inter - m_tot)                           # (b,H,c)

    scale = 1.0 / jnp.sqrt(hd)
    s = jnp.einsum("bhcd,bhkd->bhck", q, k) * scale              # (b,H,c,c)
    h_intra = jnp.einsum("bhck,bhck,bhkd->bhcd", s, w_intra, v)
    h_inter = jnp.einsum("bhcd,bhde->bhce", q * scale, C_prev) * w_inter[..., None]
    n_vec = (jnp.einsum("bhck,bhck,bhkd->bhcd", s * 0 + 1.0, w_intra, k)
             + n_prev[:, :, None, :] * w_inter[..., None])
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhcd,bhcd->bhc", q * scale, n_vec)),
                        jnp.exp(-m_tot)) + EPS
    h = (h_intra + h_inter) / denom[..., None]                   # (b,H,c,hd)

    # carry update to end of chunk
    m_next = jnp.maximum(F[..., -1] + m_prev, jnp.max(D[..., -1, :], axis=-1))
    w_end = jnp.exp(F[..., -1:] - F + logi - m_next[..., None])  # (b,H,c)
    C_next = (C_prev * jnp.exp(F[..., -1] + m_prev - m_next)[..., None, None]
              + jnp.einsum("bhck,bhcd,bhce->bhde", w_end[..., None] * 0 + w_end[..., None],
                            k, v))
    n_next = (n_prev * jnp.exp(F[..., -1] + m_prev - m_next)[..., None]
              + jnp.einsum("bhc,bhcd->bhd", w_end, k))
    return (C_next, n_next, m_next), h


def mlstm_apply(params, x, cfg: ModelConfig, cache=None, chunk: int = 256,
                return_state: bool = False):
    """x: (b, s, d). cache (decode): {"C","n","m"}. Returns (out, new_cache)."""
    b, s, d = x.shape
    H = cfg.num_heads
    hd = d // H
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(dtype)).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"].astype(dtype)).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"].astype(dtype)).astype(jnp.float32)
    gates = (jnp.einsum("bsd,dhg->bhsg", x, params["w_if"].astype(dtype)).astype(jnp.float32)
             + params["b_if"].astype(jnp.float32)[None, :, None, :])
    logi = gates[..., 0]
    logf = jax.nn.log_sigmoid(gates[..., 1])                     # (b,H,s)

    if cache is not None and s == 1:
        C_prev = cache["C"].astype(jnp.float32)
        n_prev = cache["n"].astype(jnp.float32)
        m_prev = cache["m"].astype(jnp.float32)
        li, lf = logi[..., 0], logf[..., 0]
        m_new = jnp.maximum(lf + m_prev, li)
        i_s = jnp.exp(li - m_new)
        f_s = jnp.exp(lf + m_prev - m_new)
        scale = 1.0 / jnp.sqrt(hd)
        kv = jnp.einsum("bhd,bhe->bhde", k[:, :, 0], v[:, :, 0])
        C_new = f_s[..., None, None] * C_prev + i_s[..., None, None] * kv
        n_new = f_s[..., None] * n_prev + i_s[..., None] * k[:, :, 0]
        qs = q[:, :, 0] * scale
        num = jnp.einsum("bhd,bhde->bhe", qs, C_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n_new)),
                          jnp.exp(-m_new)) + EPS
        h = (num / den[..., None])[:, :, None, :]                # (b,H,1,hd)
        new_cache = {"C": C_new.astype(cache["C"].dtype),
                     "n": n_new.astype(cache["n"].dtype),
                     "m": m_new.astype(cache["m"].dtype)}
    else:
        chunk = min(chunk, s)
        assert s % chunk == 0
        nc = s // chunk
        def to_chunks(t):
            return t.reshape(b, H, nc, chunk, *t.shape[3:]).transpose(2, 0, 1, 3, *range(4, t.ndim + 1))
        qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
        lis = logi.reshape(b, H, nc, chunk).transpose(2, 0, 1, 3)
        lfs = logf.reshape(b, H, nc, chunk).transpose(2, 0, 1, 3)
        if cache is not None:
            carry0 = (cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                      cache["m"].astype(jnp.float32))
        else:
            carry0 = (jnp.zeros((b, H, hd, hd), jnp.float32),
                      jnp.zeros((b, H, hd), jnp.float32),
                      jnp.full((b, H), 0.0, jnp.float32))
        def body(carry, inp):
            qc, kc, vc, lic, lfc, ci = inp
            carry, h = _mlstm_chunk(qc, kc, vc, lic, lfc, carry, ci)
            return carry, h
        # stays a scan in cost mode; corrected analytically (launch/dryrun.py)
        carry, hs = lax.scan(body, carry0, (qs, ks, vs, lis, lfs, jnp.arange(nc)))
        h = hs.transpose(1, 2, 0, 3, 4).reshape(b, H, s, hd)
        if cache is not None or return_state:
            new_cache = {"C": carry[0], "n": carry[1], "m": carry[2]}
            if cache is not None:
                new_cache = {k: v.astype(cache[k].dtype) for k, v in new_cache.items()}
        else:
            new_cache = None

    h = h.transpose(0, 2, 1, 3).reshape(b, s, d).astype(dtype)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, params["w_o"].astype(dtype)))
    out = jnp.einsum("bse,ed->bsd", h * o, params["out_proj"].astype(dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    return {
        "w_in": ParamSpec((d, 4, H, hd), ("embed", None, "heads", "head_dim"),
                          init="normal", scale=0.02),
        "r": ParamSpec((4, H, hd, hd), (None, "heads", "head_dim", None),
                       init="normal", scale=0.02),
        "b": ParamSpec((4, H, hd), (None, "heads", "head_dim"), init="zeros"),
        "out_proj": ParamSpec((d, d), ("d_inner", "embed"), init="fan_in"),
    }


def _slstm_step(params32, carry, wx_t):
    """carry = (c, n, h, m) each (b,H,hd); wx_t: (b,4,H,hd)."""
    r, bias = params32
    c, n, h, m = carry
    rec = jnp.einsum("ghde,bhe->bghd", r, h)                     # (b,4,H,hd)
    pre = wx_t + rec + bias[None]
    li = pre[:, 0]                                               # log input gate
    lf = jax.nn.log_sigmoid(pre[:, 1])                           # log forget gate
    z = jnp.tanh(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(lf + m, li)
    i_s = jnp.exp(li - m_new)
    f_s = jnp.exp(lf + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, EPS)
    return (c_new, n_new, h_new, m_new)


def slstm_apply(params, x, cfg: ModelConfig, cache=None, return_state: bool = False):
    """x: (b, s, d). cache: {"c","n","h","m"} each (b,H,hd)."""
    b, s, d = x.shape
    H = cfg.num_heads
    hd = d // H
    dtype = x.dtype
    wx = jnp.einsum("bsd,dghk->bsghk", x, params["w_in"].astype(dtype)).astype(jnp.float32)
    r = params["r"].astype(jnp.float32)
    bias = params["b"].astype(jnp.float32)
    if cache is not None:
        carry0 = tuple(cache[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))
    else:
        zero = jnp.zeros((b, H, hd), jnp.float32)
        carry0 = (zero, zero, zero, zero)

    def body(carry, wx_t):
        new = _slstm_step((r, bias), carry, wx_t)
        return new, new[2]

    carry, hs = lax.scan(body, carry0, wx.transpose(1, 0, 2, 3, 4))  # scan over seq
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(dtype)
    out = jnp.einsum("bse,ed->bsd", h, params["out_proj"].astype(dtype))
    new_cache = None
    if cache is not None or return_state:
        new_cache = dict(zip(("c", "n", "h", "m"), carry))
        if cache is not None:
            new_cache = {k: v.astype(cache[k].dtype) for k, v in new_cache.items()}
    return out, new_cache


def init_xlstm_cache(cfg: ModelConfig, batch: int, num_units: int, dtype=jnp.float32):
    """Per-unit caches for the (pattern-cycled) xLSTM stack."""
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    caches = []
    for kind in cfg.xlstm_pattern:
        if kind == "m":
            caches.append({
                "C": jnp.zeros((num_units, batch, H, hd, hd), dtype),
                "n": jnp.zeros((num_units, batch, H, hd), dtype),
                "m": jnp.zeros((num_units, batch, H), dtype),
            })
        else:
            caches.append({
                k: jnp.zeros((num_units, batch, H, hd), dtype)
                for k in ("c", "n", "h", "m")
            })
    return caches
