"""Decoder assembly for every assigned architecture family.

Families (ModelConfig.family):
  dense   — GQA transformer (qwen3, granite, llama3.2, + vlm/audio backbones)
  moe     — dense blocks with MoE FFN (mixtral, llama4, moonshot)
  hybrid  — zamba2: Mamba2 backbone + ONE shared attention block applied at
            the start of every unit of ``hybrid_attn_every`` mamba layers
  ssm     — xLSTM: units cycling ``xlstm_pattern`` (mLSTM / sLSTM blocks)
  vlm     — dense + vision-embedding merge + M-RoPE (frontend stubbed)
  audio   — dense over frame embeddings, K-codebook output heads

Layers are stacked and iterated with ``lax.scan`` so HLO size is O(1) in
depth (80-layer archs lower in seconds); ``cfg.remat == "block"`` wraps the
scan body in ``jax.checkpoint``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import mamba2, moe as moe_lib, xlstm as xlstm_lib
from repro.models.attention import attention, attention_specs
from repro.models.layers import (embed, embedding_specs, rmsnorm, rmsnorm_specs,
                                 unembed_specs)
from repro.models.mlp import swiglu, swiglu_specs
from repro.models.module import ParamSpec, stack_specs
from repro.sharding.rules import shard_act


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _attn_block_specs(cfg: ModelConfig):
    return {"ln": rmsnorm_specs(cfg.d_model), "attn": attention_specs(cfg)}


def _ffn_block_specs(cfg: ModelConfig):
    if cfg.moe.num_experts:
        return {"ln": rmsnorm_specs(cfg.d_model), "ffn": moe_lib.moe_specs(cfg)}
    return {"ln": rmsnorm_specs(cfg.d_model), "ffn": swiglu_specs(cfg.d_model, cfg.d_ff)}


def _dense_block_specs(cfg: ModelConfig):
    a, f = _attn_block_specs(cfg), _ffn_block_specs(cfg)
    return {"attn_ln": a["ln"], "attn_attn": a["attn"],
            "ffn_ln": f["ln"], "ffn": f["ffn"]}


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_units, mamba layers per unit) for zamba2-style stacks."""
    k = max(cfg.hybrid_attn_every, 1)
    assert cfg.num_layers % k == 0, (cfg.num_layers, k)
    return cfg.num_layers // k, k


def xlstm_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.xlstm_pattern or ("m",)
    assert cfg.num_layers % len(pat) == 0, (cfg.num_layers, pat)
    return cfg.num_layers // len(pat), pat


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {"final_norm": rmsnorm_specs(d)}
    if cfg.family != "audio":
        specs["embed"] = embedding_specs(V, d)
    if cfg.family == "audio":
        K = cfg.audio_codebooks
        specs["unembed"] = {"kernel": ParamSpec((K, d, V), ("codebooks", "embed", "vocab"),
                                                init="fan_in")}
    elif not cfg.tie_embeddings:
        specs["unembed"] = unembed_specs(V, d)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        specs["blocks"] = stack_specs(_dense_block_specs(cfg), cfg.num_layers)
    elif cfg.family == "hybrid":
        units, per = hybrid_layout(cfg)
        mamba_block = {"ln": rmsnorm_specs(d), "mixer": mamba2.mamba2_specs(cfg)}
        specs["shared_attn"] = _attn_block_specs(cfg)
        specs["mamba"] = stack_specs(stack_specs(mamba_block, per, "inner"), units, "units")
    elif cfg.family == "ssm":
        units, pat = xlstm_layout(cfg)
        blocks = {}
        for i, kind in enumerate(pat):
            bs = (xlstm_lib.mlstm_specs(cfg) if kind == "m" else xlstm_lib.slstm_specs(cfg))
            blocks[f"b{i}_{kind}"] = stack_specs(
                {"ln": rmsnorm_specs(d), "mixer": bs,
                 "ffn_ln": rmsnorm_specs(d),
                 "ffn": swiglu_specs(d, 4 * d)},
                units, "units")
        specs["units"] = blocks
    else:
        raise ValueError(cfg.family)
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat == "block" else fn


def _dense_stack(params, x, cfg: ModelConfig, *, positions, mrope_positions,
                 caches=None, cache_index=None, return_kv=False):
    """Scan over stacked dense/moe blocks. Returns (x, aux, new_caches)."""

    def body(carry, inp):
        x, aux = carry
        layer_params, cache_l = inp
        h, kv = attention(
            layer_params["attn_attn"], rmsnorm(layer_params["attn_ln"], x, cfg.norm_eps),
            cfg, positions=positions, mrope_positions=mrope_positions,
            window=cfg.window, cache=cache_l, cache_index=cache_index,
            return_kv=return_kv, kv_dtype=jnp.dtype(cfg.kv_cache_dtype))
        x = x + h
        h = rmsnorm(layer_params["ffn_ln"], x, cfg.norm_eps)
        if cfg.moe.num_experts:
            y, a = moe_lib.moe_apply(layer_params["ffn"], h, cfg)
            aux = aux + a
        else:
            y = swiglu(layer_params["ffn"], h)
        x = shard_act(x + y, ("batch", "seq", "embed_act"))
        return (x, aux), kv

    body = _maybe_remat(body, cfg)
    (x, aux), kvs = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             (params["blocks"], caches),
                             unroll=cfg.unroll_layers)
    return x, aux, kvs


def _hybrid_stack(params, x, cfg: ModelConfig, *, positions, caches=None,
                  cache_index=None, prefill=False):
    """zamba2: scan over units; each unit = shared attention + ``per`` mamba."""
    shared = params["shared_attn"]

    def unit_body(carry, inp):
        x, aux = carry
        unit_params, attn_cache, mamba_cache = inp
        h, kv = attention(shared["attn"], rmsnorm(shared["ln"], x, cfg.norm_eps), cfg,
                          positions=positions, window=cfg.window,
                          cache=attn_cache, cache_index=cache_index,
                          return_kv=prefill, kv_dtype=jnp.dtype(cfg.kv_cache_dtype))
        x = x + h

        def mamba_body(xc, minp):
            mp, mc = minp
            h, new_mc = mamba2.mamba2_apply(mp["mixer"], rmsnorm(mp["ln"], xc, cfg.norm_eps),
                                            cfg, cache=mc, return_state=prefill)
            return xc + h, new_mc

        x, new_mc = lax.scan(mamba_body, x, (unit_params, mamba_cache),
                             unroll=cfg.hybrid_attn_every if cfg.unroll_inner else 1)
        x = shard_act(x, ("batch", "seq", "embed_act"))
        return (x, aux), (kv, new_mc)

    unit_body = _maybe_remat(unit_body, cfg)
    attn_caches, mamba_caches = (caches if caches is not None else (None, None))
    (x, aux), (kvs, mcs) = lax.scan(
        unit_body, (x, jnp.zeros((), jnp.float32)),
        (params["mamba"], attn_caches, mamba_caches),
        unroll=cfg.unroll_layers)
    return x, aux, (kvs, mcs)


def _ssm_stack(params, x, cfg: ModelConfig, *, caches=None, prefill=False):
    """xLSTM: scan over units cycling the block pattern."""
    _, pat = xlstm_layout(cfg)

    def unit_body(carry, inp):
        x, aux = carry
        unit_params, unit_caches = inp
        new_caches = []
        for i, kind in enumerate(pat):
            bp = unit_params[f"b{i}_{kind}"]
            bc = unit_caches[i] if unit_caches is not None else None
            h_in = rmsnorm(bp["ln"], x, cfg.norm_eps)
            if kind == "m":
                h, nc = xlstm_lib.mlstm_apply(bp["mixer"], h_in, cfg, cache=bc,
                                              return_state=prefill)
            else:
                h, nc = xlstm_lib.slstm_apply(bp["mixer"], h_in, cfg, cache=bc,
                                              return_state=prefill)
            x = x + h
            x = x + swiglu(bp["ffn"], rmsnorm(bp["ffn_ln"], x, cfg.norm_eps))
            x = shard_act(x, ("batch", "seq", "embed_act"))
            new_caches.append(nc)
        return (x, aux), tuple(new_caches)

    unit_body = _maybe_remat(unit_body, cfg)
    unit_caches = caches if caches is not None else None
    (x, aux), new_caches = lax.scan(unit_body, (x, jnp.zeros((), jnp.float32)),
                                    (params["units"], unit_caches),
                                    unroll=cfg.unroll_layers)
    return x, aux, new_caches


def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, Any], dtype):
    """Family-specific input embedding. Returns (x, mrope_positions)."""
    if cfg.family == "audio":
        return batch["frames"].astype(dtype), None
    x = embed(params["embed"], batch["tokens"], dtype)
    mrope = None
    if cfg.family == "vlm":
        ve = batch["vision_embeds"].astype(dtype)
        v = ve.shape[1]
        x = jnp.concatenate([ve, x[:, v:]], axis=1)
        mrope = batch["mrope_positions"]
    return x, mrope


def _unembed(params, cfg: ModelConfig, x):
    """x: (b, s, d) -> logits."""
    if cfg.family == "audio":
        kern = params["unembed"]["kernel"].astype(x.dtype)
        return jnp.einsum("bsd,kdv->bskv", x, kern).astype(cfg.logits_dtype)
    if cfg.tie_embeddings:
        kern = params["embed"]["table"].astype(x.dtype)  # (V, d)
        return jnp.einsum("bsd,vd->bsv", x, kern).astype(cfg.logits_dtype)
    kern = params["unembed"]["kernel"].astype(x.dtype)
    return jnp.einsum("bsd,dv->bsv", x, kern).astype(cfg.logits_dtype)


def forward(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            caches=None, cache_index=None, return_kv: bool = False,
            last_token_only: bool = False):
    """Full forward pass. Returns (logits, aux_loss, new_caches).

    Modes: train/eval (caches=None, return_kv=False), prefill (caches=None,
    return_kv=True — builds decode caches), decode (caches given +
    cache_index)."""
    dtype = jnp.dtype(cfg.dtype)
    x, mrope = _embed_inputs(params, cfg, batch, dtype)
    x = shard_act(x, ("batch", "seq", "embed_act"))
    b, s = x.shape[0], x.shape[1]
    if cache_index is not None:
        positions = jnp.full((b, s), cache_index, jnp.int32) + jnp.arange(s)[None, :]
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.family == "vlm" and mrope is None:
        mrope = jnp.broadcast_to(positions[None], (3, b, s)).astype(jnp.int32)

    prefill = return_kv and caches is None
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        x, aux, new_caches = _dense_stack(
            params, x, cfg, positions=positions, mrope_positions=mrope,
            caches=caches, cache_index=cache_index, return_kv=return_kv)
    elif cfg.family == "hybrid":
        x, aux, new_caches = _hybrid_stack(
            params, x, cfg, positions=positions, caches=caches,
            cache_index=cache_index, prefill=prefill)
    else:
        x, aux, new_caches = _ssm_stack(params, x, cfg, caches=caches,
                                        prefill=prefill)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if last_token_only:
        x = x[:, -1:]
    logits = _unembed(params, cfg, x)
    if cfg.family == "audio":
        logits = shard_act(logits, ("batch", "seq", "codebooks", "vocab"))
    else:
        logits = shard_act(logits, ("batch", "seq", "vocab"))
    return logits, aux, new_caches


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Decode-state pytree matching the scan layout of ``forward``."""
    from repro.models.attention import init_kv_cache
    dtype = dtype or jnp.dtype(cfg.kv_cache_dtype)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return init_kv_cache(cfg, batch, max_seq, dtype, num_layers=cfg.num_layers)
    if cfg.family == "hybrid":
        units, per = hybrid_layout(cfg)
        attn_c = init_kv_cache(cfg, batch, max_seq, dtype, num_layers=units)
        mamba_c = mamba2.init_mamba_cache(cfg, batch, units * per, jnp.float32)
        mamba_c = jax.tree_util.tree_map(
            lambda t: t.reshape(units, per, *t.shape[1:]), mamba_c)
        return (attn_c, mamba_c)
    if cfg.family == "ssm":
        units, pat = xlstm_layout(cfg)
        return tuple(xlstm_lib.init_xlstm_cache(cfg, batch, units, jnp.float32)[i]
                     for i in range(len(pat)))
    raise ValueError(cfg.family)
