"""Shared layers: norms, embeddings, projections (pure-function style).

Every layer is a pair (``*_specs`` -> ParamSpec tree, ``apply`` function).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import ParamSpec


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_specs(dim: int):
    return {"scale": ParamSpec((dim,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def l2norm(x, eps: float = 1e-6):
    """Parameter-free L2 norm (used by qk_norm variants)."""
    return x * jax.lax.rsqrt(jnp.sum(jnp.square(x), axis=-1, keepdims=True) + eps)


# ---------------------------------------------------------------------------
# Embedding / output head
# ---------------------------------------------------------------------------

def embedding_specs(vocab: int, dim: int):
    # "vocab_table" (not "vocab"): the gather table may be sharded differently
    # from the logits projection — stacked-per-group P4 runs unshard the table
    # to keep embedding gathers pod-local (§Perf hillclimb 3).
    return {"table": ParamSpec((vocab, dim), ("vocab_table", "embed"), init="normal", scale=0.01)}


def embed(params, tokens, dtype):
    return jnp.take(params["table"].astype(dtype), tokens, axis=0)


def unembed_specs(vocab: int, dim: int):
    return {"kernel": ParamSpec((dim, vocab), ("embed", "vocab"), init="fan_in")}


def unembed(params, x, dtype):
    return jnp.einsum("...d,dv->...v", x, params["kernel"].astype(x.dtype)).astype(dtype)


# ---------------------------------------------------------------------------
# Generic dense
# ---------------------------------------------------------------------------

def dense_specs(d_in: int, d_out: int, dims=("embed", "ffn"), init="fan_in", bias=False):
    spec = {"kernel": ParamSpec((d_in, d_out), dims, init=init)}
    if bias:
        spec["bias"] = ParamSpec((d_out,), (dims[-1],), init="zeros")
    return spec


def dense(params, x):
    y = jnp.einsum("...d,df->...f", x, params["kernel"].astype(x.dtype))
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels, mask=None, z_loss: float = 0.0):
    """Token-level CE with optional z-loss; logits (..., V), labels int (...).

    The label log-prob uses a masked reduce rather than take_along_axis: the
    gather reshards vocab-sharded logits (cross-shard collective-permutes in
    the HLO); the masked reduce stays local per vocab shard and the partial
    sum joins the existing all-reduce (§Perf hillclimb 3, iter 4)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.where(vocab_iota == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        loss = loss * mask
        return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)


def kl_divergence(p_logits, q_logits, temperature: float = 1.0):
    """KL(p ‖ q) over the last axis — the paper's Eq. 7 distillation loss."""
    t = temperature
    p = jax.nn.log_softmax(p_logits.astype(jnp.float32) / t, axis=-1)
    q = jax.nn.log_softmax(q_logits.astype(jnp.float32) / t, axis=-1)
    return jnp.mean(jnp.sum(jnp.exp(p) * (p - q), axis=-1)) * t * t
