"""Round schedules: who participates in a round and how updates merge.

The PR-2 engine ran one hardwired round body — every client trains every
round, synchronous aggregation. A ``RoundSchedule`` owns that body instead,
so partial participation and async aggregation are engine features (one
schedule object) rather than per-strategy rewrites:

  FullParticipation  — the PR-2 body, verbatim. Bit-identical trajectories
                       (locked down in ``tests/test_engine.py``).
  ClientSampling     — Bernoulli-q or fixed-size cohorts drawn with
                       ``jax.random`` *inside* the jitted chunk; the scan
                       stays device-resident and the per-round participation
                       masks come back as a stacked scan output, so host-side
                       byte accounting and the privacy ledger see the exact
                       cohorts the device drew.
  AsyncStaleness     — buffered aggregation: clients train every round but
                       the merge runs only every ``staleness + 1`` rounds,
                       discounted by the FedBuff-style polynomial staleness
                       weight (1 + s)^(-staleness_pow). staleness=0 is the
                       synchronous body exactly.

Per-round randomness matches the PR-2 derivation — ``rk = fold_in(phase_key,
r)`` with streams 0/1/2 for batch/local/aggregate — and ClientSampling draws
its mask from the previously unused stream 3, so adding a schedule never
perturbs the existing streams.

Participation semantics (ClientSampling): an absent client neither trains,
sends, nor receives this round — its state is bit-unchanged. Present clients
aggregate over the cohort only (strategies override ``aggregate_masked`` for
method-specific cohort math, e.g. P4's masked group mean); decentralized
methods whose aggregation reads neighbors (ring / exponential graph) see the
absent neighbor's last-known state, which is what a real stale cache holds.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.resilience import current_faults


def _fault_blend(agg, base, w_rows, rows: int, w_scalar):
    """Per-client staleness-discounted fold: client-stacked leaves blend with
    their own realized weight, replicated/server leaves with the mean weight
    (so single-device and sharded traces agree on non-stacked state)."""
    def f(a, b):
        if a.ndim >= 1 and a.shape[:1] == (rows,):
            ww = w_rows.reshape((rows,) + (1,) * (a.ndim - 1))
        else:
            ww = w_scalar
        return (ww * a + (1.0 - ww) * b).astype(b.dtype)
    return jax.tree_util.tree_map(f, agg, base)


def sample_client_batches(train_x, train_y, key, batch_size: Optional[int]):
    """Per-client minibatches drawn on device: (M, B, ...), (M, B).

    ``batch_size=None`` means full-batch (returns the stacks unchanged —
    used by P4's bootstrap phase, which trains on the whole local dataset).
    """
    if batch_size is None:
        return train_x, train_y
    M, R = train_y.shape
    idx = jax.random.randint(key, (M, batch_size), 0, R)
    xs = jnp.take_along_axis(
        train_x, idx.reshape(idx.shape + (1,) * (train_x.ndim - 2)), axis=1)
    ys = jnp.take_along_axis(train_y, idx, axis=1)
    return xs, ys


@dataclass(eq=False)  # identity hash: schedules are closed over by jitted chunks
class RoundSchedule:
    """Owns the engine's scanned round body.

    ``round_body(strategy, batch_size)`` returns
    ``body(state, r, phase_key, train_x, train_y) -> (state, (metrics, aux))``
    where ``aux`` is an (empty or participation-carrying) dict of per-round
    arrays stacked by the scan — the engine forwards ``aux["participation"]``
    to byte accounting and History.
    """

    name = "full"

    def client_fraction(self, M: Optional[int] = None) -> float:
        """Expected fraction of clients participating per round — the
        schedule's contribution to the ledger's effective sampling rate."""
        return 1.0

    def fingerprint(self):
        """Value key for the engine's compiled-chunk cache (all schedule
        fields are trace-baked constants, so all of them key)."""
        return (type(self).__name__,) + tuple(
            (f.name, getattr(self, f.name)) for f in dataclasses.fields(self))

    def round_body(self, strategy, batch_size: Optional[int]):
        raise NotImplementedError

    def sharded_round_body(self, strategy, batch_size: Optional[int], ctx):
        """Round body for a shard_map region over the client axis: same key
        derivation and call sequence as ``round_body``, with the strategy's
        sharded hooks in place of the single-device ones (see
        ``repro.engine.sharded``)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no sharded round body")


@dataclass(eq=False)
class FullParticipation(RoundSchedule):
    """Every client, every round, synchronous aggregation — the PR-2 body."""

    name = "full"

    def round_body(self, strategy, batch_size):
        def body(state, r, phase_key, train_x, train_y):
            rk = jax.random.fold_in(phase_key, r)
            xs, ys = sample_client_batches(
                train_x, train_y, jax.random.fold_in(rk, 0), batch_size)
            af = current_faults()
            if af is None:
                state, metrics = strategy.local_update(
                    state, xs, ys, r, jax.random.fold_in(rk, 1))
                state = strategy.aggregate(state, r, jax.random.fold_in(rk, 2))
                return state, (metrics, {})
            # faults installed: down/slow clients are frozen — their training
            # is discarded, they receive nothing, and aggregation runs over
            # the active cohort (the ClientSampling machinery, mask = active)
            active = af.real.active()
            new, metrics = strategy.local_update(
                state, xs, ys, r, jax.random.fold_in(rk, 1))
            new = strategy.merge_participation(state, new, active)
            new = strategy.aggregate_masked(new, r, jax.random.fold_in(rk, 2),
                                            active)
            new = strategy.merge_participation(state, new, active)
            empty = jnp.sum(active) == 0
            state = jax.tree_util.tree_map(
                lambda s, n: jnp.where(empty, s, n), state, new)
            return state, (metrics, {"participation": active})

        return body

    def sharded_round_body(self, strategy, batch_size, ctx):
        def body(state, r, phase_key, train_x, train_y):
            rk = jax.random.fold_in(phase_key, r)
            xs, ys = ctx.sample_local_batches(
                train_x, train_y, jax.random.fold_in(rk, 0), batch_size)
            af = current_faults()
            if af is None:
                state, metrics = strategy.sharded_local_update(
                    state, xs, ys, r, jax.random.fold_in(rk, 1), ctx)
                state = strategy.sharded_aggregate(
                    state, r, jax.random.fold_in(rk, 2), ctx)
                return state, (metrics, {})
            # the realization is replicated (the fault carry is stepped from
            # the phase key on every slice), so active matches single-device
            active = af.real.active()
            local_active = ctx.shard_rows(active)
            new, metrics = strategy.sharded_local_update(
                state, xs, ys, r, jax.random.fold_in(rk, 1), ctx)
            new = strategy.merge_participation(state, new, local_active)
            new = strategy.sharded_aggregate_masked(
                new, r, jax.random.fold_in(rk, 2), ctx, active, local_active)
            new = strategy.merge_participation(state, new, local_active)
            empty = jnp.sum(active) == 0
            state = jax.tree_util.tree_map(
                lambda s, n: jnp.where(empty, s, n), state, new)
            return state, (metrics, {"participation": active})

        return body


@dataclass(eq=False)
class ClientSampling(RoundSchedule):
    """Partial participation: a per-round cohort drawn inside the jit.

    ``mode="bernoulli"`` — each client independently with probability q.
    This is exact Poisson sampling — the amplification-by-subsampling regime
    the RDP accountant models — so an empty draw is NOT redrawn or patched
    (that would raise the true inclusion probability above the q the ledger
    accounts at); an empty-cohort round is a no-op (state passes through
    unchanged, guarded in the round body for server-style strategies whose
    cohort-weighted aggregation would otherwise divide by zero).
    ``mode="fixed"`` — a uniformly random cohort of exactly
    ``max(1, round(q·M))`` clients (sampling without replacement).
    """

    q: float = 1.0
    mode: str = "bernoulli"          # bernoulli | fixed
    name = "sampling"

    def client_fraction(self, M: Optional[int] = None) -> float:
        if self.mode == "fixed" and M:
            return max(1, int(round(self.q * M))) / M
        return min(1.0, float(self.q))

    def draw_mask(self, key, M: int):
        """(M,) float32 0/1 participation mask; deterministic in ``key``."""
        k1, _ = jax.random.split(key)
        if self.mode == "fixed":
            k = max(1, int(round(self.q * M)))
            # top_k of the negated uniforms = the k smallest = the stable
            # argsort's first k rows (both break ties lower-index-first), so
            # the mask is bit-identical to the argsort lowering — but O(M·k)
            # instead of a full O(M log M) sort, which is what makes fixed
            # cohorts affordable per round at virtual-population M (the
            # paged engine draws and host-replays this at full M)
            u = jax.random.uniform(k1, (M,))
            _, idx = jax.lax.top_k(-u, k)
            return jnp.zeros((M,), jnp.float32).at[idx].set(1.0)
        return (jax.random.uniform(k1, (M,)) < self.q).astype(jnp.float32)

    def round_body(self, strategy, batch_size):
        def body(state, r, phase_key, train_x, train_y):
            M = train_y.shape[0]
            rk = jax.random.fold_in(phase_key, r)
            xs, ys = sample_client_batches(
                train_x, train_y, jax.random.fold_in(rk, 0), batch_size)
            mask = self.draw_mask(jax.random.fold_in(rk, 3), M)
            af = current_faults()
            if af is not None:
                # a sampled client that is down or slow still can't serve
                mask = mask * af.real.active()
            new, metrics = strategy.local_update(
                state, xs, ys, r, jax.random.fold_in(rk, 1))
            # absent clients' local training is discarded: aggregation sees
            # their pre-round (last-known) state
            new = strategy.merge_participation(state, new, mask)
            new = strategy.aggregate_masked(new, r, jax.random.fold_in(rk, 2),
                                            mask)
            # ...and they receive nothing: final state is bit-unchanged
            new = strategy.merge_participation(state, new, mask)
            # empty Bernoulli cohort ⇒ the round is a no-op for everyone
            # (stacked strategies are already frozen by the merges; this
            # guards server-style states whose cohort-weighted aggregation
            # has no cohort to weight)
            empty = jnp.sum(mask) == 0
            state = jax.tree_util.tree_map(
                lambda s, n: jnp.where(empty, s, n), state, new)
            return state, (metrics, {"participation": mask})

        return body

    def paged_round_body(self, strategy, batch_size, pctx):
        """Round body over a paged cohort (``repro.engine.population``): the
        chunk's arrays hold the compact (C, ...) cohort rows, but every random
        draw is made at full population size — the (M,) participation mask,
        the M-way per-client key split, the (M, B) batch-index draw — and then
        sliced at the cohort's global ids, so the streams (and the aux
        participation masks the ledger and byte accounting consume) are
        bit-identical to the resident body's."""
        def body(state, r, phase_key, train_x, train_y):
            rk = jax.random.fold_in(phase_key, r)
            xs, ys = pctx.sample_cohort_batches(
                train_x, train_y, jax.random.fold_in(rk, 0), batch_size)
            mask = self.draw_mask(jax.random.fold_in(rk, 3), pctx.M)
            af = current_faults()
            if af is not None:
                mask = mask * af.real.active()
            # cohort-slot view of the full mask; padding slots never merge
            mask_c = mask[pctx.ids_clip] * pctx.valid
            new, metrics = strategy.paged_local_update(
                state, xs, ys, r, jax.random.fold_in(rk, 1), pctx)
            new = strategy.merge_participation(state, new, mask_c)
            new = strategy.paged_aggregate_masked(
                new, r, jax.random.fold_in(rk, 2), mask, pctx)
            new = strategy.merge_participation(state, new, mask_c)
            empty = jnp.sum(mask) == 0
            state = jax.tree_util.tree_map(
                lambda s, n: jnp.where(empty, s, n), state, new)
            return state, (metrics, {"participation": mask})

        return body

    def sharded_round_body(self, strategy, batch_size, ctx):
        def body(state, r, phase_key, train_x, train_y):
            rk = jax.random.fold_in(phase_key, r)
            xs, ys = ctx.sample_local_batches(
                train_x, train_y, jax.random.fold_in(rk, 0), batch_size)
            # the full (M,) mask is drawn replicated — every shard computes
            # the same draw the single-device body makes, then slices its own
            # rows; the aux output stays the full mask so byte accounting and
            # the ledger see exactly the single-device cohorts
            mask = self.draw_mask(jax.random.fold_in(rk, 3), ctx.M)
            af = current_faults()
            if af is not None:
                mask = mask * af.real.active()
            local_mask = ctx.shard_rows(mask)
            new, metrics = strategy.sharded_local_update(
                state, xs, ys, r, jax.random.fold_in(rk, 1), ctx)
            new = strategy.merge_participation(state, new, local_mask)
            new = strategy.sharded_aggregate_masked(
                new, r, jax.random.fold_in(rk, 2), ctx, mask, local_mask)
            new = strategy.merge_participation(state, new, local_mask)
            empty = jnp.sum(mask) == 0
            state = jax.tree_util.tree_map(
                lambda s, n: jnp.where(empty, s, n), state, new)
            return state, (metrics, {"participation": mask})

        return body


@dataclass(eq=False)
class AsyncStaleness(RoundSchedule):
    """Buffered aggregation: merge every ``staleness + 1`` rounds.

    Clients train every round; their unaggregated local progress is the
    buffer. At each merge point the aggregate is folded in with the
    FedBuff-style polynomial staleness discount
    ``w = (1 + staleness)^(-staleness_pow)``:

        state ← w · aggregate(state) + (1 − w) · state

    so the staler the buffered updates, the less the consensus direction is
    trusted. ``staleness=0`` reduces to the synchronous body exactly (w = 1,
    merge every round) — locked down in ``tests/test_schedule.py``.
    """

    staleness: int = 0
    staleness_pow: float = 0.5
    name = "async"

    def round_body(self, strategy, batch_size):
        period = int(self.staleness) + 1
        weight = float(period ** (-self.staleness_pow))

        def body(state, r, phase_key, train_x, train_y):
            rk = jax.random.fold_in(phase_key, r)
            xs, ys = sample_client_batches(
                train_x, train_y, jax.random.fold_in(rk, 0), batch_size)
            af = current_faults()
            if af is not None:
                # realized staleness: each client's merge weight comes from
                # the rounds it actually missed ((1+age)^-pow, FedBuff form)
                # instead of the configured scalar s — slow devices emerge
                # from the straggler chain
                active = af.real.active()
                new, metrics = strategy.local_update(
                    state, xs, ys, r, jax.random.fold_in(rk, 1))
                new = strategy.merge_participation(state, new, active)
                agg = strategy.aggregate_masked(
                    new, r, jax.random.fold_in(rk, 2), active)
                w = (1.0 + af.real.age) ** (-self.staleness_pow)
                if strategy.state_client_stacked(state):
                    merged = _fault_blend(agg, new, w, w.shape[0],
                                          jnp.mean(w))
                    hold = new
                else:
                    # server-style state: the aggregate folds into the
                    # previous global model at the mean realized discount
                    wbar = jnp.mean(w)
                    merged = jax.tree_util.tree_map(
                        lambda a, s: (wbar * a + (1.0 - wbar) * s)
                        .astype(s.dtype), agg, state)
                    hold = state
                if period > 1:
                    is_merge = jnp.equal(r % period, period - 1)
                    merged = jax.tree_util.tree_map(
                        lambda m, n: jnp.where(is_merge, m, n), merged, hold)
                merged = strategy.merge_participation(state, merged, active)
                empty = jnp.sum(active) == 0
                state = jax.tree_util.tree_map(
                    lambda s, n: jnp.where(empty, s, n), state, merged)
                return state, (metrics, {"participation": active})
            state, metrics = strategy.local_update(
                state, xs, ys, r, jax.random.fold_in(rk, 1))
            if period == 1:   # synchronous: identical to FullParticipation
                state = strategy.aggregate(state, r, jax.random.fold_in(rk, 2))
                return state, (metrics, {})

            def merge(s):
                agg = strategy.aggregate(s, r, jax.random.fold_in(rk, 2))
                return jax.tree_util.tree_map(
                    lambda a, b: (weight * a + (1.0 - weight) * b).astype(b.dtype),
                    agg, s)

            state = jax.lax.cond(jnp.equal(r % period, period - 1),
                                 merge, lambda s: s, state)
            return state, (metrics, {})

        return body

    def sharded_round_body(self, strategy, batch_size, ctx):
        period = int(self.staleness) + 1
        weight = float(period ** (-self.staleness_pow))

        def body(state, r, phase_key, train_x, train_y):
            rk = jax.random.fold_in(phase_key, r)
            xs, ys = ctx.sample_local_batches(
                train_x, train_y, jax.random.fold_in(rk, 0), batch_size)
            af = current_faults()
            if af is not None:
                active = af.real.active()
                local_active = ctx.shard_rows(active)
                new, metrics = strategy.sharded_local_update(
                    state, xs, ys, r, jax.random.fold_in(rk, 1), ctx)
                new = strategy.merge_participation(state, new, local_active)
                agg = strategy.sharded_aggregate_masked(
                    new, r, jax.random.fold_in(rk, 2), ctx, active,
                    local_active)
                w = (1.0 + af.real.age) ** (-self.staleness_pow)
                if strategy.state_client_stacked(state):
                    merged = _fault_blend(agg, new, ctx.shard_rows(w), ctx.m,
                                          jnp.mean(w))
                    hold = new
                else:
                    wbar = jnp.mean(w)
                    merged = jax.tree_util.tree_map(
                        lambda a, s: (wbar * a + (1.0 - wbar) * s)
                        .astype(s.dtype), agg, state)
                    hold = state
                if period > 1:
                    is_merge = jnp.equal(r % period, period - 1)
                    merged = jax.tree_util.tree_map(
                        lambda m, n: jnp.where(is_merge, m, n), merged, hold)
                merged = strategy.merge_participation(state, merged,
                                                      local_active)
                empty = jnp.sum(active) == 0
                state = jax.tree_util.tree_map(
                    lambda s, n: jnp.where(empty, s, n), state, merged)
                return state, (metrics, {"participation": active})
            state, metrics = strategy.sharded_local_update(
                state, xs, ys, r, jax.random.fold_in(rk, 1), ctx)
            if period == 1:   # synchronous: identical to FullParticipation
                state = strategy.sharded_aggregate(
                    state, r, jax.random.fold_in(rk, 2), ctx)
                return state, (metrics, {})
            # collectives must execute uniformly across shards, so the merge
            # is select-based rather than lax.cond: the aggregate (and its
            # all_gather/psum) runs every round and non-merge rounds select
            # the untouched state — bit-identical outcomes, uniform comms
            agg = strategy.sharded_aggregate(
                state, r, jax.random.fold_in(rk, 2), ctx)
            merged = jax.tree_util.tree_map(
                lambda a, b: (weight * a + (1.0 - weight) * b).astype(b.dtype),
                agg, state)
            is_merge = jnp.equal(r % period, period - 1)
            state = jax.tree_util.tree_map(
                lambda m, s: jnp.where(is_merge, m, s), merged, state)
            return state, (metrics, {})

        return body


def wrap_overlap(body, strategy, ctx):
    """Thread the strategy's prefetched halo blocks through the scan carry.

    The wrapped body's carry is ``(state, halos)``: the round's hooks trace
    with ``current_halos()`` holding the blocks exchanged at the END of the
    previous round (so the ppermute for round r's boundary rows was issued
    before round r-1's local compute finished — compute/communication
    overlap), and a fresh prefetch is issued from the new state afterwards.
    Strategies that return None from ``sharded_prefetch`` carry an empty
    tuple; their rounds trace exactly as before.
    """
    from repro.engine.strategy import sharded_halos

    def wrapped(carry, r, phase_key, *data):
        state, halos = carry
        empty = isinstance(halos, tuple) and not halos
        with sharded_halos(None if empty else halos):
            state, out = body(state, r, phase_key, *data)
        nxt = strategy.sharded_prefetch(state, ctx)
        return (state, () if nxt is None else nxt), out

    return wrapped


def make_schedule(cfg) -> RoundSchedule:
    """Build a RoundSchedule from a ``repro.config.ScheduleConfig``."""
    if cfg is None or cfg.kind == "full":
        return FullParticipation()
    if cfg.kind == "sampling":
        return ClientSampling(q=cfg.client_rate, mode=cfg.mode)
    if cfg.kind == "async":
        return AsyncStaleness(staleness=cfg.staleness,
                              staleness_pow=cfg.staleness_pow)
    raise ValueError(f"unknown schedule kind {cfg.kind!r}; "
                     "expected full | sampling | async")
