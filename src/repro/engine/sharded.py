"""Multi-mesh federation: the scanned round loop sharded over a client axis.

``ShardedEngine`` runs each ``RoundSchedule``'s round body under ``shard_map``
over a 1-D ``clients`` mesh (``repro.launch.mesh.make_client_mesh``): the
(M, ...) state/data stacks are sharded so each mesh slice hosts a disjoint
client shard, local training is embarrassingly parallel across slices, and
aggregation happens through explicit collectives (all_gather / ppermute; the
specs come from ``repro.sharding.rules.client_specs``). This is the structure
Bellet et al.'s P2P learning and MAPL exploit: clients are independent
between gossip steps.

Equivalence contract (locked by ``tests/test_sharded_engine.py``): a sharded
run is BIT-IDENTICAL to the single-device engine under FullParticipation, and
numerically tight under ClientSampling/AsyncStaleness. Three mechanisms make
that possible:

  * layout-invariant randomness — ``jax.random.split(key, M)`` is not
    prefix-stable, so every shard recomputes the full M-way split (cheap,
    replicated) and slices its own block (``ClientShardCtx.shard_keys``);
    batch-index draws are likewise drawn at full (M, B) and row-sliced;
  * gather-exact aggregation — the default ``Strategy.sharded_aggregate``
    all_gathers the client stacks and runs the single-device aggregate
    verbatim, so the arithmetic (and its float rounding) is identical;
    strategies override with cheaper collectives where the result provably
    matches (P4's shard-resident group mean, DP-DSGT's ppermute ring);
  * deterministic padding — when M % n_devices != 0 the stacks are padded to
    the next multiple; padded slots train on zeroed data, are excluded from
    every aggregate (out-of-range segment ids / zero masks), and are sliced
    away before evaluate/checkpoint/History, so they can never leak into
    results or byte accounting.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.engine.loop import CHUNK_STATS, Engine, _cache_get, _cache_put
from repro.engine.strategy import FederatedData, runtime_params
from repro.sharding.rules import CLIENT_AXIS, client_specs, shard_map_compat


def _pad_rows(arr, target: int):
    """Zero-pad the leading (client) axis to ``target`` rows."""
    arr = jnp.asarray(arr)
    if arr.shape[0] == target:
        return arr
    pad = [(0, target - arr.shape[0])] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, pad)


def _pad_axis1(arr, target: int):
    """Zero-pad axis 1 (the client axis of a (rounds, M, ...) stack) to
    ``target`` — the same zero padding ``shard_rows`` applies per round."""
    if arr.shape[1] == target:
        return arr
    pad = [(0, 0), (0, target - arr.shape[1])] + [(0, 0)] * (arr.ndim - 2)
    return jnp.pad(arr, pad)


class ClientShardCtx:
    """Trace-time view of the client mesh inside the shard_map region.

    ``M`` is the true client count, ``n`` the mesh-axis size, ``M_pad`` the
    padded stack height (next multiple of n), ``m = M_pad // n`` the rows
    this shard holds. All helpers are traced (called from the round body).
    """

    def __init__(self, mesh, axis: str, num_clients: int):
        self.mesh = mesh
        self.axis = axis
        self.M = int(num_clients)
        self.n = int(mesh.shape[axis])
        self.M_pad = -(-self.M // self.n) * self.n
        self.m = self.M_pad // self.n
        # per-round prefetched randomness (``prefetched``): this round's
        # (m, 2) key slice / (m, B) batch-index slice, already sharded.
        # Single-use — consumed by the first shard_keys / batch draw of the
        # round, so a second call (if a strategy ever makes one) falls back
        # to the replicated recompute instead of silently reusing a stream.
        self._pf_keys = None
        self._pf_idx = None

    @contextlib.contextmanager
    def prefetched(self, keys, idx):
        """Trace-time context installed by the engine's scan body: the
        round's per-client key slice and batch-index slice were derived
        *outside* the scan (one vmapped draw for the whole chunk, sharded
        over the mesh) so the round body itself runs zero random ops."""
        self._pf_keys, self._pf_idx = keys, idx
        try:
            yield
        finally:
            self._pf_keys = self._pf_idx = None

    # ------------------------------------------------------------- indexing
    def shard_offset(self):
        """First global (padded) client row held by this shard."""
        return jax.lax.axis_index(self.axis) * self.m

    def shard_rows(self, arr):
        """Slice this shard's rows from a replicated full-stack array
        ((M, ...) arrays are zero-padded to (M_pad, ...) first)."""
        if arr.shape[0] == self.M:
            arr = _pad_rows(arr, self.M_pad)
        return jax.lax.dynamic_slice_in_dim(arr, self.shard_offset(), self.m)

    def valid_mask(self):
        """(m,) float32: 1 for real clients, 0 for padded slots."""
        idx = self.shard_offset() + jnp.arange(self.m)
        return (idx < self.M).astype(jnp.float32)

    # ------------------------------------------------------------ randomness
    def shard_keys(self, key):
        """This shard's per-client keys — the *global* M-way split's slice,
        so client i's stream is independent of the mesh layout (split is not
        prefix-stable; every shard recomputes the full split, replicated).
        When the engine prefetched this round's slice, consume it instead —
        bit-identical (same derivation, hoisted out of the scan body)."""
        if self._pf_keys is not None:
            out, self._pf_keys = self._pf_keys, None
            return out
        return self.shard_rows(jax.random.split(key, self.M))

    def sample_local_batches(self, train_x, train_y, key, batch_size):
        """Sharded twin of ``sample_client_batches``: the (M, B) index draw
        is replicated (identical to the single-device draw), then row-sliced
        onto this shard's data. ``batch_size=None`` = full local batch."""
        if batch_size is None:
            return train_x, train_y
        if self._pf_idx is not None:
            idx, self._pf_idx = self._pf_idx, None
        else:
            R = train_y.shape[1]
            idx = jax.random.randint(key, (self.M, batch_size), 0, R)
            idx = self.shard_rows(idx)
        xs = jnp.take_along_axis(
            train_x, idx.reshape(idx.shape + (1,) * (train_x.ndim - 2)),
            axis=1)
        ys = jnp.take_along_axis(train_y, idx, axis=1)
        return xs, ys

    # ----------------------------------------------------------- collectives
    def gather(self, tree):
        """all_gather every leaf's client axis back to the full, UNPADDED
        (M, ...) stack (replicated on every shard)."""
        def g(x):
            full = jax.lax.all_gather(x, self.axis, axis=0, tiled=True)
            return full[: self.M] if self.M_pad != self.M else full
        return jax.tree_util.tree_map(g, tree)

    def scatter_like(self, out, full_in):
        """Re-shard an aggregate's output: leaves still shaped like the
        gathered (M, ...) input take this shard's row block (padded slots
        zeroed); leaves whose shape changed (e.g. FedAvg's (M, ...) → global
        model) are replicated results and pass through."""
        out_leaves, out_def = jax.tree_util.tree_flatten(out)
        full_leaves, full_def = jax.tree_util.tree_flatten(full_in)
        if out_def != full_def:
            return out
        res = []
        for o, f in zip(out_leaves, full_leaves):
            if o.shape == f.shape and o.ndim >= 1 and o.shape[0] == self.M:
                res.append(self.shard_rows(o))
            else:
                res.append(o)
        return jax.tree_util.tree_unflatten(out_def, res)

    def metric_means(self, per_client: Dict[str, Any]) -> Dict[str, Any]:
        """Global scalar means bit-identical to the single-device
        ``jnp.mean`` over the (M,) per-client metric vector: gather, unpad,
        then mean the exact same vector."""
        def mean(v):
            if getattr(v, "ndim", 0) >= 1 and v.shape[0] == self.m:
                return jnp.mean(self.gather(v))
            return v
        return {k: mean(v) for k, v in per_client.items()}


@dataclass(eq=False)
class ShardedEngine(Engine):
    """Engine whose chunks run under shard_map over a client mesh axis.

    ``mesh`` defaults to a 1-D mesh over every host device
    (``make_client_mesh``); pass any mesh containing ``client_axis``. The
    loop structure (eval cadence, History, ledger, checkpoints, byte
    accounting) is inherited — only the chunk execution and the
    client-padding representation differ, so sharded and single-device runs
    share everything the equivalence tests compare.
    """

    mesh: Optional[Any] = None
    client_axis: str = CLIENT_AXIS

    # the shard_map trace stays tap-free (callbacks inside the region would
    # fire once per device); the telemetry tap streams the same per-round
    # events host-side from the chunk's stacked outputs instead, so tap
    # on/off never changes the sharded trace or its cache key
    _tap_in_jit = False

    def __post_init__(self):
        super().__post_init__()
        if self.mesh is None:
            from repro.launch.mesh import make_client_mesh
            self.mesh = make_client_mesh(axis=self.client_axis)
        if self.client_axis not in self.mesh.shape:
            raise ValueError(
                f"mesh {dict(self.mesh.shape)} has no {self.client_axis!r} "
                "axis")
        self._padded_data: Dict[int, Tuple[Any, Any]] = {}
        self._M: Optional[int] = None

    # ------------------------------------------------------------ chunk key
    def _mesh_fingerprint(self) -> Tuple:
        n = int(self.mesh.shape[self.client_axis])
        devs = tuple(d.id for d in self.mesh.devices.flat)
        # self._M is set before any chunk builds (fit pads state first); it
        # keys the trace because ctx.M is baked into the compiled body
        return ("sharded", self.client_axis, n, devs, self._M)

    # --------------------------------------------------------- chunk builder
    def _chunk_fn(self, length: int, batch_size: Optional[int],
                  data: FederatedData):
        self._M = data.num_clients
        key_ = self._chunk_key(length, batch_size)
        fn = _cache_get(key_)
        if fn is not None:
            return fn
        ctx = ClientShardCtx(self.mesh, self.client_axis, data.num_clients)
        from repro.engine.schedule import wrap_overlap
        body = self.schedule.sharded_round_body(self.strategy, batch_size, ctx)
        body = wrap_overlap(body, self.strategy, ctx)
        faulted = self.faults is not None
        if faulted:
            from repro.resilience import wrap_round_body
            body = wrap_round_body(body, self.faults)
        mesh, axis = self.mesh, self.client_axis
        strategy = self.strategy
        stacked_state = self.strategy.state_client_stacked
        repl = lambda tree: jax.tree_util.tree_map(lambda _: P(), tree)

        # Hot-loop randomness, hoisted: every shard would otherwise
        # recompute the full M-way key split and (M, B) batch-index draw
        # inside every scanned round (replicated, so it is pure overhead
        # that scales with M). Derive the whole chunk's worth in one
        # vmapped draw — bit-identical streams, since fold_in/split/randint
        # are elementwise over rounds — pad with the same zeros shard_rows
        # would add, and feed each round its slice through the scan xs.
        # The draw runs in its OWN unsharded jit: under the mesh constraint
        # the SPMD partitioner replicates the whole threefry chain on every
        # device (measured ~4x the unsharded cost), so hash once and reshard
        # the small result with device_put instead.
        R = data.train_y.shape[1]
        row_sh = NamedSharding(mesh, P(None, axis))

        @jax.jit
        def draw(phase_key, start):
            rounds = start + jnp.arange(length)
            rks = jax.vmap(lambda r: jax.random.fold_in(phase_key, r))(rounds)
            keys_all = jax.vmap(
                lambda k: jax.random.split(jax.random.fold_in(k, 1),
                                           ctx.M))(rks)
            pf = [_pad_axis1(keys_all, ctx.M_pad)]
            if batch_size is not None:
                idx_all = jax.vmap(
                    lambda k: jax.random.randint(
                        jax.random.fold_in(k, 0), (ctx.M, batch_size), 0,
                        R))(rks)
                pf.append(_pad_axis1(idx_all, ctx.M_pad))
            return tuple(pf)

        def chunk(state, phase_key, train_x, train_y, start, rt, *pf):
            CHUNK_STATS["traces"] += 1
            # under faults the carry is (strategy state, FaultState); the
            # fault chains are replicated — every slice steps the identical
            # Markov transition from the replicated phase key, which is what
            # makes sharded ≡ single-device hold under every fault regime
            st = state[0] if faulted else state
            s0 = (client_specs(st, ctx.M_pad, axis)
                  if stacked_state(st) else repl(st))
            sspec = (s0, repl(state[1])) if faulted else s0
            rounds = start + jnp.arange(length)

            def sharded(state, phase_key, tx, ty, rounds, rt, *pf):
                with runtime_params(rt):
                    st0 = state[0] if faulted else state
                    h0 = strategy.sharded_prefetch(st0, ctx)
                    h0 = () if h0 is None else h0
                    carry = (((state[0], h0), state[1]) if faulted
                             else (state, h0))

                    def scan_body(c, xs_r):
                        r, keys_r = xs_r[0], xs_r[1]
                        idx_r = xs_r[2] if len(xs_r) > 2 else None
                        with ctx.prefetched(keys_r, idx_r):
                            return body(c, r, phase_key, tx, ty)

                    # long chunks amortize scan bookkeeping by unrolling:
                    # XLA fuses across consecutive rounds, which is where
                    # the remaining per-round dispatch overhead of the
                    # shard_map hot loop lives. Short chunks (the eval-dense
                    # equivalence runs) keep unroll=1 — their bodies are the
                    # heavy mixing ones and 8x the trace is real compile cost.
                    unroll = 8 if length >= 64 else 1
                    carry, out = jax.lax.scan(scan_body, carry,
                                              (rounds,) + pf, unroll=unroll)
                    if faulted:
                        (st, _h), fstate = carry
                        return (st, fstate), out
                    st, _h = carry
                    return st, out

            return shard_map_compat(
                sharded, mesh,
                in_specs=(sspec, P(), P(axis), P(axis), P(), P())
                + (P(None, axis),) * len(pf),
                out_specs=(sspec, P()),
            )(state, phase_key, train_x, train_y, rounds, rt, *pf)

        jfn = jax.jit(chunk, donate_argnums=0)

        def run(state, phase_key, train_x, train_y, start, rt):
            pf = tuple(jax.device_put(a, row_sh)
                       for a in draw(phase_key, start))
            return jfn(state, phase_key, train_x, train_y, start, rt, *pf)

        _cache_put(key_, run)
        return run

    # --------------------------------------------- padded client representation
    def _train_arrays(self, data: FederatedData):
        # the cached entry holds the FederatedData itself: the identity check
        # can't be fooled by a recycled object id, and the reference keeps the
        # id stable for as long as the entry exists
        cached = self._padded_data.get(id(data))
        if cached is None or cached[0] is not data:
            n = int(self.mesh.shape[self.client_axis])
            M_pad = -(-data.num_clients // n) * n
            sh = NamedSharding(self.mesh, P(self.client_axis))
            cached = (data,
                      jax.device_put(_pad_rows(data.train_x, M_pad), sh),
                      jax.device_put(_pad_rows(data.train_y, M_pad), sh))
            self._padded_data[id(data)] = cached
        return cached[1], cached[2]

    def _prepare_state(self, state, data: FederatedData):
        self._M = M = data.num_clients
        n = int(self.mesh.shape[self.client_axis])
        M_pad = -(-M // n) * n
        stacked = self.strategy.state_client_stacked(state)
        row_sh = NamedSharding(self.mesh, P(self.client_axis))
        rep_sh = NamedSharding(self.mesh, P())

        def prep(leaf):
            leaf = jnp.asarray(leaf)
            if stacked and leaf.ndim >= 1 and leaf.shape[0] == M:
                return jax.device_put(_pad_rows(leaf, M_pad), row_sh)
            return jax.device_put(leaf, rep_sh)

        return jax.tree_util.tree_map(prep, state)

    def _finalize_state(self, state):
        M = self._M
        n = int(self.mesh.shape[self.client_axis])
        M_pad = -(-M // n) * n
        stacked = self.strategy.state_client_stacked(state)
        dev0 = jax.devices()[0]

        def unpad(leaf):
            if (stacked and getattr(leaf, "ndim", 0) >= 1
                    and leaf.shape[0] == M_pad and M_pad != M):
                leaf = leaf[:M]
            # devolve to a plain single-device array: evaluate/checkpoint/
            # callers then run the exact single-device computation (leaving
            # the mesh sharding in place reorders eval reductions by a ulp)
            return jax.device_put(leaf, dev0)

        return jax.tree_util.tree_map(unpad, state)
