"""Strategy interface + registry for the unified federation engine.

Every federated method in the repo — P4 and all §4.2.1 baselines — is a
``Strategy``: a small object exposing ``init → local_update → aggregate →
eval_params`` hooks over client state pytrees (usually stacked ``(M, ...)``
trees, one leading slot per client). The engine (``repro.engine.loop``) owns
the round schedule, on-device batch sampling, eval cadence, history, and the
optional communication/checkpoint hooks, so methods cannot drift apart on
anything but their update rule.

This mirrors how Bellet et al. (Personalized and Private P2P ML) and MAPL
frame decentralized learning: one round schedule, pluggable local-update /
communicate / aggregate operators.

Registry: ``@register_strategy("name")`` on the class; ``get_strategy("name")``
returns the class so sweeps can be driven by config strings.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

_REGISTRY: Dict[str, type] = {}


# ---------------------------------------------------------------------------
# Runtime parameters: host-side scalars (today: the DP noise multiplier σ)
# threaded into the jitted chunk as *arguments* instead of being baked into
# the trace as constants. This is what lets a sweep over ε/σ reuse one
# compiled chunk across points: the engine activates the context while the
# chunk traces, strategies read the traced value through ``runtime_sigma``,
# and subsequent calls just pass a different scalar.
# ---------------------------------------------------------------------------

_RUNTIME = threading.local()


@contextlib.contextmanager
def runtime_params(params: Dict[str, jnp.ndarray]):
    """Trace-time context installed by the engine around the chunk body."""
    prev = getattr(_RUNTIME, "params", None)
    _RUNTIME.params = params
    try:
        yield
    finally:
        _RUNTIME.params = prev


# Compute/communication overlap: the sharded engine's scan body threads the
# strategy's prefetched halo blocks (``Strategy.sharded_prefetch``) through
# the scan carry and exposes them to the NEXT round's hooks through this
# context — round r+1's boundary ppermute is issued at the end of round r's
# body, before the next local_update trains. Same trace-time mechanism as
# runtime_params: no hook-signature changes for strategies that don't opt in.
_HALOS = threading.local()


@contextlib.contextmanager
def sharded_halos(halos):
    """Trace-time context installed by the sharded scan body around each
    round: ``halos`` is whatever the strategy's ``sharded_prefetch`` returned
    at the end of the previous round (None when it doesn't prefetch)."""
    prev = getattr(_HALOS, "value", None)
    _HALOS.value = halos
    try:
        yield
    finally:
        _HALOS.value = prev


def current_halos():
    return getattr(_HALOS, "value", None)


def runtime_sigma(static_sigma):
    """The traced σ if an engine runtime context is active, else the host
    value. Only substitutes when DP is actually on (static σ > 0) so the
    σ == 0 trace keeps its noiseless structure — DP on/off is part of the
    chunk-cache key, the magnitude is not."""
    if isinstance(static_sigma, (int, float)) and static_sigma > 0:
        d = getattr(_RUNTIME, "params", None)
        if d is not None and "sigma" in d:
            return d["sigma"]
    return static_sigma


class _IdToken:
    """Identity-keyed fingerprint entry for field values that aren't
    hashable by value. The chunk cache holds the key (and therefore the
    object) alive, so the identity is stable for the cache's lifetime —
    two distinct instances never collide, they just don't share chunks."""
    __slots__ = ("obj",)

    def __init__(self, obj):
        self.obj = obj

    def __hash__(self):
        return id(self.obj)

    def __eq__(self, other):
        return isinstance(other, _IdToken) and other.obj is self.obj


def register_strategy(name: str) -> Callable[[type], type]:
    """Class decorator: register a Strategy subclass under ``name``."""
    def deco(cls: type) -> type:
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_strategy(name: str) -> type:
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@dataclass(eq=False)  # hashable by identity: safe to close over in jit
class FederatedData:
    """Client-stacked datasets, device-resident for the whole run.

    ``train_x: (M, R, ...)``, ``train_y: (M, R)``; test likewise (the test
    leading dim may differ from M, e.g. the pooled-data centralized baseline
    trains on (1, N, ...) but reports per-client test accuracy).
    """
    train_x: jnp.ndarray
    train_y: jnp.ndarray
    test_x: jnp.ndarray
    test_y: jnp.ndarray

    def __post_init__(self):
        self.train_x = jnp.asarray(self.train_x)
        self.train_y = jnp.asarray(self.train_y)
        self.test_x = jnp.asarray(self.test_x)
        self.test_y = jnp.asarray(self.test_y)

    @property
    def num_clients(self) -> int:
        return self.train_y.shape[0]

    @property
    def samples_per_client(self) -> int:
        return self.train_y.shape[1]


@dataclass(eq=False)
class Strategy:
    """Base class for federated methods run by the engine.

    State is an arbitrary pytree owned by the strategy (stacked client
    params, plus any method state: control variates, gradient trackers, ...).
    All hooks except ``init`` are traced into the engine's scanned round body,
    so they must be jit-compatible; the round index ``r`` and all keys arrive
    as traced scalars.
    """

    # plain class attribute (NOT a dataclass field): register_strategy
    # overrides it per subclass and instances resolve it through the class
    name = "base"
    # engine chunk-cache invalidation: the compiled round chunks close over
    # the strategy, so any host-side attribute change that alters the traced
    # computation (e.g. P4Strategy.set_groups) MUST bump this counter.
    # (σ is exempt: it flows through the chunk as a runtime argument.)
    cache_token = 0
    # communication topology (repro.topology): None = the strategy's built-in
    # pattern (DP-DSGT's ring, P4's group mean). Subclasses that shadow this
    # with a dataclass field get it hashed into the default fingerprint
    # automatically (Topology is hashable by value).
    topology = None
    _mix_plan = None

    # ------------------------------------------------------------ chunk cache
    def fingerprint(self) -> Tuple:
        """Value key for the engine's cross-instance compiled-chunk cache:
        two strategies with equal fingerprints must trace to the same chunk
        computation (σ excluded — it is a runtime argument; only its
        positivity, which gates the noise ops, is keyed). The default walks
        the dataclass fields; unhashable values fall back to identity tokens
        (safe: no cross-instance reuse). Override to enable value-based
        reuse for composite fields (see P4Strategy)."""
        vals = [type(self).__name__, self.cache_token]
        # the configured topology changes the traced mixing step even when it
        # is not a dataclass field (set via set_topology); include it so two
        # same-token instances with different graphs can never share a chunk
        field_names = {f.name for f in dataclasses.fields(self)}
        if "topology" not in field_names and self.topology is not None:
            vals.append(self.topology.fingerprint())
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "sigma":
                vals.append(isinstance(v, (int, float)) and v > 0)
                continue
            try:
                hash(v)
            except TypeError:
                v = _IdToken(v)
            vals.append(v)
        return tuple(vals)

    def runtime_params(self) -> Dict[str, float]:
        """Host scalars the engine passes into the chunk each call (read back
        at trace time via ``runtime_sigma``). Keys must be stable for a given
        fingerprint — presence/absence is part of the chunk-cache key."""
        sigma = getattr(self, "sigma", 0.0)
        if isinstance(sigma, (int, float)) and sigma > 0:
            return {"sigma": float(sigma)}
        return {}

    # ------------------------------------------------------------------ hooks
    def init(self, key, data: FederatedData, batch_size: Optional[int]):
        """Build the initial state pytree (host-side, before tracing)."""
        raise NotImplementedError

    def local_update(self, state, xs, ys, r, key):
        """One round of local training on the sampled batches.

        Returns ``(state, metrics)`` where metrics is a (possibly empty) dict
        of scalars with a structure that is identical every round.
        """
        raise NotImplementedError

    def local_update_keyed(self, state, xs, ys, r, keys):
        """Per-client-keyed form of ``local_update``: ``keys`` is the stacked
        key array aligned with the leading client axis. Strategies that
        support the sharded engine implement this (and express
        ``local_update`` as ``local_update_keyed(..., split(key, M))``) so a
        client shard can be driven with the *global* key split's slice —
        per-client randomness becomes layout-invariant. Returns
        ``(state, per_client_metrics)`` with (M',)-shaped metric leaves."""
        raise NotImplementedError(
            f"{type(self).__name__} does not implement local_update_keyed; "
            "it cannot run under the sharded engine")

    def aggregate(self, state, r, key):
        """Communication/aggregation step after local updates (identity by
        default — e.g. the local-training baseline never communicates)."""
        return state

    # ------------------------------------------------------------- topology
    def set_topology(self, topology, kernels=None) -> None:
        """Install a communication graph (``repro.topology``): the mixing
        plan is compiled once host-side and the traced ``mix``/``mix_sharded``
        hooks below apply it per round. Changes the traced computation, so
        compiled chunks are invalidated; ``None`` restores the strategy's
        built-in pattern. ``kernels`` (a ``KernelConfig``) opts the halo mix
        step into the dispatch autotuner's row-tile search."""
        from repro.topology.mixing import make_plan
        self.topology = topology
        self._mix_plan = (None if topology is None
                          else make_plan(topology, kernels=kernels))
        self.cache_token += 1

    def mix(self, stacked_tree, r, key):
        """One gossip round over the configured topology: t ← W_r t on every
        client-stacked leaf, with the round's link faults drawn in-jit from
        ``key``'s fault stream. Identity when no topology is configured —
        strategies call this unconditionally and topology-free runs trace
        nothing extra."""
        if self._mix_plan is None:
            return stacked_tree
        from repro.resilience import current_faults
        from repro.topology.mixing import mix_stacked
        af = current_faults()
        # a correlated fault process supersedes the plan's i.i.d. rates: the
        # realized keep matrix (bursty links, outages, partitions) replaces
        # the per-round memoryless draw
        keep = None if af is None else af.real.keep
        return mix_stacked(stacked_tree, self._mix_plan, r, key, keep=keep)

    def mix_sharded(self, stacked_tree, r, key, ctx, halo=None):
        """Sharded twin of ``mix`` (inside the shard_map region): ppermute
        halo exchange for banded/bounded-bandwidth graphs, slice-local
        gathers when every edge is shard-resident, gather→mix→re-shard
        otherwise. ``halo`` optionally carries boundary rows already
        exchanged by ``sharded_prefetch`` in the previous round (overlap)."""
        if self._mix_plan is None:
            return stacked_tree
        from repro.resilience import current_faults
        from repro.topology.mixing import mix_stacked_sharded
        af = current_faults()
        keep = None if af is None else af.real.keep
        return mix_stacked_sharded(stacked_tree, self._mix_plan, r, key, ctx,
                                   keep=keep, halo=halo)

    # ------------------------------------------------------- sharded engine
    # These hooks run inside a shard_map region over the client mesh axis
    # (``repro.engine.sharded``): ``state``/``xs``/``ys`` hold this shard's
    # client rows, ``ctx`` is the ClientShardCtx. Defaults are bit-exact with
    # the single-device path by construction; strategies override to replace
    # the all_gather round-trip with cheaper collectives (P4's pod-resident
    # group mean, DP-DSGT's ppermute ring gossip).

    def state_client_stacked(self, state) -> bool:
        """Whether the *carry* state is client-stacked (leading client dim on
        every stacked leaf). Server-style strategies whose carry is a single
        global model (FedAvg, Scaffold) return False so the engine replicates
        the carry instead of trusting the leading-dim shape heuristic."""
        return True

    def sharded_local_update(self, state, xs, ys, r, key, ctx):
        """Local update on this shard's clients. Default: derive the full
        run's per-client keys (identical on every shard), feed this shard's
        slice to ``local_update_keyed``, and reduce metrics to the same
        global means the single-device path records."""
        state, per_client = self.local_update_keyed(
            state, xs, ys, r, ctx.shard_keys(key))
        return state, ctx.metric_means(per_client)

    def sharded_prefetch(self, state, ctx):
        """Issue next-round boundary transfers from the end-of-round state
        (compute/communication overlap). Called by the sharded scan body
        right after the round's hooks; whatever pytree it returns is carried
        to the next round and exposed back to the hooks via
        ``current_halos()`` while they trace. Return None (the default) to
        opt out — the carry then holds an empty placeholder and the mixing
        step issues its own exchange inline."""
        return None

    def sharded_aggregate(self, state, r, key, ctx):
        """Aggregation as explicit collectives. Default: all_gather the
        client stacks to the full (M, ...) trees, run the single-device
        ``aggregate`` verbatim (bit-identical arithmetic), slice this shard's
        rows back out. Replicated (non-stacked) outputs pass through.
        Strategies that never communicate (``aggregate`` left as the base
        identity — local training, gossip-in-local_update methods) skip the
        round-trip entirely."""
        if type(self).aggregate is Strategy.aggregate:
            return state
        full = ctx.gather(state)
        return ctx.scatter_like(self.aggregate(full, r, key), full)

    def sharded_aggregate_masked(self, state, r, key, ctx, mask, local_mask):
        """Cohort aggregation under a sampling schedule: ``mask`` is the full
        (M,) participation mask (replicated — every shard drew the same one),
        ``local_mask`` its rows for this shard."""
        if (type(self).aggregate is Strategy.aggregate
                and type(self).aggregate_masked is Strategy.aggregate_masked):
            # merge_participation(state, identity(state)) == state bitwise
            return state
        full = ctx.gather(state)
        return ctx.scatter_like(self.aggregate_masked(full, r, key, mask),
                                full)

    # --------------------------------------------------------- paged cohorts
    # These hooks run inside a PagedEngine chunk (``repro.engine.population``):
    # ``state``/``xs``/``ys`` hold the cohort's compact (C, ...) rows and
    # ``pctx`` is the PagedCtx mapping cohort slots to global client ids.
    # Defaults are bit-exact with the resident path by construction: per-client
    # randomness comes from the *global* M-way key split (layout-invariant),
    # and cohort aggregation scatter-expands to the full (M, ...) stack so the
    # resident reduction runs verbatim (identical float rounding).

    def paged_local_update(self, state, xs, ys, r, key, pctx):
        """Local update on the cohort's rows. Default: slice the global
        per-client key split at the cohort's ids and reduce metrics over the
        valid (non-padding) slots."""
        state, per_client = self.local_update_keyed(
            state, xs, ys, r, pctx.cohort_keys(key))
        return state, pctx.metric_means(per_client)

    def mix_paged(self, tree_c, r, key, pctx):
        """Paged twin of ``mix``: the same per-row gossip arithmetic with
        neighbor reads resolved through the cohort's slot map. The cohort
        planner closed the cohort over in-neighbors
        (``paged_cohort_closure``), so every participant row reads exactly
        the values the resident step reads."""
        if self._mix_plan is None:
            return tree_c
        from repro.resilience import current_faults
        from repro.topology.mixing import mix_stacked_paged
        af = current_faults()
        keep = None if af is None else af.real.keep
        return mix_stacked_paged(tree_c, self._mix_plan, r, key, pctx,
                                 keep=keep)

    def paged_aggregate_masked(self, state, r, key, mask, pctx):
        """Cohort aggregation under a sampling schedule: ``mask`` is the full
        (M,) participation mask (the paged body draws the identical full-M
        mask the resident body draws). Default: scatter-expand the compact
        rows into a zeros-backed (M, ...) stack, run the resident
        ``aggregate_masked`` verbatim, take the cohort rows back. Absent
        clients contribute exact zero terms either way, so the reduction is
        bit-identical up to the sign of zero."""
        if (type(self).aggregate is Strategy.aggregate
                and type(self).aggregate_masked is Strategy.aggregate_masked):
            # merge_participation(state, identity(state)) == state bitwise
            return state
        full = pctx.expand(state)
        return pctx.compact_like(self.aggregate_masked(full, r, key, mask),
                                 full)

    def paged_cohort_closure(self, ids, rounds):
        """Host-side: global client ids a chunk must page in beyond the
        sampled participants — the union with every participant's in-neighbors
        under the configured mixing plan (a participant's gossip step reads
        its neighbors' last-known state). ``ids``/``rounds`` are numpy."""
        if self._mix_plan is None:
            return ids
        from repro.topology.mixing import plan_in_neighbors
        return plan_in_neighbors(self._mix_plan, ids, rounds)

    # ------------------------------------------------- partial participation
    def merge_participation(self, prev_state, new_state, mask):
        """Under a ClientSampling schedule: keep absent clients' state.

        Default: for every leaf stacked per-client (leading dim == M with an
        unchanged shape), select ``new`` where the (M,) mask is 1 and ``prev``
        where it is 0; other leaves (and states whose pytree structure changed
        mid-round, e.g. a server-style global→clients expansion) pass through
        for ``aggregate_masked`` to handle. Override when client identity
        lives elsewhere in the state."""
        prev_leaves, prev_def = jax.tree_util.tree_flatten(prev_state)
        new_leaves, new_def = jax.tree_util.tree_flatten(new_state)
        if prev_def != new_def:
            return new_state
        M = mask.shape[0]

        def sel(o, n):
            if n.ndim >= 1 and n.shape == o.shape and n.shape[0] == M:
                m = mask.reshape((M,) + (1,) * (n.ndim - 1))
                return jnp.where(m > 0, n, o)
            return n

        return jax.tree_util.tree_unflatten(
            prev_def, [sel(o, n) for o, n in zip(prev_leaves, new_leaves)])

    def aggregate_masked(self, state, r, key, mask):
        """Aggregation under partial participation. Default: run the full
        aggregate, then keep absent clients' pre-aggregation state — present
        clients therefore see absent peers' last-known values (a stale cache),
        and absent clients receive nothing. Override for cohort-weighted
        aggregation (FedAvg/Scaffold means, P4's masked group mean)."""
        return self.merge_participation(
            state, self.aggregate(state, r, key), mask)

    def eval_params(self, state):
        """Stacked (M_test, ...) per-client parameters to evaluate."""
        raise NotImplementedError

    # ---------------------------------------------------------------- derived
    def evaluate(self, state, test_x, test_y):
        """(M,) per-client test accuracy; override for non-stacked methods."""
        from repro.core.small_models import accuracy
        params = self.eval_params(state)
        return jax.vmap(lambda p, x, y: accuracy(self.apply_fn(p, x), y))(
            params, test_x, test_y)

    # ------------------------------------------------------- optional hooks
    def log_communication(self, net, state, r: int, mask=None,
                          phase_key=None, faults=None) -> None:
        """Record the round's messages on a P2PNetwork (host-side, called by
        the engine at eval boundaries for each elapsed round). ``mask`` is the
        round's (M,) participation mask under a sampling schedule (None for
        full participation) — absent clients must contribute zero bytes.
        ``phase_key`` is the engine's phase key: strategies with a faulty
        topology re-derive the round's exact link-fault realization from it
        (``repro.topology.faults.host_fault_masks``) so dropped links also
        contribute zero bytes. ``faults`` is the round's replayed
        ``repro.resilience.HostFaults`` when the engine runs a correlated
        fault process (it supersedes the topology's i.i.d. rates)."""

    def set_sigma(self, sigma: float) -> None:
        """Engine hook for target-ε calibration (``Engine.fit(target_epsilon=
        ...)``): install the calibrated noise multiplier. σ flows into
        compiled chunks as a runtime argument (``runtime_sigma``), so this no
        longer invalidates the chunk cache — which is exactly what lets an
        ε-sweep reuse one compiled chunk across calibration points."""
        if not hasattr(self, "sigma"):
            raise AttributeError(
                f"{type(self).__name__} has no 'sigma' attribute; override "
                "set_sigma to route the calibrated noise multiplier")
        self.sigma = float(sigma)

    def state_to_save(self, state):
        """Pytree persisted by the engine's checkpoint hook."""
        return state
