"""Strategy interface + registry for the unified federation engine.

Every federated method in the repo — P4 and all §4.2.1 baselines — is a
``Strategy``: a small object exposing ``init → local_update → aggregate →
eval_params`` hooks over client state pytrees (usually stacked ``(M, ...)``
trees, one leading slot per client). The engine (``repro.engine.loop``) owns
the round schedule, on-device batch sampling, eval cadence, history, and the
optional communication/checkpoint hooks, so methods cannot drift apart on
anything but their update rule.

This mirrors how Bellet et al. (Personalized and Private P2P ML) and MAPL
frame decentralized learning: one round schedule, pluggable local-update /
communicate / aggregate operators.

Registry: ``@register_strategy("name")`` on the class; ``get_strategy("name")``
returns the class so sweeps can be driven by config strings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

_REGISTRY: Dict[str, type] = {}


def register_strategy(name: str) -> Callable[[type], type]:
    """Class decorator: register a Strategy subclass under ``name``."""
    def deco(cls: type) -> type:
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_strategy(name: str) -> type:
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@dataclass(eq=False)  # hashable by identity: safe to close over in jit
class FederatedData:
    """Client-stacked datasets, device-resident for the whole run.

    ``train_x: (M, R, ...)``, ``train_y: (M, R)``; test likewise (the test
    leading dim may differ from M, e.g. the pooled-data centralized baseline
    trains on (1, N, ...) but reports per-client test accuracy).
    """
    train_x: jnp.ndarray
    train_y: jnp.ndarray
    test_x: jnp.ndarray
    test_y: jnp.ndarray

    def __post_init__(self):
        self.train_x = jnp.asarray(self.train_x)
        self.train_y = jnp.asarray(self.train_y)
        self.test_x = jnp.asarray(self.test_x)
        self.test_y = jnp.asarray(self.test_y)

    @property
    def num_clients(self) -> int:
        return self.train_y.shape[0]

    @property
    def samples_per_client(self) -> int:
        return self.train_y.shape[1]


@dataclass(eq=False)
class Strategy:
    """Base class for federated methods run by the engine.

    State is an arbitrary pytree owned by the strategy (stacked client
    params, plus any method state: control variates, gradient trackers, ...).
    All hooks except ``init`` are traced into the engine's scanned round body,
    so they must be jit-compatible; the round index ``r`` and all keys arrive
    as traced scalars.
    """

    # plain class attribute (NOT a dataclass field): register_strategy
    # overrides it per subclass and instances resolve it through the class
    name = "base"
    # engine chunk-cache invalidation: the compiled round chunks close over
    # the strategy, so any host-side attribute change that alters the traced
    # computation (e.g. P4Strategy.set_groups) MUST bump this counter
    cache_token = 0

    # ------------------------------------------------------------------ hooks
    def init(self, key, data: FederatedData, batch_size: Optional[int]):
        """Build the initial state pytree (host-side, before tracing)."""
        raise NotImplementedError

    def local_update(self, state, xs, ys, r, key):
        """One round of local training on the sampled batches.

        Returns ``(state, metrics)`` where metrics is a (possibly empty) dict
        of scalars with a structure that is identical every round.
        """
        raise NotImplementedError

    def aggregate(self, state, r, key):
        """Communication/aggregation step after local updates (identity by
        default — e.g. the local-training baseline never communicates)."""
        return state

    # ------------------------------------------------- partial participation
    def merge_participation(self, prev_state, new_state, mask):
        """Under a ClientSampling schedule: keep absent clients' state.

        Default: for every leaf stacked per-client (leading dim == M with an
        unchanged shape), select ``new`` where the (M,) mask is 1 and ``prev``
        where it is 0; other leaves (and states whose pytree structure changed
        mid-round, e.g. a server-style global→clients expansion) pass through
        for ``aggregate_masked`` to handle. Override when client identity
        lives elsewhere in the state."""
        prev_leaves, prev_def = jax.tree_util.tree_flatten(prev_state)
        new_leaves, new_def = jax.tree_util.tree_flatten(new_state)
        if prev_def != new_def:
            return new_state
        M = mask.shape[0]

        def sel(o, n):
            if n.ndim >= 1 and n.shape == o.shape and n.shape[0] == M:
                m = mask.reshape((M,) + (1,) * (n.ndim - 1))
                return jnp.where(m > 0, n, o)
            return n

        return jax.tree_util.tree_unflatten(
            prev_def, [sel(o, n) for o, n in zip(prev_leaves, new_leaves)])

    def aggregate_masked(self, state, r, key, mask):
        """Aggregation under partial participation. Default: run the full
        aggregate, then keep absent clients' pre-aggregation state — present
        clients therefore see absent peers' last-known values (a stale cache),
        and absent clients receive nothing. Override for cohort-weighted
        aggregation (FedAvg/Scaffold means, P4's masked group mean)."""
        return self.merge_participation(
            state, self.aggregate(state, r, key), mask)

    def eval_params(self, state):
        """Stacked (M_test, ...) per-client parameters to evaluate."""
        raise NotImplementedError

    # ---------------------------------------------------------------- derived
    def evaluate(self, state, test_x, test_y):
        """(M,) per-client test accuracy; override for non-stacked methods."""
        from repro.core.small_models import accuracy
        params = self.eval_params(state)
        return jax.vmap(lambda p, x, y: accuracy(self.apply_fn(p, x), y))(
            params, test_x, test_y)

    # ------------------------------------------------------- optional hooks
    def log_communication(self, net, state, r: int, mask=None) -> None:
        """Record the round's messages on a P2PNetwork (host-side, called by
        the engine at eval boundaries for each elapsed round). ``mask`` is the
        round's (M,) participation mask under a sampling schedule (None for
        full participation) — absent clients must contribute zero bytes."""

    def set_sigma(self, sigma: float) -> None:
        """Engine hook for target-ε calibration (``Engine.fit(target_epsilon=
        ...)``): install the calibrated noise multiplier before tracing.
        Mutates host-side state the jitted chunks close over, so it must bump
        ``cache_token``."""
        if not hasattr(self, "sigma"):
            raise AttributeError(
                f"{type(self).__name__} has no 'sigma' attribute; override "
                "set_sigma to route the calibrated noise multiplier")
        self.sigma = float(sigma)
        self.cache_token += 1

    def state_to_save(self, state):
        """Pytree persisted by the engine's checkpoint hook."""
        return state
