"""The federated-simulation engine: one device-resident round loop for all
methods.

The legacy trainers each hand-rolled a Python ``for r in range(rounds)`` loop
with host-side numpy batch sampling — every round paid one dispatch plus an
H2D transfer of M×B×D batch data. Here the loop is the fast path:

  * batch indices are drawn with ``jax.random`` *inside* the jitted step and
    gathered from the device-resident ``(M, R, ...)`` training stacks — no
    per-round host↔device traffic at all;
  * rounds are chunked under ``jax.lax.scan`` between eval points, with the
    state carry donated, so a 100-round sweep is a handful of XLA calls
    rather than hundreds of Python dispatches;
  * every method shares the same eval cadence and ``History`` record, so
    trainers can only differ in their Strategy hooks.

Per-round randomness is derived as ``fold_in(phase_key, r)`` — a Python loop
driving the same round body reproduces the scan bit-for-bit (tested in
``tests/test_engine.py``), which is what makes the refactor safe.

The round body itself is owned by a ``RoundSchedule`` (``engine/schedule.py``):
full participation (the body above, verbatim), client sampling (cohorts drawn
inside the jit), or staleness-buffered async aggregation. An optional
``PrivacyLedger`` (``engine/accounting.py``) is advanced per executed chunk
and its cumulative (ε, δ) lands in ``History.metrics`` at every eval round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.accounting import PrivacyLedger
from repro.engine.schedule import (FullParticipation, RoundSchedule,
                                   sample_client_batches)
from repro.engine.strategy import FederatedData, Strategy


@dataclass
class History:
    """Unified metrics record shared by every trainer."""
    rounds: List[int] = field(default_factory=list)
    accuracy: List[float] = field(default_factory=list)
    metrics: Dict[str, List[float]] = field(default_factory=dict)

    def record(self, r: int, acc: float, metrics: Optional[Dict[str, float]] = None):
        self.rounds.append(int(r))
        self.accuracy.append(float(acc))
        for k, v in (metrics or {}).items():
            self.metrics.setdefault(k, []).append(float(v))

    def as_tuples(self) -> List[Tuple[int, float]]:
        """Legacy ``[(round, mean_accuracy)]`` shape used by benchmarks."""
        return list(zip(self.rounds, self.accuracy))

    def last(self) -> Tuple[int, float]:
        return self.rounds[-1], self.accuracy[-1]

    # sequence protocol: drop-in for the legacy [(round, acc)] histories
    def __len__(self) -> int:
        return len(self.rounds)

    def __getitem__(self, i):
        return self.as_tuples()[i]

    def __iter__(self):
        return iter(self.as_tuples())


def eval_rounds(start: int, rounds: int, eval_every: int) -> List[int]:
    """The legacy cadence: after round r when r % eval_every == 0, plus the
    final round — preserved exactly so histories line up across the port."""
    ev = max(int(eval_every), 1)
    out = [r for r in range(start, rounds) if r % ev == 0]
    if rounds - 1 >= start and (rounds - 1) not in out:
        out.append(rounds - 1)
    return out


@dataclass(eq=False)  # identity hash: instances close over jitted chunks
class Engine:
    """Owns the round loop; the strategy owns the method.

    Optional hooks:
      network         — a ``repro.core.p2p.P2PNetwork``; at each eval boundary
                        the strategy's ``log_communication`` is invoked for
                        every elapsed round, so §4.5 byte/message accounting
                        falls out of the same loop as training.
      checkpoint_dir  — save the strategy state at every eval point and
                        resume from the latest checkpoint via ``fit(resume=True)``.
      schedule        — a ``RoundSchedule`` owning the scanned round body
                        (default FullParticipation: the PR-2 body, verbatim).
      ledger          — a ``PrivacyLedger``; advanced per executed chunk, its
                        cumulative (ε, δ) is recorded in ``History.metrics``
                        at every eval round.
    """
    strategy: Strategy
    eval_every: int = 20
    network: Optional[Any] = None
    checkpoint_dir: Optional[str] = None
    schedule: Optional[RoundSchedule] = None
    ledger: Optional[PrivacyLedger] = None

    def __post_init__(self):
        if self.schedule is None:
            self.schedule = FullParticipation()
        self._chunk_cache: Dict[Tuple[int, Optional[int], int], Any] = {}

    # ------------------------------------------------------------------
    def _chunk_fn(self, length: int, batch_size: Optional[int]):
        """Jitted scan over ``length`` rounds; the state carry is donated.
        The cache key includes the strategy's ``cache_token`` so host-side
        strategy changes (e.g. groups set between phases) can't be silently
        shadowed by a previously compiled chunk."""
        key_ = (length, batch_size, self.strategy.cache_token)
        if key_ in self._chunk_cache:
            return self._chunk_cache[key_]
        body = self.schedule.round_body(self.strategy, batch_size)

        def run(state, phase_key, train_x, train_y, start):
            def scan_body(state, r):
                return body(state, r, phase_key, train_x, train_y)

            return jax.lax.scan(scan_body, state, start + jnp.arange(length))

        fn = jax.jit(run, donate_argnums=0)
        self._chunk_cache[key_] = fn
        return fn

    def run_rounds(self, state, data: FederatedData, phase_key, start: int,
                   stop: int, batch_size: Optional[int]):
        """Run rounds [start, stop) as one scanned chunk. Returns
        (state, metrics, aux) with metrics/aux stacked over the chunk's
        rounds; aux carries the (chunk, M) participation masks under a
        sampling schedule (empty dict otherwise)."""
        if stop <= start:
            return state, {}, {}
        fn = self._chunk_fn(stop - start, batch_size)
        state, (metrics, aux) = fn(state, phase_key, data.train_x,
                                   data.train_y, jnp.asarray(start, jnp.int32))
        return state, metrics, aux

    # ------------------------------------------------------------------
    def fit(self, data: FederatedData, *, rounds: int, key,
            batch_size: Optional[int] = None, start_round: int = 0,
            state=None, evaluate: bool = True, history: Optional[History] = None,
            resume: bool = False, target_epsilon: Optional[float] = None):
        """Run one phase of training: rounds [start_round, rounds).

        ``state=None`` initializes via the strategy. With ``evaluate=False``
        the phase runs as a single chunk with no eval (P4's bootstrap).

        ``target_epsilon`` requests a privacy budget instead of a noise
        multiplier: the ledger calibrates σ for the phase's rounds at the
        schedule's effective sampling rate and installs it on the strategy
        (``set_sigma``) before any chunk is traced.
        """
        strategy = self.strategy
        init_key, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))
        if target_epsilon is not None:
            if self.ledger is None:
                raise ValueError("target_epsilon requires a PrivacyLedger")
            strategy.set_sigma(
                self.ledger.calibrate(target_epsilon, rounds - start_round))
        if state is None:
            state = strategy.init(init_key, data, batch_size)
        history = history if history is not None else History()

        if resume and self.checkpoint_dir:
            from repro.checkpoint import latest_step, restore_checkpoint
            step = latest_step(self.checkpoint_dir)
            if step is not None:
                saved, step = restore_checkpoint(
                    self.checkpoint_dir, strategy.state_to_save(state), step)
                state = saved
                if self.ledger is not None:
                    # the rounds skipped by the resume were spent by the
                    # pre-restart run — an accountant that forgot them would
                    # under-report the release's true (ε, δ)
                    self.ledger.advance(step + 1 - start_round)
                start_round = step + 1

        boundaries = (eval_rounds(start_round, rounds, self.eval_every)
                      if evaluate else [])
        cursor = start_round
        for ev in boundaries:
            state, metrics, aux = self.run_rounds(state, data, phase_key,
                                                  cursor, ev + 1, batch_size)
            self._log_network(state, cursor, ev, aux.get("participation"))
            if self.ledger is not None:
                self.ledger.advance(ev + 1 - cursor)
            cursor = ev + 1
            acc = strategy.evaluate(state, data.test_x, data.test_y)
            chunk_means = {k: jnp.mean(v) for k, v in (metrics or {}).items()}
            if "participation" in aux:
                chunk_means["participation_rate"] = jnp.mean(
                    aux["participation"])
            if self.ledger is not None:
                chunk_means.update(self.ledger.metrics())
            history.record(ev, jnp.mean(acc), chunk_means)
            if self.checkpoint_dir:
                from repro.checkpoint import save_checkpoint
                save_checkpoint(self.checkpoint_dir, ev,
                                strategy.state_to_save(state))
        if cursor < rounds:  # tail (or the whole phase when evaluate=False)
            state, _, aux = self.run_rounds(state, data, phase_key, cursor,
                                            rounds, batch_size)
            self._log_network(state, cursor, rounds - 1,
                              aux.get("participation"))
            if self.ledger is not None:
                self.ledger.advance(rounds - cursor)
        return state, history

    # ------------------------------------------------------------------
    def _log_network(self, state, first_round: int, last_round: int,
                     masks=None) -> None:
        if self.network is None:
            return
        masks = None if masks is None else np.asarray(masks)
        for i, r in enumerate(range(first_round, last_round + 1)):
            mask = None if masks is None else masks[i]
            self.strategy.log_communication(self.network, state, r, mask=mask)


# ---------------------------------------------------------------------------
# LM-scale step loop (the launch/train.py --p4 driver)
# ---------------------------------------------------------------------------

def make_scan_steps(step_fn, make_batch, length: int):
    """Chunk ``length`` LM training steps under one jitted ``lax.scan``.

    ``make_batch(key, i)`` must build the step's batch *inside* the trace
    (e.g. ``jax.random``-drawn synthetic tokens) so the loop never touches
    the host; the (params, opt_states) carry is donated.
    """
    def run(params, opt_states, key, start):
        def body(carry, i):
            params, opt_states = carry
            k = jax.random.fold_in(key, i)
            batch = make_batch(jax.random.fold_in(k, 0), i)
            params, opt_states, metrics = step_fn(
                params, opt_states, batch, jax.random.fold_in(k, 1))
            return (params, opt_states), metrics["loss"]

        (params, opt_states), losses = jax.lax.scan(
            body, (params, opt_states), start + jnp.arange(length))
        return params, opt_states, losses

    return jax.jit(run, donate_argnums=(0, 1))
