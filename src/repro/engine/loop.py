"""The federated-simulation engine: one device-resident round loop for all
methods.

The legacy trainers each hand-rolled a Python ``for r in range(rounds)`` loop
with host-side numpy batch sampling — every round paid one dispatch plus an
H2D transfer of M×B×D batch data. Here the loop is the fast path:

  * batch indices are drawn with ``jax.random`` *inside* the jitted step and
    gathered from the device-resident ``(M, R, ...)`` training stacks — no
    per-round host↔device traffic at all;
  * rounds are chunked under ``jax.lax.scan`` between eval points, with the
    state carry donated, so a 100-round sweep is a handful of XLA calls
    rather than hundreds of Python dispatches;
  * every method shares the same eval cadence and ``History`` record, so
    trainers can only differ in their Strategy hooks.

Per-round randomness is derived as ``fold_in(phase_key, r)`` — a Python loop
driving the same round body reproduces the scan bit-for-bit (tested in
``tests/test_engine.py``), which is what makes the refactor safe.

The round body itself is owned by a ``RoundSchedule`` (``engine/schedule.py``):
full participation (the body above, verbatim), client sampling (cohorts drawn
inside the jit), or staleness-buffered async aggregation. An optional
``PrivacyLedger`` (``engine/accounting.py``) is advanced per executed chunk
and its cumulative (ε, δ) lands in ``History.metrics`` at every eval round.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.accounting import PrivacyLedger
from repro.engine.schedule import (FullParticipation, RoundSchedule,
                                   sample_client_batches)
from repro.engine.strategy import (FederatedData, Strategy, runtime_params)
from repro.obs.probes import Probe


# ---------------------------------------------------------------------------
# Compiled-chunk cache: GLOBAL (cross-Engine-instance), keyed by value
# fingerprints. A sweep that builds a fresh (strategy, Engine) pair per ε/σ
# point reuses the chunk compiled at the first point — σ reaches the trace as
# a runtime argument (see engine.strategy.runtime_params), so only changes
# that alter the traced computation (groups, schedule, lr, DP on/off, mesh,
# chunk length) miss. Bounded LRU; ``CHUNK_STATS["traces"]`` counts actual
# retraces (the probe the regression tests assert on).
# ---------------------------------------------------------------------------

CHUNK_CACHE: "OrderedDict[Tuple, Any]" = OrderedDict()
CHUNK_CACHE_MAX = 128
# a registry-backed Probe (still a dict: every existing read/increment works
# verbatim) — ``repro.obs.probe_deltas("engine.chunk_cache")`` scopes it
CHUNK_STATS = Probe("engine.chunk_cache", {"traces": 0, "hits": 0,
                                           "misses": 0})


def clear_chunk_cache() -> None:
    CHUNK_CACHE.clear()
    CHUNK_STATS.update(traces=0, hits=0, misses=0)


def _cache_get(key):
    fn = CHUNK_CACHE.get(key)
    if fn is not None:
        CHUNK_CACHE.move_to_end(key)
        CHUNK_STATS["hits"] += 1
    return fn


def _cache_put(key, fn) -> None:
    CHUNK_STATS["misses"] += 1
    CHUNK_CACHE[key] = fn
    while len(CHUNK_CACHE) > CHUNK_CACHE_MAX:
        CHUNK_CACHE.popitem(last=False)


@dataclass
class History:
    """Unified metrics record shared by every trainer."""
    rounds: List[int] = field(default_factory=list)
    accuracy: List[float] = field(default_factory=list)
    metrics: Dict[str, List[float]] = field(default_factory=dict)

    @staticmethod
    def _scalar(key: str, v) -> float:
        """Validate one recorded value: plain scalars and 0-d arrays pass;
        anything else (stray (1,)-arrays, traced values, objects) raises
        naming the offending metric key instead of dying with an opaque
        ``TypeError`` deep in the loop."""
        if isinstance(v, (bool, int, float)):
            return float(v)
        try:
            arr = np.asarray(v)
        except Exception as e:  # e.g. a jax tracer leaking out of a jit
            raise TypeError(
                f"History.record: metric {key!r} is not a concrete scalar "
                f"(got {type(v).__name__}: {v!r})") from e
        if arr.ndim == 0 and arr.dtype != object:
            return float(arr)
        raise TypeError(
            f"History.record: metric {key!r} must be a scalar or 0-d array, "
            f"got shape {arr.shape} dtype {arr.dtype} — reduce it (e.g. "
            f"jnp.mean) before recording")

    def record(self, r: int, acc: float, metrics: Optional[Dict[str, float]] = None):
        self.rounds.append(int(r))
        self.accuracy.append(self._scalar("accuracy", acc))
        for k, v in (metrics or {}).items():
            self.metrics.setdefault(k, []).append(self._scalar(k, v))

    def as_tuples(self) -> List[Tuple[int, float]]:
        """Legacy ``[(round, mean_accuracy)]`` shape used by benchmarks."""
        return list(zip(self.rounds, self.accuracy))

    def last(self) -> Tuple[int, float]:
        return self.rounds[-1], self.accuracy[-1]

    # sequence protocol: drop-in for the legacy [(round, acc)] histories
    def __len__(self) -> int:
        return len(self.rounds)

    def __getitem__(self, i):
        return self.as_tuples()[i]

    def __iter__(self):
        return iter(self.as_tuples())


def eval_rounds(start: int, rounds: int, eval_every: int) -> List[int]:
    """The legacy cadence: after round r when r % eval_every == 0, plus the
    final round — preserved exactly so histories line up across the port."""
    ev = max(int(eval_every), 1)
    out = [r for r in range(start, rounds) if r % ev == 0]
    if rounds - 1 >= start and (rounds - 1) not in out:
        out.append(rounds - 1)
    return out


@dataclass(eq=False)  # identity hash: instances close over jitted chunks
class Engine:
    """Owns the round loop; the strategy owns the method.

    Optional hooks:
      network         — a ``repro.core.p2p.P2PNetwork``; at each eval boundary
                        the strategy's ``log_communication`` is invoked for
                        every elapsed round, so §4.5 byte/message accounting
                        falls out of the same loop as training.
      checkpoint_dir  — save the strategy state at every eval point and
                        resume from the latest checkpoint via ``fit(resume=True)``.
      schedule        — a ``RoundSchedule`` owning the scanned round body
                        (default FullParticipation: the PR-2 body, verbatim).
      ledger          — a ``PrivacyLedger``; advanced per executed chunk, its
                        cumulative (ε, δ) is recorded in ``History.metrics``
                        at every eval round.
      faults          — a ``repro.resilience.FaultProcess``; its Markov state
                        rides the scan carry, each round's realization reaches
                        the schedule/strategy via the trace-time fault context,
                        and host-side replay re-derives the exact masks for
                        byte accounting and crash-resume fast-forward.
      checkpoint_keep — retain only the newest k checkpoints (0 = keep all).
      telemetry       — a ``repro.obs.Telemetry``; spans chunk dispatch,
                        streams eval/tap events to the run directory's
                        ``events.jsonl`` and maintains ``manifest.json``.
                        ``None`` (or a disabled Telemetry) is provably free:
                        the engine takes the exact pre-telemetry code path
                        and chunk-cache keys/traces are unchanged.
    """
    strategy: Strategy
    eval_every: int = 20
    network: Optional[Any] = None
    checkpoint_dir: Optional[str] = None
    schedule: Optional[RoundSchedule] = None
    ledger: Optional[PrivacyLedger] = None
    faults: Optional[Any] = None
    checkpoint_keep: int = 0
    telemetry: Optional[Any] = None

    # whether the opt-in metrics tap is inserted INTO the traced round body
    # (io_callback). ShardedEngine keeps its shard_map trace tap-free and
    # streams the same events host-side from the stacked chunk outputs.
    _tap_in_jit = True

    def __post_init__(self):
        if self.schedule is None:
            self.schedule = FullParticipation()

    # ------------------------------------------------------- telemetry seams
    def _telemetry_on(self):
        tel = self.telemetry
        return tel if (tel is not None and tel.enabled) else None

    def _tap_traced(self) -> bool:
        """True when the in-jit tap is part of this engine's chunk trace."""
        tel = self._telemetry_on()
        return bool(tel is not None and tel.tap and self._tap_in_jit)

    # ------------------------------------------------------------------
    def _chunk_key(self, length: int, batch_size: Optional[int]) -> Tuple:
        """Global-cache key: everything that changes the traced computation.
        Strategy/schedule fingerprints carry cache_token, groups, lr, DP
        on/off, ... — σ is deliberately absent (runtime argument); the
        runtime-param *keys* are in (their presence gates noise ops)."""
        base = (self.strategy.fingerprint(), self.schedule.fingerprint(),
                length, batch_size,
                tuple(sorted(self.strategy.runtime_params())),
                None if self.faults is None else self.faults.fingerprint(),
                self._mesh_fingerprint())
        # the in-jit tap is part of the traced computation, so it is part of
        # the key — but ONLY when on: with telemetry off/absent the key is
        # byte-identical to the pre-telemetry key (the zero-overhead-off
        # contract the equivalence tier locks)
        return base + (("tap",) if self._tap_traced() else ())

    def _mesh_fingerprint(self) -> Tuple:
        return ()   # single-device loop; ShardedEngine adds (axis, n, M)

    def _chunk_fn(self, length: int, batch_size: Optional[int],
                  data: FederatedData):
        """Jitted scan over ``length`` rounds; the state carry is donated.
        Cached globally across Engine instances (see CHUNK_CACHE above)."""
        key_ = self._chunk_key(length, batch_size)
        fn = _cache_get(key_)
        if fn is not None:
            return fn
        body = self.schedule.round_body(self.strategy, batch_size)
        if self.faults is not None:
            from repro.resilience import wrap_round_body
            body = wrap_round_body(body, self.faults)
        tap = None
        if self._tap_traced():
            from repro.obs.telemetry import tap_scan
            tap = tap_scan

        def run(state, phase_key, train_x, train_y, start, rt):
            CHUNK_STATS["traces"] += 1   # python body executes per trace only
            with runtime_params(rt):
                def scan_body(state, r):
                    return body(state, r, phase_key, train_x, train_y)

                rs = start + jnp.arange(length)
                if tap is not None:
                    return tap(scan_body, state, rs, rt)
                return jax.lax.scan(scan_body, state, rs)

        fn = jax.jit(run, donate_argnums=0)
        _cache_put(key_, fn)
        return fn

    def run_rounds(self, state, data: FederatedData, phase_key, start: int,
                   stop: int, batch_size: Optional[int]):
        """Run rounds [start, stop) as one scanned chunk. Returns
        (state, metrics, aux) with metrics/aux stacked over the chunk's
        rounds; aux carries the (chunk, M) participation masks under a
        sampling schedule (empty dict otherwise)."""
        if stop <= start:
            return state, {}, {}
        fn = self._build_chunk(self._chunk_fn, stop - start, batch_size, data)
        train_x, train_y = self._train_arrays(data)
        rt = {k: jnp.asarray(v, jnp.float32)
              for k, v in self.strategy.runtime_params().items()}
        carry = state if self.faults is None else (state, self._fault_state)
        carry, (metrics, aux) = self._dispatch_chunk(
            fn, (carry, phase_key, train_x, train_y,
                 jnp.asarray(start, jnp.int32), rt),
            start, stop, rt)
        if self.faults is None:
            state = carry
        else:
            state, self._fault_state = carry
        return state, metrics, aux

    # ----------------------------------------------------- telemetry dispatch
    def _build_chunk(self, builder, *args):
        """Chunk lookup/build, spanned when telemetry is on (cache hits show
        up as ~0-cost build spans; the trace itself lands in the execute
        span of the first dispatch)."""
        tel = self._telemetry_on()
        if tel is None:
            return builder(*args)
        with tel.span("chunk/build"):
            return builder(*args)

    def _dispatch_chunk(self, fn, args, start: int, stop: int, rt=None):
        """Execute one compiled chunk. Telemetry off: a bare call, nothing
        added. Telemetry on: the chunk span (trace-vs-execute split via the
        chunk-cache probe, optional Nth-chunk profiler capture) wraps the
        call, the tap's io_callbacks route to this run's sink, and engines
        whose trace is tap-free (sharded) stream the per-round events from
        the stacked outputs instead."""
        tel = self._telemetry_on()
        if tel is None:
            return fn(*args)
        with tel.activate(), tel.chunk_span(start=int(start), stop=int(stop)):
            out = fn(*args)
            jax.block_until_ready(out)
            if tel.tap and self._tap_in_jit:
                # callbacks ride XLA's host-callback thread: drain them while
                # this run's sink is still the active one
                jax.effects_barrier()
        if tel.tap and not self._tap_in_jit:
            _, (metrics, aux) = out
            tel.emit_tap_stacked(int(start), int(stop) - int(start),
                                 metrics, aux, rt)
        return out

    # ------------------------------------------------- sharded-engine seams
    def _train_arrays(self, data: FederatedData):
        """Training stacks as the chunk consumes them (padded under a client
        mesh)."""
        return data.train_x, data.train_y

    def _prepare_state(self, state, data: FederatedData):
        """Engine-internal state representation (client-padded + mesh-sharded
        under ShardedEngine; identity here)."""
        return state

    def _finalize_state(self, state):
        """Back to the strategy-visible representation (unpad) for evaluate,
        checkpointing, and the value ``fit`` returns."""
        return state

    # ------------------------------------------------- checkpointing seams
    # (PagedEngine splits the save across a plain npz for the non-paged
    #  remainder plus an incremental dirty-row population chain, and resume
    #  must land on a step whose WHOLE set verifies — hence three seams.)

    def _latest_resume_step(self) -> Optional[int]:
        from repro.checkpoint import latest_step
        return latest_step(self.checkpoint_dir)

    def _restore_for_resume(self, state, data: FederatedData,
                            resume_step: int):
        """Restore ``resume_step`` into the engine-internal representation.
        Returns (state, resume_step, history-dict-or-None)."""
        from repro.checkpoint import (load_checkpoint_metadata,
                                      restore_checkpoint)
        saved, resume_step = restore_checkpoint(
            self.checkpoint_dir,
            self.strategy.state_to_save(self._finalize_state(state)),
            resume_step)
        state = self._prepare_state(saved, data)
        meta = load_checkpoint_metadata(self.checkpoint_dir, resume_step)
        return state, resume_step, (meta or {}).get("history")

    def _save_checkpoint(self, ev: int, state, history: "History") -> None:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(self.checkpoint_dir, ev,
                        self.strategy.state_to_save(
                            self._finalize_state(state)),
                        metadata={"history": {
                            "rounds": history.rounds,
                            "accuracy": history.accuracy,
                            "metrics": history.metrics}},
                        keep_last=self.checkpoint_keep)

    # ------------------------------------------------------------------
    def fit(self, data: FederatedData, *, rounds: int, key,
            batch_size: Optional[int] = None, start_round: int = 0,
            state=None, evaluate: bool = True, history: Optional[History] = None,
            resume: bool = False, target_epsilon: Optional[float] = None):
        """Run one phase of training: rounds [start_round, rounds).

        ``state=None`` initializes via the strategy. With ``evaluate=False``
        the phase runs as a single chunk with no eval (P4's bootstrap).

        ``target_epsilon`` requests a privacy budget instead of a noise
        multiplier: the ledger calibrates σ for the phase's rounds at the
        schedule's effective sampling rate and installs it on the strategy
        (``set_sigma``) before any chunk is traced.
        """
        strategy = self.strategy
        init_key, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))
        history = history if history is not None else History()
        # the fault chains' time origin is the phase's first round as CALLED —
        # a resumed fit passes the same start_round, so host replay rejoins
        # the exact trajectory the killed run was on
        self._fault_origin = start_round

        # resolve the resume point BEFORE calibration and init: calibrating
        # with the pre-resume start_round would size σ for rounds that will
        # never run (the old double-advance hazard), and strategies whose
        # init consumes σ (e.g. DP-DSGT's noised tracker bootstrap) must see
        # the calibrated value
        resume_step = None
        if resume and self.checkpoint_dir:
            resume_step = self._latest_resume_step()
        if resume_step is not None and self.ledger is not None:
            # the rounds skipped by the resume were spent by the pre-restart
            # run — an accountant that forgot them would under-report the
            # release's true (ε, δ)
            self.ledger.advance(resume_step + 1 - start_round)
        if target_epsilon is not None:
            if self.ledger is None:
                raise ValueError("target_epsilon requires a PrivacyLedger")
            remaining = rounds - (resume_step + 1 if resume_step is not None
                                  else start_round)
            # composes on the ledger's accumulated spend (incl. the resumed
            # rounds just advanced): past + future lands on the target
            strategy.set_sigma(self.ledger.calibrate(target_epsilon,
                                                     remaining))

        if state is None:
            state = strategy.init(init_key, data, batch_size)
        state = self._prepare_state(state, data)
        if resume_step is not None:
            state, resume_step, h = self._restore_for_resume(state, data,
                                                             resume_step)
            start_round = resume_step + 1
            # the sidecar carries the killed run's History: restoring it makes
            # the resumed record bit-exact with an uninterrupted run (floats
            # round-trip exactly through JSON's shortest-repr)
            if h and not history.rounds:
                history.rounds[:] = [int(x) for x in h.get("rounds", [])]
                history.accuracy[:] = [float(x) for x in h.get("accuracy", [])]
                history.metrics.clear()
                history.metrics.update({k: [float(x) for x in v]
                                        for k, v in h.get("metrics", {}).items()})

        self._fault_state = None
        if self.faults is not None:
            # fast-forward the fault chains to start_round by eager replay
            # from the phase origin (bit-identical to the traced transitions)
            from repro.resilience import fault_state_at
            self._fault_state = fault_state_at(self.faults, phase_key,
                                               self._fault_origin, start_round)

        tel = self._telemetry_on()
        if tel is not None:
            tel.begin_phase(self._phase_info(rounds, start_round, batch_size))

        boundaries = (eval_rounds(start_round, rounds, self.eval_every)
                      if evaluate else [])
        cursor = start_round
        for ev in boundaries:
            state, metrics, aux = self.run_rounds(state, data, phase_key,
                                                  cursor, ev + 1, batch_size)
            self._log_network(state, cursor, ev, aux.get("participation"),
                              phase_key)
            if self.ledger is not None:
                self.ledger.advance(ev + 1 - cursor)
            cursor = ev + 1
            acc = strategy.evaluate(self._finalize_state(state), data.test_x,
                                    data.test_y)
            chunk_means = {k: jnp.mean(v) for k, v in (metrics or {}).items()}
            if "participation" in aux:
                chunk_means["participation_rate"] = jnp.mean(
                    aux["participation"])
            for k, v in (aux or {}).items():
                if k.startswith("fault_"):
                    chunk_means[k] = jnp.mean(v)
            if self.ledger is not None:
                chunk_means.update(self.ledger.metrics())
            history.record(ev, jnp.mean(acc), chunk_means)
            if tel is not None:
                # copied from the History entry AFTER recording, so the
                # JSONL trajectory matches the returned History exactly
                tel.eval_event(ev, history.accuracy[-1],
                               {k: v[-1] for k, v in history.metrics.items()})
            if self.checkpoint_dir:
                self._save_checkpoint(ev, state, history)
        if cursor < rounds:  # tail (or the whole phase when evaluate=False)
            state, _, aux = self.run_rounds(state, data, phase_key, cursor,
                                            rounds, batch_size)
            self._log_network(state, cursor, rounds - 1,
                              aux.get("participation"), phase_key)
            if self.ledger is not None:
                self.ledger.advance(rounds - cursor)
        if tel is not None:
            tel.end_phase()
        return self._finalize_state(state), history

    def _phase_info(self, rounds: int, start_round: int,
                    batch_size: Optional[int]) -> Dict[str, Any]:
        """The run manifest's identity record for one ``fit`` phase."""
        import hashlib

        def fp(x):
            s = str(x)
            return {"sha1": hashlib.sha1(s.encode()).hexdigest()[:12],
                    "repr": s[:2000]}

        info = {"engine": type(self).__name__,
                "strategy": type(self.strategy).__name__,
                "schedule": type(self.schedule).__name__,
                "rounds": int(rounds), "start_round": int(start_round),
                "batch_size": None if batch_size is None else int(batch_size),
                "eval_every": int(self.eval_every),
                "mesh": str(self._mesh_fingerprint()),
                "strategy_fingerprint": fp(self.strategy.fingerprint()),
                "schedule_fingerprint": fp(self.schedule.fingerprint()),
                "faults": (None if self.faults is None
                           else str(self.faults.fingerprint()))}
        topo = getattr(self.strategy, "topology", None)
        if topo is not None and hasattr(topo, "fingerprint"):
            info["topology_fingerprint"] = fp(topo.fingerprint())
        return info

    # ------------------------------------------------------------------
    def _log_network(self, state, first_round: int, last_round: int,
                     masks=None, phase_key=None) -> None:
        if self.network is None:
            return
        frs = None
        if self.faults is not None:
            # re-derive the chunk's exact correlated realizations host-side,
            # the same way host_fault_masks re-derives the i.i.d. ones
            from repro.resilience import host_realizations
            frs = host_realizations(self.faults, phase_key,
                                    self._fault_origin, first_round,
                                    last_round + 1)
        masks = None if masks is None else np.asarray(masks)
        for i, r in enumerate(range(first_round, last_round + 1)):
            mask = None if masks is None else masks[i]
            self.strategy.log_communication(
                self.network, state, r, mask=mask, phase_key=phase_key,
                faults=None if frs is None else frs[i])


# ---------------------------------------------------------------------------
# LM-scale step loop (the launch/train.py --p4 driver)
# ---------------------------------------------------------------------------

def make_scan_steps(step_fn, make_batch, length: int):
    """Chunk ``length`` LM training steps under one jitted ``lax.scan``.

    ``make_batch(key, i)`` must build the step's batch *inside* the trace
    (e.g. ``jax.random``-drawn synthetic tokens) so the loop never touches
    the host; the (params, opt_states) carry is donated.
    """
    def run(params, opt_states, key, start):
        def body(carry, i):
            params, opt_states = carry
            k = jax.random.fold_in(key, i)
            batch = make_batch(jax.random.fold_in(k, 0), i)
            params, opt_states, metrics = step_fn(
                params, opt_states, batch, jax.random.fold_in(k, 1))
            return (params, opt_states), metrics["loss"]

        (params, opt_states), losses = jax.lax.scan(
            body, (params, opt_states), start + jnp.arange(length))
        return params, opt_states, losses

    return jax.jit(run, donate_argnums=(0, 1))
