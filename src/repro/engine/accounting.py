"""Engine-native DP accounting: a composable Rényi-DP ledger.

The accountant math lives in ``repro.core.dp`` (``rdp_increment`` /
``rdp_to_epsilon`` — the Mironov subsampled-Gaussian bound). The ledger here
is the *stateful* piece the engine drives: it accumulates per-order RDP
across training segments that may differ in sampling rate (P4's full-batch
bootstrap at q=1, then a subsampled co-train phase; schedules that change
the per-round client fraction), and converts to the tightest (ε, δ) on
demand. ``Engine.fit`` advances it once per executed chunk and writes the
cumulative spend into ``History.metrics`` at every eval round, so privacy
sweeps read budgets from the same record as accuracy instead of re-deriving
them.

Effective sampling rate: a record enters a round's mechanism only if its
client is in the cohort (schedule's ``client_rate``) AND it lands in the
minibatch (``sample_rate``) — for Poisson sampling at both levels the rates
multiply, the standard two-level amplification composition (cf. Noble et
al.; Bellet et al.'s P2P analysis).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple


def _dp():
    # deferred: repro.core's package __init__ imports core.p4 which imports
    # repro.engine — a module-level import here would be circular
    from repro.core import dp as dp_lib
    return dp_lib


class PrivacyLedger:
    """Cumulative (ε, δ) of a run, composed round-by-round in RDP space.

    One ledger instance follows one training run. ``advance`` adds rounds
    (each ``local_steps`` compositions of the subsampled Gaussian at the
    segment's effective rate); ``epsilon()`` converts the accumulated
    per-order RDP to (ε, δ)-DP, minimized over orders. Segments with
    different q compose exactly because RDP is additive per order.
    """

    def __init__(self, *, sigma: float, delta: float, sample_rate: float = 1.0,
                 client_rate: float = 1.0, local_steps: int = 1):
        self.sigma = float(sigma)
        self.delta = float(delta)
        self.sample_rate = float(sample_rate)
        self.client_rate = float(client_rate)
        self.local_steps = max(int(local_steps), 1)
        self.rounds_seen = 0
        self._rdp: Dict[float, float] = {a: 0.0 for a in _dp().RDP_ORDERS}

    # ------------------------------------------------------------------
    @property
    def q(self) -> float:
        """Effective per-step sampling rate: client cohort × data batch."""
        return min(1.0, self.sample_rate * self.client_rate)

    def advance(self, rounds: int, q: Optional[float] = None,
                sigma: Optional[float] = None) -> None:
        """Account ``rounds`` more rounds (``rounds × local_steps`` steps) at
        sampling rate ``q`` (default: the ledger's effective rate) and noise
        ``sigma`` (default: the ledger's)."""
        rounds = int(rounds)
        if rounds <= 0:
            return
        q = self.q if q is None else float(q)
        sigma = self.sigma if sigma is None else float(sigma)
        steps = rounds * self.local_steps
        for a in self._rdp:
            if sigma <= 0.0:
                self._rdp[a] = math.inf    # noiseless release: no DP guarantee
            else:
                self._rdp[a] += steps * _dp().rdp_increment(q, sigma, a)
        self.rounds_seen += rounds

    # ------------------------------------------------------------------
    def epsilon(self) -> float:
        """Tightest ε at the ledger's δ for everything advanced so far."""
        if self.rounds_seen == 0:
            return 0.0
        return min(_dp().rdp_to_epsilon(r, a, self.delta)
                   for a, r in self._rdp.items())

    def spend(self) -> Tuple[float, float]:
        return self.epsilon(), self.delta

    def metrics(self) -> Dict[str, float]:
        """The per-eval-round History payload."""
        return {"dp_epsilon": self.epsilon(), "dp_delta": self.delta}

    # ------------------------------------------------------------------
    def calibrate(self, target_epsilon: float, rounds: int) -> float:
        """σ such that ``rounds`` future rounds at the ledger's effective rate
        spend at most ``target_epsilon`` — the request-ε-instead-of-σ hook.
        Sets (and returns) the ledger's σ so subsequent ``advance`` calls
        account at the calibrated noise. Raises if no σ in the bisection
        bracket meets the target (silently running over a budget the caller
        explicitly requested is the one thing an accountant must not do)."""
        return self.calibrate_segments(target_epsilon, [(int(rounds), None)])

    def calibrate_segments(self, target_epsilon: float, segments,
                           lo: float = 0.2, hi: float = 200.0) -> float:
        """Like ``calibrate`` but for a run composed of segments with
        different sampling rates — e.g. P4's full-batch bootstrap at q = 1
        followed by a subsampled co-train phase. ``segments`` is a list of
        ``(rounds, q)`` pairs (q = None means the ledger's effective rate);
        bisects the smallest σ whose total composed spend meets the target.

        Spend already accumulated on this ledger (e.g. rounds restored by a
        checkpoint resume) composes into the target: the calibrated σ makes
        the WHOLE trajectory — past plus future segments — land on
        ``target_epsilon``, so calibrate-then-resume cannot overrun the
        budget the caller asked for."""
        dp_lib = _dp()
        segs = [(int(r), self.q if q is None else float(q))
                for r, q in segments if r > 0]
        base = dict(self._rdp)   # RDP already spent before this calibration

        def spend(sigma: float) -> float:
            return min(
                dp_lib.rdp_to_epsilon(
                    base[a]
                    + sum(r * self.local_steps * dp_lib.rdp_increment(q, sigma, a)
                          for r, q in segs),
                    a, self.delta)
                for a in dp_lib.RDP_ORDERS)

        if spend(hi) > target_epsilon:
            raise ValueError(
                f"target epsilon {target_epsilon} unreachable: even sigma={hi} "
                f"spends {spend(hi):.4g} over segments {segs} at delta="
                f"{self.delta}")
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if spend(mid) > target_epsilon:
                lo = mid
            else:
                hi = mid
        self.sigma = hi
        return hi
