"""Million-client federation: a host-resident population with paged cohorts.

The resident engine keeps every client in a device ``(M, ...)`` stack, so M
is capped by device memory. ``PagedEngine`` decouples the *population* from
the *cohort*: the full per-client state and training data live host-side in
NumPy (``VirtualPopulation`` / ``HostFederatedData``), and each scanned chunk
materializes only the clients it can possibly touch as a compact ``(C, ...)``
device stack — the union of the chunk's sampled cohorts (replayed host-side
from the same PRNG streams the device draws), closed over gossip in-neighbors
(``Strategy.paged_cohort_closure``). This is the APPFL-style rank-0
orchestrator shape (SNIPPETS.md §2) rebuilt device-native.

The paged ≡ resident contract (locked by ``tests/_sharded_equivalence_main``
and ``tests/test_population.py``):

  * absent clients are bit-frozen — they are either not paged in at all, or
    paged in as neighbors and their updates discarded by the same
    ``merge_participation`` selects the resident body runs;
  * per-client PRNG streams are layout-invariant — every per-client draw is
    made at full population size (the M-way key split, the (M, B) batch-index
    draw, the (M,) participation mask) and sliced at the cohort's *global*
    ids, never keyed by cohort slot;
  * cohort aggregation scatter-expands into a zeros-backed (M, ...) stack and
    runs the resident reduction verbatim, so the float rounding is identical;
  * the ``PrivacyLedger`` sees the same full-M participation masks and
    advances by the same round counts, so (ε, δ) rates are computed against
    the full population M.

Under ``FullParticipation`` / ``AsyncStaleness`` every client trains every
round, so the cohort is the whole population: the engine gathers the full
stacks and reuses the resident round body verbatim (trivially bit-exact).
Only ``ClientSampling`` runs the true compact-cohort body.

Double-buffered prefetch: while a chunk executes on device (JAX dispatch is
asynchronous), a host thread plans and gathers the next chunk's cohort
(``CohortPrefetcher``). A prefetched state gather is validated against the
population's version counter at take time — a scatter in between re-gathers
instead of serving stale rows (property-tested).
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.loop import CHUNK_STATS, Engine, _cache_get, _cache_put
from repro.engine.schedule import ClientSampling
from repro.engine.strategy import FederatedData, runtime_params
from repro.obs.probes import Probe

# process-wide twin of the per-instance ``CohortPrefetcher.stats`` dicts:
# ``misses`` are prefetch stalls (the chunk gathers synchronously), ``stale``
# are version-mismatch re-gathers — scoped per run via
# ``repro.obs.probe_deltas("engine.prefetch")``
PREFETCH_STATS = Probe("engine.prefetch", {"submitted": 0, "hits": 0,
                                           "misses": 0, "stale": 0})


@dataclass(eq=False)
class HostFederatedData:
    """NumPy twin of ``FederatedData``: client stacks that never leave the
    host. Duck-types the attributes strategies touch (``init``/``evaluate``
    coerce through jnp on use), so a PagedEngine run needs no strategy-side
    data changes."""

    train_x: np.ndarray
    train_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray

    def __post_init__(self):
        self.train_x = np.asarray(self.train_x)
        self.train_y = np.asarray(self.train_y)
        self.test_x = np.asarray(self.test_x)
        self.test_y = np.asarray(self.test_y)

    @property
    def num_clients(self) -> int:
        return self.train_y.shape[0]

    @property
    def samples_per_client(self) -> int:
        return self.train_y.shape[1]


def as_host_data(data) -> HostFederatedData:
    if isinstance(data, HostFederatedData):
        return data
    return HostFederatedData(np.asarray(data.train_x),
                             np.asarray(data.train_y),
                             np.asarray(data.test_x),
                             np.asarray(data.test_y))


class VirtualPopulation:
    """Host-resident store for the client-stacked state leaves.

    One NumPy array per stacked leaf (leading axis = global client id), a
    monotone ``version`` counter bumped by every scatter (the prefetcher's
    staleness check), and per-row dirty tracking since the last checkpoint
    save (``repro.checkpoint.save_population`` writes only dirty rows)."""

    def __init__(self, num_clients: int):
        self.M = int(num_clients)
        self.arrays: List[np.ndarray] = []
        self.version = 0
        self._dirty = np.zeros((self.M,), bool)
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.arrays)

    def add(self, arr: np.ndarray) -> int:
        if arr.shape[0] != self.M:
            raise ValueError(f"leaf rows {arr.shape[0]} != M={self.M}")
        self.arrays.append(np.ascontiguousarray(arr))
        return len(self.arrays) - 1

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in self.arrays)

    # ------------------------------------------------------- gather / scatter
    def gather(self, rows: np.ndarray) -> List[np.ndarray]:
        """Copy the rows of every leaf (fancy indexing ⇒ fresh arrays)."""
        with self._lock:
            return [a[rows] for a in self.arrays]

    def scatter(self, rows: np.ndarray, leaves: List[np.ndarray]) -> None:
        """Write updated rows back and mark them dirty. Untouched rows are
        bit-unchanged by construction (they are simply not written)."""
        with self._lock:
            for a, v in zip(self.arrays, leaves):
                a[rows] = np.asarray(v, a.dtype)
            self._dirty[rows] = True
            self.version += 1

    # ----------------------------------------------------------- checkpoints
    def dirty_rows(self) -> np.ndarray:
        with self._lock:
            return np.nonzero(self._dirty)[0]

    def clear_dirty(self) -> None:
        with self._lock:
            self._dirty[:] = False

    def mark_all_dirty(self) -> None:
        with self._lock:
            self._dirty[:] = True


class _PopLeaf:
    """Pytree-leaf placeholder marking a state leaf that lives in the
    population store (identified by its flatten index)."""

    __slots__ = ("idx", "shape", "dtype")

    def __init__(self, idx: int, shape, dtype):
        self.idx, self.shape, self.dtype = idx, tuple(shape), dtype

    def __repr__(self):
        return f"_PopLeaf({self.idx}, {self.shape}, {self.dtype})"


class PagedCtx:
    """Trace-time view of the cohort boundary inside a paged chunk.

    ``M`` is the population size, ``C`` the padded cohort width. The chunk
    passes the cohort's global ids as a TRACED ``(C,)`` argument (padding
    slots carry the sentinel id ``M``), so one compiled chunk serves every
    cohort of the same padded width; ``installed`` is the trace-time context
    the chunk wraps around its scan (same mechanism as the sharded engine's
    ``ctx.prefetched``)."""

    def __init__(self, num_clients: int, cohort: int):
        self.M = int(num_clients)
        self.C = int(cohort)
        self.ids = None          # (C,) int32 global ids, M on padding slots
        self.ids_clip = None     # (C,) int32 clipped to [0, M) for gathers
        self.valid = None        # (C,) float32, 0 on padding slots
        self.slot_of = None      # (M + 1,) int32 global id -> cohort slot

    def installed(self, ids, valid):
        import contextlib

        @contextlib.contextmanager
        def cm():
            self.ids = ids
            self.ids_clip = jnp.minimum(ids, self.M - 1)
            self.valid = valid
            # padding slots all write the dummy entry M (never read: plan
            # neighbor ids are < M); out-of-cohort ids default to slot 0 —
            # finite garbage on rows whose results the schedule discards
            self.slot_of = jnp.zeros((self.M + 1,), jnp.int32).at[ids].set(
                jnp.arange(self.C, dtype=jnp.int32), mode="drop")
            try:
                yield
            finally:
                self.ids = self.ids_clip = self.valid = self.slot_of = None

        return cm()

    # ------------------------------------------------------------ randomness
    def cohort_keys(self, key):
        """The global M-way key split's cohort rows — client i's stream is
        independent of its cohort slot (split is not prefix-stable, so the
        full split is computed then sliced, exactly like the sharded path)."""
        return jax.random.split(key, self.M)[self.ids_clip]

    def sample_cohort_batches(self, train_x, train_y, key, batch_size):
        """Paged twin of ``sample_client_batches``: the (M, B) index draw is
        made at full population size and row-sliced at the cohort's global
        ids, then gathered from the compact data stacks."""
        if batch_size is None:
            return train_x, train_y
        R = train_y.shape[1]
        idx = jax.random.randint(key, (self.M, batch_size), 0,
                                 R)[self.ids_clip]
        xs = jnp.take_along_axis(
            train_x, idx.reshape(idx.shape + (1,) * (train_x.ndim - 2)),
            axis=1)
        ys = jnp.take_along_axis(train_y, idx, axis=1)
        return xs, ys

    # --------------------------------------------------------------- metrics
    def metric_means(self, per_client: Dict[str, Any]) -> Dict[str, Any]:
        """Scalar means over the cohort's valid rows. (Under sampling the
        resident engine means train metrics over all M clients, including
        never-aggregated local passes — the cohort mean is the documented
        paged difference; accuracy/participation/ledger metrics are computed
        elsewhere and stay bit-exact.)"""
        denom = jnp.maximum(jnp.sum(self.valid), 1.0)

        def mean(v):
            if getattr(v, "ndim", 0) >= 1 and v.shape[0] == self.C:
                return jnp.sum(v * self.valid) / denom
            return v

        return {k: mean(v) for k, v in per_client.items()}

    # --------------------------------------------------- expansion / compact
    def expand(self, tree_c):
        """Scatter-expand compact (C, ...) leaves into zeros-backed (M, ...)
        stacks (padding slots land in a dummy row and are sliced away), so a
        resident full-M reduction can run verbatim."""
        def ex(leaf):
            if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == self.C:
                buf = jnp.zeros((self.M + 1,) + leaf.shape[1:], leaf.dtype)
                return buf.at[self.ids].set(leaf, mode="drop")[: self.M]
            return leaf

        return jax.tree_util.tree_map(ex, tree_c)

    def compact_like(self, out, full_in):
        """Take the cohort rows back out of a full-M aggregation result;
        leaves whose shape changed (e.g. FedAvg's (M, ...) → global model)
        are population-independent results and pass through."""
        out_leaves, out_def = jax.tree_util.tree_flatten(out)
        full_leaves, full_def = jax.tree_util.tree_flatten(full_in)
        if out_def != full_def:
            return out
        res = []
        for o, f in zip(out_leaves, full_leaves):
            if (getattr(o, "ndim", 0) >= 1 and o.shape == f.shape
                    and o.shape[0] == self.M):
                res.append(o[self.ids_clip])
            else:
                res.append(o)
        return jax.tree_util.tree_unflatten(out_def, res)


class CohortPrefetcher:
    """Double-buffered host-side staging of the next chunk's cohort.

    ``submit(tag, fn)`` runs ``fn`` on a background thread while the current
    chunk executes on device; ``take(tag)`` returns the result only when the
    prediction tag matches. Staleness discipline lives with the caller: every
    prefetched payload records the population ``version`` at gather time, and
    ``PagedEngine`` re-gathers state rows whenever the version moved (a
    scatter landed in between) — a stale cohort is never served."""

    def __init__(self):
        self._pool: Optional[ThreadPoolExecutor] = None
        self._fut = None
        self._tag = None
        self.stats = {"submitted": 0, "hits": 0, "misses": 0, "stale": 0}

    def submit(self, tag, fn) -> None:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="cohort-prefetch")
        self._tag = tag
        self._fut = self._pool.submit(fn)
        self.stats["submitted"] += 1
        PREFETCH_STATS["submitted"] += 1

    def take(self, tag):
        """The prefetched payload for ``tag``, or None on a prediction miss
        (the caller gathers synchronously)."""
        fut, got = self._fut, self._tag
        self._fut = self._tag = None
        if fut is None or got != tag:
            if fut is not None:
                fut.cancel()
            self.stats["misses"] += 1
            PREFETCH_STATS["misses"] += 1
            return None
        try:
            out = fut.result()
        except Exception:
            self.stats["misses"] += 1
            PREFETCH_STATS["misses"] += 1
            return None
        self.stats["hits"] += 1
        PREFETCH_STATS["hits"] += 1
        return out

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
        self._fut = self._tag = None


@dataclass(eq=False)
class PagedEngine(Engine):
    """Engine whose client population is host-resident; only the active
    cohort is materialized on device per chunk.

    ``cohort_pad`` buckets the traced cohort width (cohorts pad up to a
    multiple, so varying Bernoulli draws reuse a handful of compiled chunks).
    ``mesh`` optionally shards the cohort axis over an existing clients mesh
    (``repro.launch.mesh.make_client_mesh``): compact stacks are device_put
    with a ``P(client_axis)`` sharding and the cohort width pads to the mesh
    size, letting GSPMD partition the paged body (numerically tight, not
    bit-exact — partitioned reductions reassociate).
    ``prefetch`` enables the double-buffered next-cohort gather."""

    cohort_pad: int = 8
    prefetch: bool = True
    mesh: Optional[Any] = None
    client_axis: str = "clients"

    def __post_init__(self):
        super().__post_init__()
        if self.mesh is not None and self.client_axis not in self.mesh.shape:
            raise ValueError(
                f"mesh {dict(self.mesh.shape)} has no {self.client_axis!r} "
                "axis")
        self._pop: Optional[VirtualPopulation] = None
        self._host_data: Optional[HostFederatedData] = None
        self._skeleton_leaves: Optional[List[Any]] = None
        self._M: Optional[int] = None
        self._prefetcher = CohortPrefetcher()
        self._replay_cache: Dict[Tuple, Any] = {}

    # ------------------------------------------------------------ chunk key
    def _mesh_fingerprint(self) -> Tuple:
        if self.mesh is None:
            return ()
        n = int(self.mesh.shape[self.client_axis])
        devs = tuple(d.id for d in self.mesh.devices.flat)
        return ("paged-mesh", self.client_axis, n, devs)

    def _paged_sampling(self) -> bool:
        return isinstance(self.schedule, ClientSampling)

    # --------------------------------------------------------- host planning
    def _replay_masks(self, phase_key, start: int, length: int) -> np.ndarray:
        """Host replay of the chunk's (L, M) participation draws — the exact
        streams the device body draws (fold_in(fold_in(phase_key, r), 3)),
        so the planned cohort is precisely the union of the device's sampled
        cohorts (a superset of realized participants under faults, which only
        remove clients)."""
        sched, M = self.schedule, self._M
        key_ = (self.schedule.fingerprint(), length, M)
        fn = self._replay_cache.get(key_)
        if fn is None:
            def replay(pk, start_r):
                def one(r):
                    rk = jax.random.fold_in(pk, r)
                    return sched.draw_mask(jax.random.fold_in(rk, 3), M)
                return jax.vmap(one)(start_r + jnp.arange(length))
            fn = jax.jit(replay)
            self._replay_cache[key_] = fn
        return np.asarray(fn(phase_key, jnp.asarray(start, jnp.int32)))

    def _plan_cohort(self, phase_key, start: int, stop: int) -> np.ndarray:
        """Global client ids the chunk [start, stop) must page in."""
        masks = self._replay_masks(phase_key, start, stop - start)
        ids = np.nonzero(masks.any(axis=0))[0]
        return np.asarray(self.strategy.paged_cohort_closure(
            ids, np.arange(start, stop)), np.int64)

    def _pad_cohort(self, n_real: int) -> int:
        pad = max(int(self.cohort_pad), 1)
        if self.mesh is not None:
            n = int(self.mesh.shape[self.client_axis])
            pad = pad * n // np.gcd(pad, n)
        return max(-(-n_real // pad) * pad, pad)

    # ------------------------------------------------------ gather / scatter
    def _gather_payload(self, gather_ids: np.ndarray) -> Dict[str, Any]:
        """Host-side cohort gather (runs on the prefetch thread): compact
        data rows plus the population's state rows, stamped with the
        population version for the staleness check."""
        # version read BEFORE the gather: a scatter racing the gather then
        # always trips the take-time staleness check (worst case a spurious
        # re-gather, never a stale serve)
        version = self._pop.version
        return {
            "train_x": self._host_data.train_x[gather_ids],
            "train_y": self._host_data.train_y[gather_ids],
            "state": self._pop.gather(gather_ids),
            "version": version,
        }

    def _device_put_rows(self, arr):
        if self.mesh is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(arr, NamedSharding(self.mesh,
                                                 P(self.client_axis)))

    def _take_cohort(self, tag, gather_ids: np.ndarray) -> Dict[str, Any]:
        """Prefetched payload if the prediction matched, else a synchronous
        gather. A prefetched payload whose population version moved (a
        scatter landed after its gather started) re-gathers the state rows —
        the data rows are immutable and stay valid, but stale state is never
        served (property-tested in tests/test_population.py)."""
        start, stop, C = tag
        payload = (self._prefetcher.take((start, stop, None))
                   if self.prefetch else None)
        if payload is None or payload.get("C") != C:
            payload = self._gather_payload(gather_ids)
        elif payload["version"] != self._pop.version:
            self._prefetcher.stats["stale"] += 1
            PREFETCH_STATS["stale"] += 1
            payload["state"] = self._pop.gather(gather_ids)
            payload["version"] = self._pop.version
        return payload

    # --------------------------------------------------------- chunk builder
    def _chunk_fn_paged(self, length: int, batch_size: Optional[int],
                        cohort: int):
        key_ = self._chunk_key(length, batch_size) + ("paged", cohort,
                                                      self._M)
        fn = _cache_get(key_)
        if fn is not None:
            return fn
        pctx = PagedCtx(self._M, cohort)
        body = self.schedule.paged_round_body(self.strategy, batch_size, pctx)
        if self.faults is not None:
            from repro.resilience import wrap_round_body
            body = wrap_round_body(body, self.faults)
        tap = None
        if self._tap_traced():
            from repro.obs.telemetry import tap_scan
            tap = tap_scan

        def run(state, phase_key, ids, valid, train_x, train_y, start, rt):
            CHUNK_STATS["traces"] += 1
            with runtime_params(rt), pctx.installed(ids, valid):
                def scan_body(state, r):
                    return body(state, r, phase_key, train_x, train_y)

                rs = start + jnp.arange(length)
                if tap is not None:
                    return tap(scan_body, state, rs, rt)
                return jax.lax.scan(scan_body, state, rs)

        fn = jax.jit(run, donate_argnums=0)
        _cache_put(key_, fn)
        return fn

    # -------------------------------------------------------------- the loop
    def run_rounds(self, state, data, phase_key, start: int, stop: int,
                   batch_size: Optional[int]):
        if stop <= start:
            return state, {}, {}
        M = self._M
        paged = self._paged_sampling()
        if paged:
            ids_real = self._plan_cohort(phase_key, start, stop)
        else:
            # full-participation / async: every client trains every round —
            # the cohort is the population, and the resident round body runs
            # verbatim on the fully gathered stacks
            ids_real = np.arange(M, dtype=np.int64)
        n_real = len(ids_real)
        if paged:
            C = self._pad_cohort(n_real)
            ids_pad = np.full((C,), M, np.int32)
            ids_pad[:n_real] = ids_real
            gather_ids = np.minimum(ids_pad, M - 1).astype(np.int64)
        else:
            C = n_real
            ids_pad = ids_real.astype(np.int32)
            gather_ids = ids_real

        payload = self._take_cohort((start, stop, C), gather_ids)
        # the full-gather (resident-body) path keeps replicated placement:
        # M need not divide the mesh, and the resident chunk is reused as-is
        put = self._device_put_rows if paged else jnp.asarray
        train_x = put(payload["train_x"])
        train_y = put(payload["train_y"])
        leaves = list(self._skeleton_leaves)
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, _PopLeaf):
                leaves[i] = put(payload["state"][leaf.idx])
        compact_state = jax.tree_util.tree_unflatten(self._treedef, leaves)

        rt = {k: jnp.asarray(v, jnp.float32)
              for k, v in self.strategy.runtime_params().items()}
        carry = (compact_state if self.faults is None
                 else (compact_state, self._fault_state))
        if paged:
            fn = self._build_chunk(self._chunk_fn_paged, stop - start,
                                   batch_size, C)
            carry, (metrics, aux) = self._dispatch_chunk(
                fn, (carry, phase_key, jnp.asarray(ids_pad),
                     jnp.asarray((ids_pad < M).astype(np.float32)),
                     train_x, train_y, jnp.asarray(start, jnp.int32), rt),
                start, stop, rt)
        else:
            fn = self._build_chunk(self._chunk_fn, stop - start, batch_size,
                                   data)
            carry, (metrics, aux) = self._dispatch_chunk(
                fn, (carry, phase_key, train_x, train_y,
                     jnp.asarray(start, jnp.int32), rt),
                start, stop, rt)
        if self.faults is None:
            out_state = carry
        else:
            out_state, self._fault_state = carry

        # predict the next chunk and start its host gather while the device
        # chunk is still executing (JAX dispatch is asynchronous — the
        # blocking np.asarray reads below overlap with this thread's work)
        if self.prefetch:
            nxt = (stop, stop + (stop - start))
            self._prefetcher.submit(
                nxt + (None,), lambda: self._prefetch_payload(phase_key, nxt))

        # scatter updated population rows back (blocks on the chunk)
        out_leaves = jax.tree_util.tree_flatten(out_state)[0]
        if len(self._pop) and n_real:
            pop_vals = []
            for skel, out in zip(self._skeleton_leaves, out_leaves):
                if isinstance(skel, _PopLeaf):
                    pop_vals.append((skel.idx, np.asarray(out)[:n_real]))
            pop_vals.sort(key=lambda t: t[0])
            self._pop.scatter(ids_real, [v for _, v in pop_vals])
        # non-paged leaves (server-style globals, fault carries) stay device-
        # resident across chunks
        new_skel = []
        for skel, out in zip(self._skeleton_leaves, out_leaves):
            new_skel.append(skel if isinstance(skel, _PopLeaf) else out)
        self._skeleton_leaves = new_skel
        return state, metrics, aux

    def _prefetch_payload(self, phase_key, nxt: Tuple[int, int]):
        start, stop = nxt
        M = self._M
        if self._paged_sampling():
            ids_real = self._plan_cohort(phase_key, start, stop)
            C = self._pad_cohort(len(ids_real))
            ids_pad = np.full((C,), M, np.int32)
            ids_pad[: len(ids_real)] = ids_real
            gather_ids = np.minimum(ids_pad, M - 1).astype(np.int64)
        else:
            C = M
            gather_ids = np.arange(M, dtype=np.int64)
        payload = self._gather_payload(gather_ids)
        payload["C"] = C
        return payload

    # ------------------------------------------- population representation
    def _prepare_state(self, state, data):
        self._M = M = data.num_clients
        self._host_data = as_host_data(data)
        stacked = self.strategy.state_client_stacked(state)
        leaves, treedef = jax.tree_util.tree_flatten(state)
        pop = VirtualPopulation(M)
        skel = []
        for leaf in leaves:
            arr = np.asarray(leaf)
            if stacked and arr.ndim >= 1 and arr.shape[0] == M and M > 1:
                idx = pop.add(arr.copy())
                skel.append(_PopLeaf(idx, arr.shape, arr.dtype))
            else:
                skel.append(jnp.asarray(leaf))
        self._pop = pop
        self._treedef = treedef
        self._skeleton_leaves = skel
        return jax.tree_util.tree_unflatten(treedef, skel)

    def _finalize_state(self, state):
        leaves = [jnp.asarray(self._pop.arrays[leaf.idx])
                  if isinstance(leaf, _PopLeaf) else leaf
                  for leaf in self._skeleton_leaves]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def _log_network(self, state, first_round, last_round, masks=None,
                     phase_key=None) -> None:
        if self.network is None:
            return
        super()._log_network(self._finalize_state(state), first_round,
                             last_round, masks=masks, phase_key=phase_key)

    # ----------------------------------------------------------- checkpoints
    def _checkpoint_rest(self, state):
        """The non-population remainder of the state (server-style globals),
        with paged leaves as zero-length placeholders so the npz template is
        shape-stable."""
        return jax.tree_util.tree_unflatten(
            self._treedef,
            [jnp.zeros((0,), leaf.dtype) if isinstance(leaf, _PopLeaf)
             else leaf for leaf in self._skeleton_leaves])

    def _save_checkpoint(self, ev: int, state, history) -> None:
        from repro import checkpoint as ck
        if len(self._pop):
            # population first, plain checkpoint last: the ckpt file is the
            # commit point (resume only considers steps whose ckpt verifies,
            # and then requires the population chain at that step to verify)
            ck.save_population(self.checkpoint_dir, ev, self._pop,
                               keep_last=self.checkpoint_keep)
        ck.save_checkpoint(self.checkpoint_dir, ev,
                           self._checkpoint_rest(state),
                           metadata={"history": {
                               "rounds": history.rounds,
                               "accuracy": history.accuracy,
                               "metrics": history.metrics},
                               "population": len(self._pop)},
                           keep_last=self.checkpoint_keep)

    def _latest_resume_step(self):
        from repro import checkpoint as ck
        for step in reversed(ck.verified_steps(self.checkpoint_dir)):
            if ck.population_chain_ok(self.checkpoint_dir, step):
                return step
        return None

    def _restore_for_resume(self, state, data, resume_step: int):
        from repro import checkpoint as ck
        saved, resume_step = ck.restore_checkpoint(
            self.checkpoint_dir, self._checkpoint_rest(state), resume_step)
        saved_leaves = jax.tree_util.tree_flatten(saved)[0]
        self._skeleton_leaves = [
            skel if isinstance(skel, _PopLeaf) else jnp.asarray(sv)
            for skel, sv in zip(self._skeleton_leaves, saved_leaves)]
        if len(self._pop):
            ck.restore_population(self.checkpoint_dir, self._pop, resume_step)
        meta = ck.load_checkpoint_metadata(self.checkpoint_dir, resume_step)
        state = jax.tree_util.tree_unflatten(self._treedef,
                                             self._skeleton_leaves)
        return state, resume_step, (meta or {}).get("history")
