"""Unified federation engine: one device-resident round loop + a strategy
registry covering P4 and every baseline, with pluggable round schedules
(full / client-sampling / async), engine-native DP accounting, and a
multi-mesh execution path sharding the round loop over a client axis (see
README §Federation engine, §Round schedules & privacy accounting, §Sharded
engine)."""
from repro.engine.accounting import PrivacyLedger
from repro.engine.loop import (CHUNK_STATS, Engine, History,
                               clear_chunk_cache, eval_rounds,
                               make_scan_steps, sample_client_batches)
from repro.engine.schedule import (AsyncStaleness, ClientSampling,
                                   FullParticipation, RoundSchedule,
                                   make_schedule)
from repro.engine.population import (CohortPrefetcher, HostFederatedData,
                                     PagedCtx, PagedEngine,
                                     VirtualPopulation, as_host_data)
from repro.engine.sharded import ClientShardCtx, ShardedEngine
from repro.engine.strategy import (FederatedData, Strategy,
                                   available_strategies, get_strategy,
                                   register_strategy, runtime_sigma)
