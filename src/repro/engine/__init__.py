"""Unified federation engine: one device-resident round loop + a strategy
registry covering P4 and every baseline, with pluggable round schedules
(full / client-sampling / async) and engine-native DP accounting (see README
§Federation engine, §Round schedules & privacy accounting)."""
from repro.engine.accounting import PrivacyLedger
from repro.engine.loop import (Engine, History, eval_rounds, make_scan_steps,
                               sample_client_batches)
from repro.engine.schedule import (AsyncStaleness, ClientSampling,
                                   FullParticipation, RoundSchedule,
                                   make_schedule)
from repro.engine.strategy import (FederatedData, Strategy,
                                   available_strategies, get_strategy,
                                   register_strategy)
