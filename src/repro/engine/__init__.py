"""Unified federation engine: one device-resident round loop + a strategy
registry covering P4 and every baseline (see README §Federation engine)."""
from repro.engine.loop import (Engine, History, eval_rounds, make_scan_steps,
                               sample_client_batches)
from repro.engine.strategy import (FederatedData, Strategy,
                                   available_strategies, get_strategy,
                                   register_strategy)
