"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (v5e constants):

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip / HBM_bw             (819 GB/s)
  collective = collective_bytes_per_chip / link_bw     (~50 GB/s/link ICI)

``cost_analysis()`` describes the per-device SPMD executable, so its flops /
bytes are already per-chip. Collective bytes are NOT in cost_analysis —
``collective_bytes`` parses the post-optimization HLO and sums the *result*
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (a send-volume proxy; each collective's output is what a
chip materializes over the interconnect).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%x = f32[8,128]{1,0} all-reduce(...)` or tuple results
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` returns a dict in some JAX versions and a
    one-element list of dicts in others — normalize to a dict. Lives here
    (not dryrun.py) so test subprocesses can import it without dryrun's
    import-time XLA_FLAGS mutation."""
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return c


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_SKIP_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast", "constant",
             "after-all", "partition-id", "iota"}


def entry_region(hlo_text: str) -> str:
    """The ENTRY computation's body (top-level, post-fusion instructions)."""
    m = re.search(r"^ENTRY\b[^{]*\{", hlo_text, re.M)
    if not m:
        return hlo_text
    start = m.end()
    depth = 1
    i = start
    while i < len(hlo_text) and depth:
        c = hlo_text[i]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
        i += 1
    return hlo_text[start:i]


_ENTRY_OP_RE = re.compile(r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+([\w-]+)")


_COMP_SPLIT_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)[^\n{]*\{", re.M)


def hbm_bytes(hlo_text: str) -> Dict[str, float]:
    """Fused-HBM-traffic proxy: Σ over instructions at computation level of
    2 × output bytes (write + one read), skipping fusion-INTERNAL
    computations (their traffic stays in VMEM/registers on a fused backend)
    and boundary-free ops. While/conditional bodies count once — the dry-run
    extrapolates trip counts via two unroll factors.

    ``bytes accessed`` from cost_analysis() counts every unfused internal and
    overestimates HBM traffic ~10×; both are recorded (§Roofline)."""
    total = 0.0
    params = 0.0
    by_kind: Dict[str, float] = {}
    # split text into computation blocks; skip fusion bodies
    blocks = list(_COMP_SPLIT_RE.finditer(hlo_text))
    for i, m in enumerate(blocks):
        name = m.group(1)
        end = blocks[i + 1].start() if i + 1 < len(blocks) else len(hlo_text)
        body = hlo_text[m.end():end]
        # skip fusion internals + scalar reduce/compare wrapper computations;
        # KEEP region_* (while/cond bodies — trip counts are extrapolated)
        if ("fused_computation" in name or name.startswith("wrapped_")
                or name == "HloModule"):
            continue
        is_entry = hlo_text[max(0, m.start() - 6):m.start() + 5].strip().startswith("ENTRY") \
            or hlo_text[m.start():m.start() + 5] == "ENTRY"
        for om in _ENTRY_OP_RE.finditer(body):
            type_str, kind = om.group(1), om.group(2)
            sz = _shape_bytes(type_str)
            if kind == "parameter":
                if is_entry:
                    params += sz
                continue
            if kind in _SKIP_OPS or kind in ("while", "conditional", "call"):
                continue
            total += 2.0 * sz
            by_kind[kind] = by_kind.get(kind, 0.0) + sz
    top = dict(sorted(by_kind.items(), key=lambda kv: -kv[1])[:8])
    return {"total": total + params, "parameter_bytes": params, "top_ops": top}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes of every collective in the compiled HLO.
    ``-start`` ops are counted; their ``-done`` twins are skipped (the result
    of -done duplicates the async buffer)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        span = hlo_text[m.start():m.end()]
        if f"{kind}-done(" in span:
            continue
        out[kind] += _shape_bytes(type_str)
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


_RG_LIST_RE = re.compile(r"replica_groups=\{(\{[\d,{} ]*\})\}")
# XLA iota (v2) format: replica_groups=[G,S]<=[d0,d1,...]T(p0,p1,...)
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _iota_groups(g: int, s: int, dims, perm):
    import numpy as np
    n = 1
    for d in dims:
        n *= d
    ids = np.arange(n).reshape(dims)
    if perm:
        ids = ids.transpose(perm)
    return ids.reshape(g, s)


def pod_traffic(hlo_text: str, pod_size: int = 256) -> Dict[str, float]:
    """Split collective result bytes into intra-pod (ICI) vs cross-pod (DCN)
    by inspecting each collective's replica_groups (both explicit-list and
    iota formats). §Perf uses this to show the P4 step's group-internal
    topology keeps gradient traffic off the cross-pod links that consensus
    training exercises every step."""
    import numpy as np
    intra = cross = 0.0
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end if line_end > 0 else len(hlo_text)]
        sz = _shape_bytes(type_str)
        is_cross = None
        it = _RG_IOTA_RE.search(line)
        if it:
            g, s = int(it.group(1)), int(it.group(2))
            dims = [int(x) for x in it.group(3).split(",")]
            perm = [int(x) for x in it.group(4).split(",")] if it.group(4) else None
            groups = _iota_groups(g, s, dims, perm)
            is_cross = bool((np.ptp(groups // pod_size, axis=1) > 0).any())
        else:
            rg = _RG_LIST_RE.search(line)
            if rg:
                is_cross = False
                for grp in re.findall(r"\{([\d, ]+)\}", rg.group(1)):
                    ids = [int(t) for t in grp.replace(" ", "").split(",") if t]
                    if len({i // pod_size for i in ids}) > 1:
                        is_cross = True
                        break
        if is_cross is None:
            is_cross = True   # no groups listed => all participants
        if is_cross:
            cross += sz
        else:
            intra += sz
    return {"intra_pod_bytes": intra, "cross_pod_bytes": cross}


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float) -> Dict[str, float]:
    compute = flops_per_chip / PEAK_FLOPS
    memory = bytes_per_chip / HBM_BW
    collective = coll_bytes_per_chip / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    return terms


def model_flops(num_params: int, active_params: int, tokens: int,
                kind: str = "train") -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts 2·N·D (fwd only)."""
    n = active_params or num_params
    per_tok = 6.0 * n if kind == "train" else 2.0 * n
    return per_tok * tokens
