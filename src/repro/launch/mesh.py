"""Production mesh definitions (v5e).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256-chip pod; multi-pod = 2 pods = 512 chips.

    Axes: ``pod`` (= the P4 group axis, DCN), ``data`` (batch/FSDP, ICI),
    ``model`` (tensor parallel, ICI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over real host devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    return jax.make_mesh((data, max(1, min(model, n // data))), ("data", "model"))
