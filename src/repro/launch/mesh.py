"""Production mesh definitions (v5e) + host-simulation meshes.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.config import MeshConfig
from repro.sharding.rules import CLIENT_AXIS


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256-chip pod; multi-pod = 2 pods = 512 chips.

    Axes: ``pod`` (= the P4 group axis, DCN), ``data`` (batch/FSDP, ICI),
    ``model`` (tensor parallel, ICI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def host_mesh_shape(data: int, model: int, num_devices: int) -> Tuple[int, int]:
    """Explicit clamping for the host-simulation mesh (pure, tested):

      data'  = clamp(data, 1, n)        — never exceed available devices
      model' = clamp(model, 1, n//data') — whatever capacity data left over

    A model request that no longer fits after the data clamp degrades to a
    1-sized model axis (replicated tensor-parallel) instead of crashing on
    ``n // 0`` or silently requesting more devices than exist. The product
    data'·model' is always ≥ 1 and ≤ n."""
    n = max(1, int(num_devices))
    data = max(1, min(int(data), n))
    model = max(1, min(int(model), n // data))
    return data, model


def make_host_mesh(data: int = 1, model: int = 1, *,
                   num_devices: Optional[int] = None):
    """Tiny mesh over real host devices (tests / examples); shapes are the
    explicit ``host_mesh_shape`` clamp, and the mesh only claims the devices
    it uses (the product may be smaller than the device count)."""
    n = num_devices if num_devices is not None else len(jax.devices())
    data, model = host_mesh_shape(data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def make_client_mesh(clients: Optional[int] = None, *, axis: str = CLIENT_AXIS):
    """1-D mesh over ``clients`` devices for the sharded federation engine
    (``repro.engine.ShardedEngine``): each slice hosts a disjoint client
    shard of the (M, ...) state/data stacks. Default: every host device."""
    n = len(jax.devices())
    clients = n if clients is None else max(1, min(int(clients), n))
    return jax.make_mesh((clients,), (axis,), devices=jax.devices()[:clients])
