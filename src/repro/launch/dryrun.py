"""Multi-pod dry-run: prove every (arch × input-shape × mesh) combination
lowers and compiles onto the production mesh, and extract roofline terms.

MUST be the very first thing in the process: 512 placeholder host devices
(jax locks device count on first init)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (DPConfig, InputShape, INPUT_SHAPES, MeshConfig,
                          P4Config, TrainConfig, replace)
from repro.configs import ARCHITECTURES, get_config
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.api import (build_model, cache_shardings, cache_specs,
                              input_shardings, input_specs, make_serve_step,
                              make_train_step, param_shardings)
from repro.models.module import abstract_params, partition_specs
from repro.sharding.rules import activation_sharding, make_rules

# archs whose long_500k run uses the framework's sliding-window variant
# (sub-quadratic requirement; SSM/hybrid run natively) — DESIGN.md §4.
_SWA_WINDOW = 8192


def _prep_config(arch: str, shape: InputShape, overrides: Dict[str, Any]):
    cfg = get_config(arch)
    notes = []
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        if cfg.window == 0:
            cfg = replace(cfg, window=_SWA_WINDOW)
            notes.append(f"long_500k uses sliding-window variant (window={_SWA_WINDOW})")
    from repro.config import _set_path
    for k, v in overrides.items():
        cfg = _set_path(cfg, k.split("."), v)
    return cfg, notes


def _active_params(cfg, specs) -> (int, int):
    """(total, active) parameter counts from the spec tree."""
    import jax.tree_util as jtu
    from repro.models.module import ParamSpec, is_spec
    total = expert = 0
    for _, s in jtu.tree_flatten_with_path(specs, is_leaf=is_spec)[0]:
        n = int(np.prod(s.shape))
        total += n
        if "experts" in s.dims:
            expert += n
    if cfg.moe.num_experts:
        k, E = cfg.moe.experts_per_token, cfg.moe.num_experts
        active = total - expert + int(expert * k / E)
    else:
        active = total
    return total, active


def _opt_state_shardings(param_pspecs, mesh):
    ns = lambda p: NamedSharding(mesh, p)
    mv = jax.tree_util.tree_map(ns, param_pspecs,
                                is_leaf=lambda x: isinstance(x, P))
    return {"m": mv, "v": mv, "count": ns(P())}


def _lower_for(cfg, shape: InputShape, mesh, mesh_cfg, rules, *, p4: bool,
               fsdp: bool):
    """Lower (not yet compiled) the step for this config onto the mesh."""
    api = build_model(cfg)
    params_abs = api.abstract()
    pspecs = partition_specs(api.specs, rules)
    ns = lambda p: NamedSharding(mesh, p)
    p_shard = jax.tree_util.tree_map(ns, pspecs, is_leaf=lambda x: isinstance(x, P))
    batch_abs = input_specs(cfg, shape)
    b_specs = input_shardings(cfg, shape, mesh_cfg, rules)
    b_shard = jax.tree_util.tree_map(ns, b_specs, is_leaf=lambda x: isinstance(x, P))

    with mesh, activation_sharding(mesh, rules):
        if p4:
            return _lower_p4(api, cfg, mesh, mesh_cfg, shape, pspecs, b_specs)
        if shape.kind == "train":
            train_cfg = TrainConfig()
            train_step, opt = make_train_step(api, train_cfg)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            o_shard = _opt_state_shardings(pspecs, mesh)
            return jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
            ).lower(params_abs, opt_abs, batch_abs)
        if shape.kind == "prefill":
            return jax.jit(
                api.prefill_fn, in_shardings=(p_shard, b_shard), out_shardings=None,
            ).lower(params_abs, batch_abs)
        # decode
        serve_step = make_serve_step(api)
        caches_abs = cache_specs(cfg, shape)
        c_specs = cache_shardings(cfg, shape, mesh_cfg, rules)
        c_shard = jax.tree_util.tree_map(ns, c_specs,
                                         is_leaf=lambda x: isinstance(x, P))
        return jax.jit(
            serve_step,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(None, None, c_shard),
        ).lower(params_abs, caches_abs, batch_abs)


def _attention_correction(cfg, shape: InputShape) -> tuple:
    """Analytic (flops, bytes) of the chunked/flash attention inner loops,
    which the cost lowering counts only once (they remain scans).

    Causal(+window) pair count, matmul (QKᵀ + PV) + ~6 flop/score softmax;
    bytes = flash HBM streaming (q once, k/v once per q-block, o once).
    Training multiplies by 4 (fwd + remat re-fwd + 2×fwd for bwd).
    Only applies when the chunked path is active (s > 2048, not decode)."""
    s = shape.seq_len
    if shape.kind == "decode" or s <= 2048 or cfg.family == "ssm":
        return 0.0, 0.0
    b = shape.global_batch
    from repro.models.attention import n_q_heads
    hq, hkv, hd = n_q_heads(cfg), cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.family == "hybrid":
        from repro.models.transformer import hybrid_layout
        n_attn = hybrid_layout(cfg)[0]
    else:
        n_attn = cfg.num_layers
    w = cfg.window or s
    # visible (q, k) pairs: train uses the differentiable full-block sweep
    # (mask-only causality); prefill skips masked chunks dynamically.
    if shape.kind == "train":
        pairs = s * s                          # full masked sweep (see above)
    elif w < s:
        pairs = s * min(w, s) - (min(w, s) * (min(w, s) - 1)) // 2
    else:
        pairs = s * (s + 1) // 2
    matmul = 2 * 2 * b * pairs * hq * hd            # QKt + PV, 2 flops/MAC
    softmax = 6 * b * pairs * hq
    mult = 4.0 if shape.kind == "train" else 1.0
    flops = mult * n_attn * (matmul + softmax)
    nq = max(1, s // 512)                            # q-chunk count (block 512)
    itemsize = 2                                     # bf16 activations
    stream = itemsize * (2 * b * s * hq * hd + 2 * nq * b * min(w, s) * hkv * hd)
    bytes_ = mult * n_attn * stream
    return flops, bytes_


def _outer_count(cfg) -> int:
    """Trip count of the outer layer-stack scan (extrapolation target)."""
    from repro.models.transformer import hybrid_layout, xlstm_layout
    if cfg.family == "hybrid":
        return hybrid_layout(cfg)[0]
    if cfg.family == "ssm":
        return xlstm_layout(cfg)[0]
    return cfg.num_layers


def _measure(cfg, shape, mesh, mesh_cfg, rules, *, p4, fsdp):
    compiled = _lower_for(cfg, shape, mesh, mesh_cfg, rules,
                          p4=p4, fsdp=fsdp).compile()
    c = roofline.cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    hb = roofline.hbm_bytes(hlo)
    return {"flops": float(c.get("flops", 0.0)),
            "bytes_unfused": float(c.get("bytes accessed", 0.0)),
            "hbm": hb["total"], "hbm_top": hb["top_ops"],
            "coll": roofline.collective_bytes(hlo)}


def _extrap(v1, vu, u: int, L: int):
    """total = outside + L·body given f(1) and f(u) measurements."""
    if isinstance(v1, dict):
        return {k: _extrap(v1.get(k, 0), vu.get(k, 0), u, L)
                for k in set(v1) | set(vu)}
    if not isinstance(v1, (int, float)):
        return vu
    body = (vu - v1) / (u - 1)
    return max(v1 + (L - 1) * body, 0.0)


def _inner_scan_correction(cfg, shape: InputShape) -> tuple:
    """Analytic (flops, bytes) for the once-counted chunked-recurrence scans
    (Mamba2 SSD inter-chunk state scan; mLSTM chunkwise scan). Their bodies
    are exact, small formulas; unrolling them at 32k–500k sequence lengths
    explodes HLO size, so we count them on paper instead.

    The measured HLO already contains ONE body per layer (the scan's single
    counted iteration), so corrections add (nc − 1) bodies per layer."""
    s = shape.seq_len if shape.kind != "decode" else 1
    if s <= 1:
        return 0.0, 0.0
    b = shape.global_batch
    mult = 4.0 if shape.kind == "train" else 1.0
    flops = bytes_ = 0.0
    if cfg.family == "hybrid" and cfg.ssm.state_dim:
        H, N = cfg.ssm.num_heads, cfg.ssm.state_dim
        P = cfg.ssm.head_dim or (cfg.ssm.expand * cfg.d_model) // H
        c = cfg.ssm.chunk_size
        nc = max(1, s // c)
        # body: y_off einsum (2cHNP) + state decay/update (3HNP)
        body_f = b * H * (2 * c * N * P + 3 * N * P)
        body_b = 4 * b * H * (2 * c * N + c * P + 2 * N * P)   # fp32 operands
        flops += mult * cfg.num_layers * (nc - 1) * body_f
        bytes_ += mult * cfg.num_layers * (nc - 1) * body_b
    if cfg.family == "ssm" and cfg.xlstm_pattern:
        from repro.models.transformer import xlstm_layout
        units, pat = xlstm_layout(cfg)
        n_mlstm = units * sum(1 for k in pat if k == "m")
        H = cfg.num_heads
        hd = cfg.d_model // H
        c = min(256, s)
        nc = max(1, s // c)
        # chunk body: qkᵀ + h_intra + n_vec (3·2·c²·hd) + inter/carry (3·2·c·hd²)
        body_f = b * H * (6 * c * c * hd + 6 * c * hd * hd + 12 * c * c)
        body_b = 4 * b * H * (3 * c * hd + 2 * hd * hd + 4 * c * c)
        flops += mult * n_mlstm * (nc - 1) * body_f
        bytes_ += mult * n_mlstm * (nc - 1) * body_b
    return flops, bytes_


def _slstm_correction(cfg, shape: InputShape) -> float:
    """Analytic flops for the sLSTM time recurrence (its seq scan cannot be
    unrolled at 4k–32k; body ≈ 4 block-diagonal recurrent matmuls)."""
    if cfg.family != "ssm" or "s" not in (cfg.xlstm_pattern or ()):
        return 0.0
    s = 1 if shape.kind == "decode" else shape.seq_len
    if s <= 1:
        return 0.0
    from repro.models.transformer import xlstm_layout
    units, pat = xlstm_layout(cfg)
    n_slstm = units * sum(1 for k in pat if k == "s")
    H = cfg.num_heads
    hd = cfg.d_model // H
    step = 2 * 4 * H * hd * hd + 30 * H * hd        # recurrence + pointwise
    mult = 3.0 if shape.kind == "train" else 1.0     # fwd+bwd≈3x fwd
    return mult * n_slstm * shape.global_batch * (s - 1) * step


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                p4: bool = False, overrides: Optional[Dict[str, Any]] = None,
                fsdp: bool = True, verbose: bool = True,
                cost_variant: bool = True,
                rule_overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    cfg, notes = _prep_config(arch, shape, overrides or {})
    mesh_cfg = MeshConfig(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh_cfg, kind=shape.kind, fsdp=fsdp)
    if rule_overrides:
        rules.update(rule_overrides)
        notes.append(f"rule_overrides={rule_overrides}")
    api = build_model(cfg)

    t0 = time.time()
    lowered = _lower_for(cfg, shape, mesh, mesh_cfg, rules, p4=p4, fsdp=fsdp)
    t_lower = time.time() - t0
    if verbose:
        print(f"[dryrun] lowered in {t_lower:.1f}s; compiling ...", flush=True)
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    if verbose:
        print(f"[dryrun] compiled in {t_compile:.1f}s", flush=True)

    mem = compiled.memory_analysis()

    # ---- cost-faithful pass: XLA cost_analysis counts while bodies ONCE, so
    # we lower twice (layer-scan unroll factors 1 and u) and extrapolate
    # total = f1 + (L-1)·(fu - f1)/(u-1). The chunked-attention inner (q, kv)
    # scans stay loops in both; their cost is exactly computable and added
    # analytically (_attention_correction), as is the sLSTM time recurrence.
    outer = _outer_count(cfg)
    cost_src = f"unroll-extrapolated(L={outer})+analytic-attn"
    try:
        if not cost_variant:
            raise RuntimeError("cost variant disabled")
        u = 2 if outer % 2 == 0 else 3
        u = min(u, outer)
        m1 = _measure(replace(cfg, unroll_layers=1, unroll_inner=True),
                      shape, mesh, mesh_cfg, rules, p4=p4, fsdp=fsdp)
        if u > 1:
            mu = _measure(replace(cfg, unroll_layers=u, unroll_inner=True),
                          shape, mesh, mesh_cfg, rules, p4=p4, fsdp=fsdp)
            meas = {k: _extrap(m1[k], mu[k], u, outer) for k in m1}
        else:
            meas = m1
    except Exception as e:  # fall back to the scan artifact, flagged
        cost_src = f"scan-fallback ({type(e).__name__}: {e})"
        c = roofline.cost_analysis_dict(compiled)
        hlo0 = compiled.as_text()
        meas = {"flops": float(c.get("flops", 0.0)),
                "bytes_unfused": float(c.get("bytes accessed", 0.0)),
                "hbm": roofline.hbm_bytes(hlo0)["total"],
                "hbm_top": roofline.hbm_bytes(hlo0)["top_ops"],
                "coll": roofline.collective_bytes(hlo0)}

    chips = mesh_cfg.num_devices
    attn_fl, attn_by = _attention_correction(cfg, shape)
    inner_fl, inner_by = _inner_scan_correction(cfg, shape)
    flops = meas["flops"] + (_slstm_correction(cfg, shape) + attn_fl + inner_fl) / chips
    byts_raw = meas["bytes_unfused"]
    byts = meas["hbm"] + (attn_by + inner_by) / chips
    coll = meas["coll"]
    hbm = {"top_ops": meas.get("hbm_top", {})}
    terms = roofline.roofline_terms(flops, byts, coll["total"])
    total_p, active_p = _active_params(cfg, api.specs)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = roofline.model_flops(total_p, active_p, tokens,
                              "train" if shape.kind == "train" else "decode")
    mf_per_chip = mf / chips
    pods = roofline.pod_traffic(compiled.as_text()) if multi_pod else None
    result = {
        "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod else "16x16",
        "p4": p4, "notes": notes, "pod_traffic": pods,
        "overrides": overrides or {},
        "params_total": total_p, "params_active": active_p,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_chip": flops, "bytes_per_chip": byts,
        "bytes_unfused_per_chip": byts_raw,
        "hbm_top_ops": hbm["top_ops"],
        "collective_bytes_per_chip": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k not in ("total",)},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                          + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "roofline": terms,
        "cost_source": cost_src,
        "model_flops_per_chip": mf_per_chip,
        "useful_flops_ratio": (mf_per_chip / flops) if flops else None,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {result['mesh']}"
              f"{' × P4' if p4 else ''}: lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={result['memory']['argument_bytes']}"
              f" temp={result['memory']['temp_bytes']} out={result['memory']['output_bytes']}")
        print(f"  cost_analysis: flops/chip={flops:.3e} bytes/chip={byts:.3e}"
              f" collective_bytes/chip={coll['total']:.3e}")
        print(f"  roofline: compute={terms['compute_s']:.4f}s memory={terms['memory_s']:.4f}s"
              f" collective={terms['collective_s']:.4f}s -> {terms['bottleneck']}")
    return result


def _lower_p4(api, cfg, mesh, mesh_cfg, shape, pspecs, b_specs):
    """P4 dual-model step over G groups == pod axis (multi-pod only)."""
    from repro.core.p4 import make_p4_lm_step
    from repro.optim import make_optimizer
    assert mesh_cfg.multi_pod, "P4 dry-run uses the pod axis as the group axis"
    G = mesh_cfg.pods
    train_cfg = TrainConfig()
    dp_cfg = DPConfig(microbatches=4)
    p4_cfg = P4Config()
    step = make_p4_lm_step(api, api, train_cfg, dp_cfg, p4_cfg)
    opt = make_optimizer(train_cfg)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct((G,) + tuple(l.shape), l.dtype), tree)

    params_abs = stack(api.abstract())
    params_abs = {"private": params_abs, "proxy": params_abs}
    opt_abs = jax.eval_shape(jax.vmap(opt.init), params_abs["private"])
    opt_abs = {"private": opt_abs, "proxy": opt_abs}

    ns = lambda p: NamedSharding(mesh, p)
    def stack_spec(p):
        return ns(P(*(("pod",) + tuple(p))))
    pp = jax.tree_util.tree_map(stack_spec, pspecs, is_leaf=lambda x: isinstance(x, P))
    p_shard = {"private": pp, "proxy": pp}
    mv = pp
    o_shard = {"private": {"m": mv, "v": mv, "count": ns(P(None))},
               "proxy": {"m": mv, "v": mv, "count": ns(P(None))}}
    b, s = shape.global_batch, shape.seq_len
    batch_abs = {"tokens": jax.ShapeDtypeStruct((G, b // G, s), jnp.int32)}
    b_shard = {"tokens": ns(P("pod", "data", None))}
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.jit(
        step,
        in_shardings=(p_shard, o_shard, b_shard, ns(P())),
        out_shardings=(p_shard, o_shard, None),
    ).lower(params_abs, opt_abs, batch_abs, key)


# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=list(ARCHITECTURES), required=False)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), required=False)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--p4", action="store_true", help="lower the P4 dual-model step")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--set", nargs="*", default=[], help="ModelConfig overrides k=v")
    ap.add_argument("--out", default=None, help="append JSON result to this file")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the unrolled cost-variant lowering")
    ap.add_argument("--rule", nargs="*", default=[],
                    help="sharding-rule overrides, e.g. vocab=none heads=model")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    rule_overrides = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rule_overrides[k] = None if v.lower() == "none" else v

    result = lower_combo(args.arch, args.shape, multi_pod=args.multi_pod,
                         p4=args.p4, overrides=overrides, fsdp=not args.no_fsdp,
                         cost_variant=not args.no_cost,
                         rule_overrides=rule_overrides or None)
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(result) + "\n")


if __name__ == "__main__":
    main()
