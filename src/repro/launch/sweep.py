"""Run the full dry-run sweep: every (arch × input-shape × mesh) as an
isolated subprocess (a failed combo doesn't kill the sweep), appending JSONL
results consumed by benchmarks/bench_roofline.py and EXPERIMENTS.md."""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = ["qwen2-vl-72b", "zamba2-7b", "mixtral-8x22b", "qwen3-14b",
         "moonshot-v1-16b-a3b", "granite-34b", "llama3.2-1b", "xlstm-125m",
         "musicgen-large", "llama4-maverick-400b-a17b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun_sweep.jsonl")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--archs", nargs="*", default=ARCHS)
    ap.add_argument("--shapes", nargs="*", default=SHAPES)
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    done = set()
    if args.skip_done and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in args.archs:
            for shape in args.shapes:
                if (arch, shape, mesh_name) in done:
                    print(f"skip {arch} {shape} {mesh_name} (done)", flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                # multi-pod pass proves the pod axis shards; roofline table is
                # single-pod — skip the (expensive) cost extrapolation there.
                if args.no_cost or mp:
                    cmd.append("--no-cost")
                t0 = time.time()
                print(f"=== {arch} × {shape} × {mesh_name}", flush=True)
                try:
                    p = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=args.timeout)
                    ok = p.returncode == 0
                    if not ok:
                        failures.append((arch, shape, mesh_name,
                                         p.stderr.strip().splitlines()[-1] if p.stderr else "?"))
                        print(p.stdout[-2000:])
                        print(p.stderr[-3000:])
                except subprocess.TimeoutExpired:
                    ok = False
                    failures.append((arch, shape, mesh_name, "timeout"))
                print(f"    -> {'OK' if ok else 'FAIL'} in {time.time()-t0:.0f}s",
                      flush=True)
    print(f"\nsweep complete; {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
