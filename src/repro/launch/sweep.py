"""Sweeps.

Default mode — the full dry-run sweep: every (arch × input-shape × mesh) as
an isolated subprocess (a failed combo doesn't kill the sweep), appending
JSONL results consumed by benchmarks/bench_roofline.py and EXPERIMENTS.md.

``--privacy`` — the small-scale ε-sweep: P4 across privacy budgets × client
sampling rates on the federation engine. The reported budget per point is
read back from ``History.metrics["dp_epsilon"]`` (the engine's PrivacyLedger
record) rather than re-derived from the config, so the sweep output and the
training record cannot disagree.

``--topology`` — the graph sweep: DP-DSGT across topology families × link
drop rates. Each record carries the graph's spectral gap and the measured
per-round byte/message load, so accuracy-vs-spectral-gap and
accuracy-vs-drop-rate curves come straight out of the JSONL."""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = ["qwen2-vl-72b", "zamba2-7b", "mixtral-8x22b", "qwen3-14b",
         "moonshot-v1-16b-a3b", "granite-34b", "llama3.2-1b", "xlstm-125m",
         "musicgen-large", "llama4-maverick-400b-a17b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def privacy_sweep(args) -> None:
    """P4 (ε × client-rate) grid; budgets read from History, not recomputed.

    Compiled chunks are shared ACROSS sweep points through the engine's
    global chunk cache: the calibrated σ reaches the trace as a runtime
    argument, so every ε at the same client rate reuses the first point's
    compilation (the bootstrap chunk always; the co-train chunk whenever the
    formed groups coincide). Cache hit/miss/trace counts are reported per
    point so a retrace regression is visible in the sweep log.

    ``--sharded`` runs each point on the ShardedEngine over a client mesh of
    every available device (set XLA_FLAGS=--xla_force_host_platform_device_count=N
    to host-simulate)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.config import (DPConfig, P4Config, RunConfig, ScheduleConfig,
                              TrainConfig)
    from repro.core.p4 import P4Trainer
    from repro.engine import clear_chunk_cache
    from repro.obs import probe_deltas

    mesh = None
    if args.sharded:
        from repro.launch.mesh import make_client_mesh
        mesh = make_client_mesh(args.mesh_clients or None)

    clear_chunk_cache()
    rng = np.random.default_rng(args.seed)
    M, R, feat, classes = 16, 96, 64, 10
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, R))
    xs = protos[ys] + rng.normal(size=(M, R, feat)).astype(np.float32) * 0.4
    X, Y = xs, ys.astype(np.int32)
    tx, ty = jnp.asarray(X), jnp.asarray(Y)
    rounds, batch = args.rounds, 24

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        for eps in args.epsilons:
            for q in args.client_rates:
                sched = (ScheduleConfig(kind="sampling", client_rate=q)
                         if q < 1.0 else ScheduleConfig())
                cfg = RunConfig(
                    dp=DPConfig(epsilon=float(eps), rounds=rounds,
                                sample_rate=batch / R),
                    p4=P4Config(group_size=4, sample_peers=M - 1),
                    train=TrainConfig(learning_rate=0.5), schedule=sched)
                tr = P4Trainer(feat_dim=feat, num_classes=classes, cfg=cfg)
                t0 = time.time()
                # THIS point's cache behavior (points after the first should
                # be pure hits), not the cumulative global counters
                with probe_deltas("engine.chunk_cache") as deltas:
                    _, _, hist = tr.fit(X, Y, tx, ty, rounds=rounds,
                                        eval_every=max(rounds - 1, 1),
                                        batch_size=batch,
                                        target_epsilon=float(eps), mesh=mesh)
                cache = deltas["engine.chunk_cache"]
                rec = {"mode": "privacy", "epsilon_target": float(eps),
                       "client_rate": float(q), "sigma": round(tr.sigma, 4),
                       # the ledger's record IS the budget — no re-derivation
                       "epsilon_spent": round(hist.metrics["dp_epsilon"][-1], 4),
                       "delta": hist.metrics["dp_delta"][-1],
                       "accuracy": round(hist[-1][1], 4),
                       "rounds": rounds, "seconds": round(time.time() - t0, 1),
                       "sharded": bool(mesh is not None),
                       "chunk_cache": cache}
                f.write(json.dumps(rec) + "\n")
                f.flush()
                print(f"eps={eps} q={q}: sigma={rec['sigma']} "
                      f"spent={rec['epsilon_spent']} acc={rec['accuracy']} "
                      f"cache={cache['hits']}h/{cache['misses']}m/"
                      f"{cache['traces']}t",
                      flush=True)


def topology_sweep(args) -> None:
    """DP-DSGT (topology family × drop rate) grid on the federation engine.

    Per point: the configured graph's spectral gap (the mixing-rate axis the
    accuracy curves are plotted against), final accuracy, and the per-round
    gossip byte/message/link load measured on a ``P2PNetwork`` — including
    the relay-free per-link maximum, the load-balance number a real
    deployment cares about. ``--sharded`` runs each point on the
    ShardedEngine over a client mesh of every available device."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.baselines.dp_dsgt import DPDSGTStrategy
    from repro.config import TopologyConfig
    from repro.core.p2p import P2PNetwork
    from repro.engine import Engine, FederatedData, ShardedEngine
    from repro.topology import make_topology, per_link_summary

    mesh = None
    if args.sharded:
        from repro.launch.mesh import make_client_mesh
        mesh = make_client_mesh(args.mesh_clients or None)

    rng = np.random.default_rng(args.seed)
    M, R, feat, classes = 16, 96, 64, 10
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, R))
    xs = protos[ys] + rng.normal(size=(M, R, feat)).astype(np.float32) * 0.4
    X, Y = xs, ys.astype(np.int32)
    data = FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))
    rounds, batch = args.rounds, 24

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        for fam in args.families:
            base = make_topology(TopologyConfig(family=fam, k=args.degree,
                                                seed=args.seed), M)
            for drop in args.drop_rates:
                topo = base.with_faults(drop_prob=drop) if drop > 0 else base
                strat = DPDSGTStrategy(feat_dim=feat, num_classes=classes,
                                       lr=0.3, sigma=args.sigma,
                                       topology=topo)
                net = P2PNetwork(M)
                eng = (ShardedEngine(strat, eval_every=max(rounds - 1, 1),
                                     network=net, mesh=mesh) if mesh is not None
                       else Engine(strat, eval_every=max(rounds - 1, 1),
                                   network=net))
                t0 = time.time()
                _, hist = eng.fit(data, rounds=rounds,
                                  key=jax.random.PRNGKey(args.seed),
                                  batch_size=batch)
                links = per_link_summary(net)
                rec = {"mode": "topology", "family": fam,
                       "topology": topo.describe(),
                       "drop_prob": float(drop),
                       "spectral_gap": topo.describe()["spectral_gap"],
                       "accuracy": round(hist[-1][1], 4),
                       "rounds": rounds,
                       "messages_per_round": round(net.num_messages() / rounds, 2),
                       "bytes_per_round": round(net.total_bytes() / rounds, 1),
                       **links,
                       "seconds": round(time.time() - t0, 1),
                       "sharded": bool(mesh is not None)}
                f.write(json.dumps(rec) + "\n")
                f.flush()
                print(f"{fam} drop={drop}: gap={rec['spectral_gap']} "
                      f"acc={rec['accuracy']} "
                      f"bytes/round={rec['bytes_per_round']}", flush=True)

        if getattr(args, "learned", False):
            _learned_point(args, data, mesh, f)


def _learned_point(args, data, mesh, f) -> None:
    """The ``--learned`` point of the topology sweep: DP-DSGT with a
    periodically re-learned push-sum graph, compared against every static
    family at EQUAL TOTAL byte budget — each static family runs for however
    many rounds its per-round gossip traffic affords out of the learned
    run's measured spend (estimation traffic included), so dense graphs pay
    for their extra links in rounds. Records the accuracy-vs-spectral-gap
    trajectory of the learned sequence."""
    import jax
    import numpy as np

    from repro.baselines.dp_dsgt import DPDSGTStrategy
    from repro.config import TopologyConfig
    from repro.core.p2p import P2PNetwork
    from repro.engine import Engine, ShardedEngine
    from repro.topology import make_topology
    from repro.topology.learned import run_learned_dsgt

    M = data.num_clients
    feat = int(data.train_x.shape[-1])
    classes = int(np.asarray(data.train_y).max()) + 1
    rounds, batch = args.rounds, 24

    def dsgt_accuracy(topo, n_rounds, net=None):
        strat = DPDSGTStrategy(feat_dim=feat, num_classes=classes, lr=0.3,
                               sigma=args.sigma, topology=topo)
        eng = (ShardedEngine(strat, eval_every=max(n_rounds - 1, 1),
                             network=net, mesh=mesh) if mesh is not None
               else Engine(strat, eval_every=max(n_rounds - 1, 1),
                           network=net))
        _, hist = eng.fit(data, rounds=n_rounds,
                          key=jax.random.PRNGKey(args.seed),
                          batch_size=batch)
        return float(hist[-1][1])

    interval = args.learn_every or max(8, rounds // 4)
    net = P2PNetwork(M)
    t0 = time.time()
    _, lrec = run_learned_dsgt(
        data, rounds=rounds, interval=interval, k=args.degree, lr=0.3,
        sigma=args.sigma, sigma_dist=args.learn_sigma,
        window=args.learn_window, batch=batch, seed=args.seed, network=net,
        mesh=mesh, num_classes=classes)
    budget = net.total_bytes()
    lacc = float(lrec["accuracy"])

    comparisons = {}
    for fam in args.families:
        topo = make_topology(TopologyConfig(family=fam, k=args.degree,
                                            seed=args.seed), M)
        probe = P2PNetwork(M)
        dsgt_accuracy(topo, 4, net=probe)
        bpr = probe.total_bytes() / 4.0
        rounds_f = int(np.clip(round(budget / max(bpr, 1.0)), 4, 4 * rounds))
        comparisons[fam] = {
            "rounds_at_budget": rounds_f,
            "bytes_per_round": round(bpr, 1),
            "accuracy": round(dsgt_accuracy(topo, rounds_f), 4),
            "spectral_gap": topo.describe()["spectral_gap"],
        }
    matches_or_beats = {fam: bool(lacc + 5e-3 >= c["accuracy"])
                        for fam, c in comparisons.items()}
    rec = {"mode": "topology_learned",
           "topology": lrec["final_topology"],
           "accuracy": round(lacc, 4),
           "rounds": rounds, "interval": interval,
           "learn_sigma": float(args.learn_sigma),
           "degree": int(args.degree),
           "estimates": lrec["estimates"],
           "fallbacks": lrec["fallbacks"],
           "gap_trajectory": lrec["gap_trajectory"],
           "history": [[int(r), round(float(a), 4)]
                       for r, a in lrec["history"]],
           "bytes_total": int(budget),
           "bytes_per_round": round(budget / rounds, 1),
           "equal_budget_static": comparisons,
           "matches_or_beats": matches_or_beats,
           "beats_all_static": bool(all(matches_or_beats.values())),
           "seconds": round(time.time() - t0, 1),
           "sharded": bool(mesh is not None)}
    f.write(json.dumps(rec) + "\n")
    f.flush()
    print(f"learned: acc={rec['accuracy']} "
          f"gaps={rec['gap_trajectory']} "
          f"beats_all_static={rec['beats_all_static']} "
          f"{ {k: c['accuracy'] for k, c in comparisons.items()} }",
          flush=True)


def faults_sweep(args) -> None:
    """P4 under the correlated fault chains: (burst length × link drop rate ×
    partition frequency) grid on the federation engine.

    Every point runs the same grouped P4 federation through a
    ``FaultProcess`` built from the grid cell — Gilbert–Elliott link bursts
    at the cell's stationary drop rate and mean burst length, partition
    events at the cell's onset frequency — plus a fixed node outage/repair
    chain so aggregator failover actually exercises. Per point: final
    accuracy, the mean realized availability, per-round byte/message load
    from the host-side ledger (which re-derives the exact in-jit fault
    realizations), and the failover count (rounds a group ran on a stand-in
    aggregator)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import (DPConfig, P4Config, RunConfig, TrainConfig)
    from repro.core.p2p import P2PNetwork
    from repro.core.p4 import P4Strategy, P4Trainer
    from repro.engine import Engine, FederatedData
    from repro.resilience import (FaultModel, gilbert_elliott_rates,
                                  host_realizations, make_fault_process)

    rng = np.random.default_rng(args.seed)
    M, R, feat, classes = 16, 96, 64, 10
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, R))
    xs = protos[ys] + rng.normal(size=(M, R, feat)).astype(np.float32) * 0.4
    X, Y = xs, ys.astype(np.int32)
    data = FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))
    rounds, batch = args.rounds, 24
    groups = [list(range(g, M, M // 4)) for g in range(M // 4)]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        for burst in args.burst_lengths:
            for drop in args.drop_rates:
                for pfreq in args.partition_freqs:
                    fail, repair = gilbert_elliott_rates(drop, burst)
                    model = FaultModel(
                        link_fail=fail, link_repair=repair,
                        partition_prob=pfreq, partition_repair=0.5,
                        node_fail=0.15, node_repair=0.5, quorum=0.5)
                    faults = make_fault_process(model, M)
                    cfg = RunConfig(
                        dp=DPConfig(epsilon=15.0, rounds=rounds,
                                    sample_rate=batch / R),
                        p4=P4Config(group_size=4, sample_peers=M - 1),
                        train=TrainConfig(learning_rate=0.5))
                    strat = P4Strategy(trainer=P4Trainer(
                        feat_dim=feat, num_classes=classes, cfg=cfg))
                    strat.set_groups([list(g) for g in groups], M)
                    strat.failover_count = 0
                    net = P2PNetwork(M)
                    key = jax.random.PRNGKey(args.seed)
                    t0 = time.time()
                    _, hist = Engine(strat, eval_every=max(rounds - 1, 1),
                                     network=net, faults=faults).fit(
                        data, rounds=rounds, key=key, batch_size=batch)
                    phase_key = jax.random.split(
                        jax.random.fold_in(key, 0x9e37))[1]
                    frs = host_realizations(faults, phase_key, 0, 0, rounds)
                    rec = {"mode": "faults",
                           "burst_length": float(burst),
                           "drop_rate": float(drop),
                           "partition_freq": float(pfreq),
                           "accuracy": round(hist[-1][1], 4),
                           "rounds": rounds,
                           "mean_availability": round(float(np.mean(
                               [fr.active.mean() for fr in frs])), 4),
                           "messages_per_round": round(
                               net.num_messages() / rounds, 2),
                           "bytes_per_round": round(
                               net.total_bytes() / rounds, 1),
                           "failover_count": strat.failover_count,
                           "seconds": round(time.time() - t0, 1)}
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    print(f"burst={burst} drop={drop} part={pfreq}: "
                          f"acc={rec['accuracy']} "
                          f"avail={rec['mean_availability']} "
                          f"bytes/round={rec['bytes_per_round']} "
                          f"failovers={rec['failover_count']}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun_sweep.jsonl")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--archs", nargs="*", default=ARCHS)
    ap.add_argument("--shapes", nargs="*", default=SHAPES)
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--privacy", action="store_true",
                    help="run the P4 epsilon x client-rate sweep instead")
    ap.add_argument("--epsilons", nargs="*", type=float,
                    default=[3.0, 8.0, 15.0])
    ap.add_argument("--client-rates", nargs="*", type=float,
                    default=[1.0, 0.5, 0.1])
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sharded", action="store_true",
                    help="--privacy/--topology: run points on the "
                         "ShardedEngine over a client mesh of every device")
    ap.add_argument("--mesh-clients", type=int, default=0,
                    help="--privacy/--topology --sharded: client-mesh size "
                         "(0 = all)")
    ap.add_argument("--topology", action="store_true",
                    help="run the DP-DSGT topology-family x drop-rate sweep")
    ap.add_argument("--families", nargs="*",
                    default=["ring", "kregular", "exponential", "smallworld",
                             "full"])
    ap.add_argument("--drop-rates", nargs="*", type=float,
                    default=[0.0, 0.1, 0.3])
    ap.add_argument("--degree", type=int, default=4,
                    help="--topology: degree for kregular/smallworld")
    ap.add_argument("--sigma", type=float, default=0.3,
                    help="--topology: DP noise multiplier")
    ap.add_argument("--learned", action="store_true",
                    help="--topology: add the learned-graph (push-sum) "
                         "point with an equal-byte-budget comparison "
                         "against every static family")
    ap.add_argument("--learn-every", type=int, default=0,
                    help="--learned: rounds between graph re-estimations "
                         "(0 = rounds // 4)")
    ap.add_argument("--learn-sigma", type=float, default=2.0,
                    help="--learned: DP noise multiplier on the released "
                         "pairwise distances")
    ap.add_argument("--learn-window", type=int, default=1,
                    help="--learned: estimates folded as a "
                         "TimeVaryingTopology window")
    ap.add_argument("--faults", action="store_true",
                    help="run the P4 burst-length x drop-rate x "
                         "partition-frequency fault sweep")
    ap.add_argument("--burst-lengths", nargs="*", type=float,
                    default=[1.0, 3.0, 8.0])
    ap.add_argument("--partition-freqs", nargs="*", type=float,
                    default=[0.0, 0.1, 0.3])
    args = ap.parse_args()

    if args.privacy:
        if args.out == "results/dryrun_sweep.jsonl":
            args.out = "results/privacy_sweep.jsonl"
        privacy_sweep(args)
        return
    if args.topology:
        if args.out == "results/dryrun_sweep.jsonl":
            args.out = "results/topology_sweep.jsonl"
        topology_sweep(args)
        return
    if args.faults:
        if args.out == "results/dryrun_sweep.jsonl":
            args.out = "results/fault_sweep.jsonl"
        faults_sweep(args)
        return

    done = set()
    if args.skip_done and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in args.archs:
            for shape in args.shapes:
                if (arch, shape, mesh_name) in done:
                    print(f"skip {arch} {shape} {mesh_name} (done)", flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                # multi-pod pass proves the pod axis shards; roofline table is
                # single-pod — skip the (expensive) cost extrapolation there.
                if args.no_cost or mp:
                    cmd.append("--no-cost")
                t0 = time.time()
                print(f"=== {arch} × {shape} × {mesh_name}", flush=True)
                try:
                    p = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=args.timeout)
                    ok = p.returncode == 0
                    if not ok:
                        failures.append((arch, shape, mesh_name,
                                         p.stderr.strip().splitlines()[-1] if p.stderr else "?"))
                        print(p.stdout[-2000:])
                        print(p.stderr[-3000:])
                except subprocess.TimeoutExpired:
                    ok = False
                    failures.append((arch, shape, mesh_name, "timeout"))
                print(f"    -> {'OK' if ok else 'FAIL'} in {time.time()-t0:.0f}s",
                      flush=True)
    print(f"\nsweep complete; {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
