"""End-to-end training driver.

Two modes:
  standard — one model, AdamW, synthetic structured token stream. With
             ``--arch llama3.2-1b --reduced`` scaled to ~100M params this is
             the brief's "train a ~100M model for a few hundred steps" driver.
  --p4     — the paper's technique at LM scale: G client groups, dual
             private/proxy models, DP-noised proxy gradients, group-internal
             aggregation (vmap over the group axis).

Runs on whatever devices exist (CPU here; the production mesh path is
exercised by launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import DPConfig, P4Config, TrainConfig, replace
from repro.configs import get_config, get_reduced_config
from repro.data.tokens import synth_token_batch
from repro.models.api import build_model, make_train_step


def scale_to_100m(cfg):
    """A ~100M-param member of the same family (for the e2e example)."""
    return replace(cfg, num_layers=max(4, min(cfg.num_layers, 8)),
                   d_model=512, num_heads=8,
                   num_kv_heads=min(8, max(1, cfg.num_kv_heads)),
                   d_ff=2048, vocab_size=min(cfg.vocab_size, 32768),
                   head_dim=0, remat="none",
                   mrope_sections=(8, 12, 12) if cfg.mrope_sections else (),
                   vision_tokens=min(cfg.vision_tokens, 64))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", help="smoke-sized model")
    ap.add_argument("--m100", action="store_true", help="~100M-param variant")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--p4", action="store_true")
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--topology", default="none",
                    help="--p4: inter-group proxy gossip graph over the G "
                         "groups (ring | full | kregular | exponential | "
                         "erdos | smallworld | gossip); 'none' keeps groups "
                         "isolated as in the paper")
    ap.add_argument("--gossip-every", type=int, default=10,
                    help="--p4 --topology: proxy gossip cadence in steps")
    ap.add_argument("--drop-prob", type=float, default=0.0,
                    help="--p4 --topology: per-gossip link-drop probability")
    ap.add_argument("--epsilon", type=float, default=15.0)
    ap.add_argument("--target-epsilon", type=float, default=None,
                    help="RDP-calibrate the proxy noise to this budget "
                         "instead of the Eq. 12 sigma")
    ap.add_argument("--delta", type=float, default=1e-5)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.m100:
        cfg = scale_to_100m(get_config(args.arch))
    cfg = replace(cfg, max_seq_len=max(cfg.max_seq_len, args.seq))
    api = build_model(cfg)
    train_cfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                            warmup_steps=max(10, args.steps // 10))
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    from repro.utils.pytree import param_count
    params = api.init(key)
    print(f"arch={cfg.name} params={param_count(params)/1e6:.1f}M "
          f"family={cfg.family}")

    def make_batch(g=None):
        toks = synth_token_batch(rng, args.batch, args.seq, cfg.vocab_size)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            from repro.models.frontends import synth_mrope_positions, synth_vision_embeds
            batch["vision_embeds"] = synth_vision_embeds(key, cfg, args.batch)
            batch["mrope_positions"] = synth_mrope_positions(cfg, args.batch, args.seq)
        if cfg.family == "audio":
            from repro.models.frontends import synth_audio_frames
            batch = {"frames": synth_audio_frames(key, cfg, args.batch, args.seq),
                     "codes": jnp.asarray(rng.integers(
                         0, cfg.vocab_size,
                         (args.batch, args.seq, cfg.audio_codebooks)), jnp.int32)}
        if g is not None:
            batch = jax.tree_util.tree_map(
                lambda t: jnp.broadcast_to(t[None], (g,) + t.shape), batch)
        return batch

    if args.p4:
        from repro.core import dp as dp_lib
        from repro.core.p4 import make_p4_lm_step
        from repro.data.tokens import synth_token_batch_device
        from repro.engine import PrivacyLedger, make_scan_steps
        from repro.optim import make_optimizer
        G = args.groups
        # engine-native accounting: the ledger follows the run and the log
        # lines below read the cumulative spend from it, not a re-derivation
        ledger = PrivacyLedger(sigma=0.0, delta=args.delta, sample_rate=1.0)
        dp_cfg = DPConfig(epsilon=args.epsilon, microbatches=2,
                          rounds=args.steps)
        if args.target_epsilon is not None:
            dp_cfg = replace(dp_cfg,
                             noise_multiplier=ledger.calibrate(
                                 args.target_epsilon, args.steps))
        ledger.sigma = dp_cfg.noise_multiplier or dp_lib.noble_sigma(
            dp_cfg.epsilon, args.delta, sample_rate=dp_cfg.sample_rate,
            rounds=dp_cfg.rounds, local_steps=dp_cfg.local_steps)
        step = make_p4_lm_step(api, api, train_cfg, dp_cfg, P4Config())
        opt = make_optimizer(train_cfg)

        def stack_init(k):
            return jax.vmap(api.init)(jax.random.split(k, G))
        params = {"private": stack_init(key), "proxy": stack_init(jax.random.fold_in(key, 1))}
        opt_states = {"private": jax.vmap(opt.init)(params["private"]),
                      "proxy": jax.vmap(opt.init)(params["proxy"])}

        # engine scan loop: the batch (tokens + any vlm frontend fields) is
        # drawn inside the trace, log_every steps per XLA call, the
        # (params, opt_states) carry donated
        def device_batch(k, i):
            k1, k2 = jax.random.split(k)
            batch = {"tokens": synth_token_batch_device(k1, args.batch,
                                                        args.seq, cfg.vocab_size)}
            if cfg.family == "vlm":
                from repro.models.frontends import (synth_mrope_positions,
                                                    synth_vision_embeds)
                batch["vision_embeds"] = synth_vision_embeds(k2, cfg, args.batch)
                batch["mrope_positions"] = synth_mrope_positions(cfg, args.batch,
                                                                 args.seq)
            return jax.tree_util.tree_map(
                lambda t: jnp.broadcast_to(t[None], (G,) + t.shape), batch)

        # inter-group proxy gossip at LM scale: the G group models become
        # nodes of a communication graph and their proxies mix every
        # --gossip-every steps — co-train rounds route over the configured
        # topology instead of groups staying mutually isolated
        gossip_fn = None
        if args.topology != "none" and G > 1:
            from repro.config import TopologyConfig
            from repro.topology import make_plan, make_topology, mix_stacked
            topo = make_topology(
                TopologyConfig(family=args.topology, k=min(4, G - 1),
                               drop_prob=args.drop_prob), G)
            plan = make_plan(topo)
            print(f"inter-group topology: {topo.describe()}")
            gossip_fn = jax.jit(
                lambda p, r, k: mix_stacked(p, plan, r, k))

        chunk = max(1, min(args.log_every, args.steps))
        scans = {chunk: make_scan_steps(step, device_batch, chunk)}
        i = 0
        while i < args.steps:
            length = min(chunk, args.steps - i)
            if length not in scans:
                scans[length] = make_scan_steps(step, device_batch, length)
            t0 = time.time()
            params, opt_states, losses = scans[length](params, opt_states, key, i)
            if gossip_fn is not None:
                # fire once per crossed gossip boundary — exact divisibility
                # would silently skip cadences that don't align with the
                # chunking (--log-every)
                g = max(1, args.gossip_every)
                for r in range(i // g + 1, (i + length) // g + 1):
                    params["proxy"] = gossip_fn(
                        params["proxy"], r, jax.random.fold_in(key, 0x7090 + r))
            ledger.advance(length)
            eps, delta = ledger.spend()
            print(f"step {i:4d} loss={float(losses[0]):.4f} "
                  f"eps={eps:.2f} (delta={delta:g}) "
                  f"({(time.time()-t0)/length:.2f}s/step)", flush=True)
            i += length
    else:
        train_step, opt = make_train_step(api, train_cfg)
        opt_state = opt.init(params)
        train_step = jax.jit(train_step)
        for i in range(args.steps):
            batch = make_batch()
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            if i % args.log_every == 0:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"ce={float(metrics['ce']):.4f} ({time.time()-t0:.2f}s)",
                      flush=True)
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps, {"params": params})
            print(f"checkpoint saved to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
