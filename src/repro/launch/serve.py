"""Serving driver: prefill a prompt batch, then autoregressive batched decode
against the KV/SSM cache (greedy)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import replace
from repro.configs import get_config, get_reduced_config
from repro.models.api import build_model, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    b, s = args.batch, args.prompt_len
    total = s + args.gen

    rng = np.random.default_rng(0)
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.family == "vlm":
        from repro.models.frontends import synth_mrope_positions, synth_vision_embeds
        batch["vision_embeds"] = synth_vision_embeds(key, cfg, b)
        batch["mrope_positions"] = synth_mrope_positions(cfg, b, s)

    t0 = time.time()
    logits, caches = jax.jit(api.prefill_fn)(params, batch)
    print(f"prefill [{b}×{s}] in {time.time()-t0:.2f}s")

    # grow attention caches to the full generation length
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        full = api.init_caches(b, total)
        caches = jax.tree_util.tree_map(
            lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=2), full, caches)
    elif cfg.family == "hybrid":
        attn_c, mamba_c = caches
        full = api.init_caches(b, total)
        attn_full = jax.tree_util.tree_map(
            lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=2), full[0], attn_c)
        caches = (attn_full, mamba_c)

    serve_step = jax.jit(make_serve_step(api))
    token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    out_tokens = [token]
    t0 = time.time()
    for i in range(args.gen - 1):
        dbatch = {"index": jnp.asarray(s + i, jnp.int32)}
        if cfg.family == "audio":
            dbatch["frames"] = jax.random.normal(
                jax.random.fold_in(key, i), (b, 1, cfg.d_model), jnp.float32)
        else:
            dbatch["tokens"] = token[:, None]
        if cfg.family == "vlm":
            dbatch["vision_embeds"] = jnp.zeros((b, 0, cfg.d_model), jnp.bfloat16)
            dbatch["mrope_positions"] = jnp.full((3, b, 1), s + i, jnp.int32)
        token, logits_d, caches = serve_step(params, caches, dbatch)
        token = token.astype(jnp.int32)
        out_tokens.append(token)
    dt = time.time() - t0
    toks = jnp.stack(out_tokens, axis=1)
    print(f"decoded {args.gen}×{b} tokens in {dt:.2f}s "
          f"({args.gen * b / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(toks[0])[:16])


if __name__ == "__main__":
    main()
