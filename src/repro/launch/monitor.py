"""Tail / summarize a telemetry run directory (``repro.obs.Telemetry``).

    PYTHONPATH=src python -m repro.launch.monitor results/run1          # summary
    PYTHONPATH=src python -m repro.launch.monitor results/run1 --follow # live tail
    PYTHONPATH=src python -m repro.launch.monitor results/run1 --tail 20

The summary reads ``manifest.json`` + ``events.jsonl`` and reports the run's
identity (phases, fingerprints, mesh), the (ε, δ)/accuracy trajectory, span
aggregates with the trace-vs-execute split (chunks that hit the compiled-
chunk cache vs chunks that traced), tap-stream coverage, and the closing
probe snapshot. ``--follow`` tails the event stream, rendering one line per
event as it lands — usable against a live run from another process.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, Iterator, List, Optional


def load_manifest(run_dir: str) -> Optional[Dict[str, Any]]:
    path = os.path.join(run_dir, "manifest.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def load_events(run_dir: str) -> List[Dict[str, Any]]:
    path = os.path.join(run_dir, "events.jsonl")
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    pass  # a line mid-write during a live tail
    return out


def _fmt_event(ev: Dict[str, Any]) -> str:
    t = ev.get("type", "?")
    if t == "span":
        extra = ""
        if ev.get("name") == "chunk":
            extra = (f" chunk={ev.get('chunk')} rounds=[{ev.get('start')},"
                     f"{ev.get('stop')}) "
                     f"{'traced' if ev.get('traced') else 'cached'}")
            if ev.get("mix_path"):
                extra += f" mix={ev['mix_path']}"
            if "profile_dir" in ev:
                extra += " [profiled]"
        return f"span {ev.get('name'):<12} {ev.get('dt', 0):8.4f}s{extra}"
    if t == "tap":
        vals = {k: v for k, v in ev.items()
                if k not in ("type", "t", "round", "source")}
        body = " ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                        for k, v in sorted(vals.items()))
        return f"tap  round={ev.get('round'):<6} {body}"
    if t == "eval":
        vals = {k: v for k, v in ev.items() if k not in ("type", "t")}
        body = " ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                        for k, v in sorted(vals.items()))
        return f"eval {body}"
    return f"{t} " + json.dumps({k: v for k, v in ev.items()
                                 if k not in ("type", "t")}, default=str)


def summarize(run_dir: str) -> str:
    manifest = load_manifest(run_dir)
    events = load_events(run_dir)
    lines = [f"run: {run_dir}"]
    if manifest:
        for i, ph in enumerate(manifest.get("phases", [])):
            lines.append(
                f"phase {i}: {ph.get('engine')}/{ph.get('strategy')} "
                f"{ph.get('schedule')} rounds=[{ph.get('start_round')},"
                f"{ph.get('rounds')}) batch={ph.get('batch_size')} "
                f"mesh={ph.get('mesh')}")
        traj = manifest.get("trajectory", [])
        if traj:
            last = traj[-1]
            eps = last.get("dp_epsilon")
            lines.append(
                f"trajectory: {len(traj)} evals, last round="
                f"{last.get('round')} acc={last.get('accuracy'):.4f}"
                + (f" eps={eps:.4g} delta={last.get('dp_delta'):.3g}"
                   if eps is not None else ""))

    spans: Dict[str, List[Dict[str, Any]]] = {}
    taps = 0
    tap_rounds: List[int] = []
    for ev in events:
        if ev.get("type") == "span":
            spans.setdefault(ev.get("name", "?"), []).append(ev)
        elif ev.get("type") == "tap":
            taps += 1
            tap_rounds.append(int(ev.get("round", -1)))
    for name in sorted(spans):
        evs = spans[name]
        total = sum(e.get("dt", 0.0) for e in evs)
        if name == "chunk":
            traced = [e for e in evs if e.get("traced")]
            cached = [e for e in evs if not e.get("traced")]

            def agg(sub):
                return (f"{len(sub)}x mean "
                        f"{(sum(e.get('dt', 0.0) for e in sub) / len(sub)):.4f}s"
                        if sub else "0x")

            lines.append(f"span chunk: {len(evs)}x total {total:.3f}s — "
                         f"traced(+compile) {agg(traced)}, "
                         f"execute-only {agg(cached)}")
            paths = sorted({e.get("mix_path") for e in evs
                            if e.get("mix_path")})
            if paths:
                lines.append(f"  mix paths: {', '.join(paths)}")
            prof = [e for e in evs if "profile_dir" in e]
            if prof:
                lines.append(f"  profiler capture: chunk "
                             f"{prof[0].get('chunk')} → "
                             f"{prof[0]['profile_dir']}")
        else:
            lines.append(f"span {name}: {len(evs)}x total {total:.3f}s")
    if taps:
        lines.append(f"tap: {taps} rounds streamed "
                     f"[{min(tap_rounds)}..{max(tap_rounds)}]")
    if manifest and manifest.get("probes"):
        for pname, counters in sorted(manifest["probes"].items()):
            nz = {k: v for k, v in counters.items() if v}
            lines.append(f"probe {pname}: {nz or dict(counters)}")
    if len(lines) == 1:
        lines.append("(no telemetry found — is this a Telemetry run_dir?)")
    return "\n".join(lines)


def follow(run_dir: str, poll: float = 0.5) -> Iterator[str]:
    """Yield one formatted line per event as the stream grows (tail -f)."""
    path = os.path.join(run_dir, "events.jsonl")
    pos = 0
    while True:
        if os.path.exists(path):
            with open(path) as f:
                f.seek(pos)
                for line in f:
                    if not line.endswith("\n"):
                        break  # partial write: re-read next poll
                    pos += len(line)
                    line = line.strip()
                    if line:
                        try:
                            yield _fmt_event(json.loads(line))
                        except json.JSONDecodeError:
                            pass
        time.sleep(poll)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Tail/summarize a repro.obs.Telemetry run directory")
    ap.add_argument("run_dir")
    ap.add_argument("--follow", action="store_true",
                    help="tail the event stream live")
    ap.add_argument("--tail", type=int, default=0, metavar="N",
                    help="print the last N events and exit")
    args = ap.parse_args(argv)
    if args.follow:
        try:
            for line in follow(args.run_dir):
                print(line, flush=True)
        except KeyboardInterrupt:
            return 0
    elif args.tail:
        for ev in load_events(args.run_dir)[-args.tail:]:
            print(_fmt_event(ev))
    else:
        print(summarize(args.run_dir))
    return 0


if __name__ == "__main__":
    sys.exit(main())
