"""repro — P4 (Private, Personalized, Peer-to-Peer learning) as a multi-pod JAX framework.

Layers:
  repro.core      — the paper's contribution (scattering features, DP, KD, grouping, P4 step)
  repro.models    — transformer/MoE/SSM/hybrid substrate for the assigned architectures
  repro.baselines — the paper's comparison methods (FedAvg, Scaffold, ProxyFL, DP-DSGT, ...)
  repro.data      — synthetic non-IID task generators + LM token pipeline
  repro.optim     — pure-JAX optimizers and schedules
  repro.sharding  — logical-axis sharding rules
  repro.kernels   — Pallas TPU kernels (dp_clip, l1_distance, flash_attention)
  repro.configs   — assigned architecture configs + the paper's own models
  repro.launch    — mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
