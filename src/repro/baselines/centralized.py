"""Centralized baseline: all data pooled, one model, no privacy (paper's
'optimal scenario' reference, §4.2.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import common
from repro.core.small_models import accuracy


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 256, seed: int = 0, eval_every: int = 20):
    """train_x: pooled (N, feat); test per-client (M, n, feat) so we report the
    same per-client-mean accuracy metric as every other method."""
    feat, classes = train_x.shape[-1], int(jnp.max(train_y)) + 1
    specs, apply_fn = common.make_model(feat, classes)
    params = jax.tree_util.tree_map(
        lambda s: s, common.init_clients(specs, jax.random.PRNGKey(seed), 1))
    params = jax.tree_util.tree_map(lambda t: t[0], params)
    rng = np.random.default_rng(seed)
    loss = common.ce_loss(apply_fn)

    @jax.jit
    def step(params, x, y):
        g = jax.grad(loss)(params, {"x": x, "y": y})
        return common.sgd_update(params, g, lr)

    history = []
    N = train_x.shape[0]
    for r in range(rounds):
        idx = rng.integers(0, N, batch_size)
        params = step(params, jnp.asarray(train_x[idx]), jnp.asarray(train_y[idx]))
        if r % eval_every == 0 or r == rounds - 1:
            acc = jax.vmap(lambda x, y: accuracy(apply_fn(params, x), y))(test_x, test_y)
            history.append((r, float(jnp.mean(acc))))
    return params, history
