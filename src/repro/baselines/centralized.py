"""Centralized baseline: all data pooled, one model, no privacy (paper's
'optimal scenario' reference, §4.2.1). In engine terms it is the degenerate
M=1 strategy: the pool is a single "client", and evaluation broadcasts the
one model across the per-client test stacks so the reported metric is the
same per-client mean accuracy as every other method."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.core.small_models import accuracy
from repro.engine import Engine, FederatedData, Strategy, register_strategy


@register_strategy("centralized")
@dataclass(eq=False)
class CentralizedStrategy(Strategy):
    feat_dim: int = 0
    num_classes: int = 2
    lr: float = 0.5

    def __post_init__(self):
        self.specs, self.apply_fn = common.make_model(self.feat_dim,
                                                      self.num_classes)
        self._loss = common.ce_loss(self.apply_fn)

    def init(self, key, data: FederatedData, batch_size):
        return jax.tree_util.tree_map(
            lambda t: t[0], common.init_clients(self.specs, key, 1))

    def local_update(self, params, xs, ys, r, key):
        # xs: (1, B, feat) — the pooled "client"
        g = jax.grad(self._loss)(params, {"x": xs[0], "y": ys[0]})
        return common.sgd_update(params, g, self.lr), {}

    def eval_params(self, state):
        return state

    def evaluate(self, state, test_x, test_y):
        return jax.vmap(lambda x, y: accuracy(self.apply_fn(state, x), y))(
            test_x, test_y)


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 256, seed: int = 0, eval_every: int = 20,
          schedule=None):
    """train_x: pooled (N, feat); test per-client (M, n, feat) so we report the
    same per-client-mean accuracy metric as every other method."""
    feat, classes = train_x.shape[-1], int(jnp.max(jnp.asarray(train_y))) + 1
    strategy = CentralizedStrategy(feat_dim=feat, num_classes=classes, lr=lr)
    data = FederatedData(jnp.asarray(train_x)[None], jnp.asarray(train_y)[None],
                         test_x, test_y)
    state, hist = Engine(strategy, eval_every=eval_every,
                         schedule=schedule).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(seed),
        batch_size=batch_size)
    return state, hist
