"""DP-DSGT (Bayrooti et al. [4]): differentially-private decentralized SGD
with gradient tracking over a ring topology — consensus-seeking (one shared
solution), which is exactly what the paper argues fails under non-IID tasks.

  x_i ← Σ_j W_ij x̃_j − lr · y_i
  y_i ← Σ_j W_ij ỹ_j + (g_i(x⁺) − g_i(x))

where x̃/ỹ are the DP-noised (clipped) shared quantities.

Engine form: state = {params, tracker, last gradients}; the tracker is
bootstrapped in ``init`` from a first on-device batch draw.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.config import DPConfig
from repro.core import dp as dp_lib
from repro.engine import (Engine, FederatedData, FullParticipation,
                          PrivacyLedger, Strategy, register_strategy,
                          runtime_sigma, sample_client_batches)


def _mix_arith(t, left, right, self_w: float):
    """The W row applied to (self, left-neighbor, right-neighbor) values —
    one shared expression so the single-device roll, the gather fallback and
    the ppermute halo produce bit-identical arithmetic."""
    return self_w * t + (1 - self_w) / 2 * (left + right)


def _ring_mix(stacked, self_w: float = 0.5):
    """W = ring with self weight 1/2 and 1/4 to each neighbor."""
    def mix(t):
        return _mix_arith(t, jnp.roll(t, 1, axis=0), jnp.roll(t, -1, axis=0),
                          self_w)
    return jax.tree_util.tree_map(mix, stacked)


def _ring_mix_sharded(stacked, ctx, self_w: float = 0.5):
    """Ring gossip as an explicit collective: each shard ppermutes its edge
    rows to its mesh neighbors (a halo exchange — the communication pattern a
    real gossip round has). Valid only when the global ring lines up with the
    shard boundaries (no padding); the uneven case falls back to
    gather → roll → re-shard, which is exact for any M."""
    if ctx.M_pad != ctx.M:
        full = ctx.gather(stacked)
        return ctx.scatter_like(_ring_mix(full, self_w), full)
    fwd = [(i, (i + 1) % ctx.n) for i in range(ctx.n)]
    bwd = [(i, (i - 1) % ctx.n) for i in range(ctx.n)]

    def mix(t):
        prev_last = jax.lax.ppermute(t[-1:], ctx.axis, fwd)
        next_first = jax.lax.ppermute(t[:1], ctx.axis, bwd)
        left = jnp.concatenate([prev_last, t[:-1]], axis=0)
        right = jnp.concatenate([t[1:], next_first], axis=0)
        return _mix_arith(t, left, right, self_w)

    return jax.tree_util.tree_map(mix, stacked)


@register_strategy("dp_dsgt")
@dataclass(eq=False)
class DPDSGTStrategy(Strategy):
    feat_dim: int = 0
    num_classes: int = 2
    lr: float = 0.3
    clip: float = 1.0
    sigma: float = 0.0

    def __post_init__(self):
        self.specs, self.apply_fn = common.make_model(self.feat_dim,
                                                      self.num_classes)

    def _grads_keyed(self, params, xs, ys, keys):
        def one(p, x, y, k):
            return common.client_grad(self.apply_fn, p, x, y, k,
                                      dp_cfg=DPConfig(clip_norm=self.clip),
                                      sigma=runtime_sigma(self.sigma))
        return jax.vmap(one)(params, xs, ys, keys)

    def _grads(self, params, xs, ys, key):
        M = ys.shape[0]
        return self._grads_keyed(params, xs, ys, jax.random.split(key, M))

    def init(self, key, data: FederatedData, batch_size):
        k1, k2, k3 = jax.random.split(key, 3)
        x_params = common.init_clients(self.specs, k1, data.num_clients)
        xs0, ys0 = sample_client_batches(data.train_x, data.train_y, k2,
                                         batch_size)
        y_track = self._grads(x_params, xs0, ys0, k3)
        # distinct buffers: the engine donates the carry, and XLA rejects the
        # same buffer appearing twice in a donated argument
        return {"x": x_params, "y": y_track,
                "g": jax.tree_util.tree_map(jnp.copy, y_track)}

    def local_update(self, state, xs, ys, r, key):
        x_new = _ring_mix(state["x"])
        x_new = jax.tree_util.tree_map(lambda x, y: x - self.lr * y,
                                       x_new, state["y"])
        g_new = self._grads(x_new, xs, ys, key)
        y_new = _ring_mix(state["y"])
        y_new = jax.tree_util.tree_map(lambda y, a, b: y + a - b,
                                       y_new, g_new, state["g"])
        return {"x": x_new, "y": y_new, "g": g_new}, {}

    def sharded_local_update(self, state, xs, ys, r, key, ctx):
        """The gossip (ring mix) crosses client-shard boundaries, so it runs
        as a ppermute halo exchange; gradients are per-client with the global
        key split's shard slice. Bit-identical to ``local_update`` on the
        gathered stacks (same ``_mix_arith`` on the same neighbor values)."""
        x_new = _ring_mix_sharded(state["x"], ctx)
        x_new = jax.tree_util.tree_map(lambda x, y: x - self.lr * y,
                                       x_new, state["y"])
        g_new = self._grads_keyed(x_new, xs, ys, ctx.shard_keys(key))
        y_new = _ring_mix_sharded(state["y"], ctx)
        y_new = jax.tree_util.tree_map(lambda y, a, b: y + a - b,
                                       y_new, g_new, state["g"])
        return {"x": x_new, "y": y_new, "g": g_new}, {}

    def eval_params(self, state):
        return state["x"]


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.3,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          dp: bool = True, schedule=None):
    M, R = train_y.shape[:2]
    feat, classes = train_x.shape[-1], int(jnp.max(jnp.asarray(train_y))) + 1
    delta = delta or 1.0 / R
    schedule = schedule or FullParticipation()
    sigma = (dp_lib.noble_sigma(epsilon, delta, sample_rate=batch_size / R,
                                rounds=rounds) if dp else 0.0)
    # σ stays Eq. 12 (Noble); the ledger reports the RDP-accounted spend it
    # actually induces (amplified by the schedule's client fraction)
    ledger = (PrivacyLedger(sigma=sigma, delta=delta, sample_rate=batch_size / R,
                            client_rate=schedule.client_fraction(M))
              if dp else None)

    strategy = DPDSGTStrategy(feat_dim=feat, num_classes=classes, lr=lr,
                              clip=clip, sigma=sigma if dp else 0.0)
    data = FederatedData(train_x, train_y, test_x, test_y)
    state, hist = Engine(strategy, eval_every=eval_every, schedule=schedule,
                         ledger=ledger).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(seed),
        batch_size=batch_size)
    return state["x"], hist, sigma
