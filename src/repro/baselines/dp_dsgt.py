"""DP-DSGT (Bayrooti et al. [4]): differentially-private decentralized SGD
with gradient tracking over a ring topology — consensus-seeking (one shared
solution), which is exactly what the paper argues fails under non-IID tasks.

  x_i ← Σ_j W_ij x̃_j − lr · y_i
  y_i ← Σ_j W_ij ỹ_j + (g_i(x⁺) − g_i(x))

where x̃/ỹ are the DP-noised (clipped) shared quantities.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.config import DPConfig
from repro.core import dp as dp_lib
from repro.utils.pytree import global_norm


def _ring_mix(stacked, self_w: float = 0.5):
    """W = ring with self weight 1/2 and 1/4 to each neighbor."""
    def mix(t):
        left = jnp.roll(t, 1, axis=0)
        right = jnp.roll(t, -1, axis=0)
        return self_w * t + (1 - self_w) / 2 * (left + right)
    return jax.tree_util.tree_map(mix, stacked)


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.3,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          dp: bool = True):
    M, R = train_y.shape
    feat, classes = train_x.shape[-1], int(jnp.max(train_y)) + 1
    specs, apply_fn = common.make_model(feat, classes)
    delta = delta or 1.0 / R
    sigma = (dp_lib.noble_sigma(epsilon, delta, sample_rate=batch_size / R,
                                rounds=rounds) if dp else 0.0)
    loss = common.ce_loss(apply_fn)

    key = jax.random.PRNGKey(seed)
    x_params = common.init_clients(specs, key, M)
    sample = common.batch_sampler(train_x, train_y, batch_size, seed)

    def grads(params, xs, ys, k):
        def one(p, x, y, kk):
            return common.client_grad(apply_fn, p, x, y, kk,
                                      dp_cfg=DPConfig(clip_norm=clip), sigma=sigma if dp else 0.0)
        return jax.vmap(one)(params, xs, ys, jax.random.split(k, M))

    xs0, ys0 = sample()
    y_track = grads(x_params, jnp.asarray(xs0), jnp.asarray(ys0), key)
    g_prev = y_track

    @jax.jit
    def step(x_params, y_track, g_prev, xs, ys, k):
        x_new = _ring_mix(x_params)
        x_new = jax.tree_util.tree_map(lambda x, y: x - lr * y, x_new, y_track)
        g_new = grads(x_new, xs, ys, k)
        y_new = _ring_mix(y_track)
        y_new = jax.tree_util.tree_map(lambda y, a, b: y + a - b, y_new, g_new, g_prev)
        return x_new, y_new, g_new

    history = []
    for r in range(rounds):
        xs, ys = sample()
        x_params, y_track, g_prev = step(x_params, y_track, g_prev, xs, ys,
                                         jax.random.fold_in(key, r + 1))
        if r % eval_every == 0 or r == rounds - 1:
            acc = common.evaluate_clients(apply_fn, x_params, test_x, test_y)
            history.append((r, float(jnp.mean(acc))))
    return x_params, history, sigma

