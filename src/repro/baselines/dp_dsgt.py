"""DP-DSGT (Bayrooti et al. [4]): differentially-private decentralized SGD
with gradient tracking — consensus-seeking (one shared solution), which is
exactly what the paper argues fails under non-IID tasks.

  x_i ← Σ_j W_ij x̃_j − lr · y_i
  y_i ← Σ_j W_ij ỹ_j + (g_i(x⁺) − g_i(x))

where x̃/ỹ are the DP-noised (clipped) shared quantities and W is the
mixing matrix of the communication graph.

The paper's W is the ring with self weight 1/2 and 1/4 per neighbor; here W
comes from the topology subsystem (``repro.topology``), so DSGT runs over
any graph family — ring / torus / expander / Erdős–Rényi / time-varying
gossip — with in-jit link faults. ``topology=None`` builds the historical
ring at ``init``, and the compiled ring plan's mixing arithmetic is
bit-identical to the pre-refactor ``_ring_mix`` (the ring is literally the
special case of the general sparse mixing step — locked down in
``tests/test_topology.py``).

Engine form: state = {params, tracker, last gradients}; the tracker is
bootstrapped in ``init`` from a first on-device batch draw.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.config import DPConfig
from repro.core import dp as dp_lib
from repro.engine import (Engine, FederatedData, FullParticipation,
                          PrivacyLedger, Strategy, register_strategy,
                          runtime_sigma, sample_client_batches)


@register_strategy("dp_dsgt")
@dataclass(eq=False)
class DPDSGTStrategy(Strategy):
    feat_dim: int = 0
    num_classes: int = 2
    lr: float = 0.3
    clip: float = 1.0
    sigma: float = 0.0
    # communication graph (repro.topology.Topology / TimeVaryingTopology,
    # hashable by value → part of the chunk-cache fingerprint); None builds
    # the paper's ring over the run's M clients at init
    topology: Optional[object] = None

    def __post_init__(self):
        self.specs, self.apply_fn = common.make_model(self.feat_dim,
                                                      self.num_classes)

    # ------------------------------------------------------------- topology
    def _ensure_plan(self, M: int) -> None:
        from repro.topology.graphs import ring
        from repro.topology.mixing import make_plan
        if self.topology is None:
            self.topology = ring(M)          # the paper's default graph
        if self.topology.M != M:
            raise ValueError(
                f"topology is over {self.topology.M} clients but the run has "
                f"M={M}")
        if self._mix_plan is None or self._mix_plan.topology is not self.topology:
            self._mix_plan = make_plan(self.topology)

    # ------------------------------------------------------------ gradients
    def _grads_keyed(self, params, xs, ys, keys):
        def one(p, x, y, k):
            return common.client_grad(self.apply_fn, p, x, y, k,
                                      dp_cfg=DPConfig(clip_norm=self.clip),
                                      sigma=runtime_sigma(self.sigma))
        return jax.vmap(one)(params, xs, ys, keys)

    def _grads(self, params, xs, ys, key):
        M = ys.shape[0]
        return self._grads_keyed(params, xs, ys, jax.random.split(key, M))

    @property
    def _push_sum(self) -> bool:
        return bool(self._mix_plan is not None and self._mix_plan.push_sum)

    def align_push_sum_state(self, state):
        """Reconcile a carried state across a topology swap (the learned-
        graph drivers re-estimate between ``Engine.fit`` segments): entering
        a push-sum plan grows the (M,) weight-scalar leaf at 1 (the carried
        x is already unbiased), leaving one folds the bias back into x
        (x ← x/w) and drops the leaf."""
        from repro.topology.mixing import push_sum_debias
        if self._push_sum and "w" not in state:
            M = jax.tree_util.tree_leaves(state["x"])[0].shape[0]
            state = dict(state, w=jnp.ones((M,), jnp.float32))
        elif not self._push_sum and "w" in state:
            state = dict(state)
            state["x"] = push_sum_debias(state["x"], state.pop("w"))
        return state

    # ---------------------------------------------------------------- hooks
    def init(self, key, data: FederatedData, batch_size):
        self._ensure_plan(data.num_clients)
        k1, k2, k3 = jax.random.split(key, 3)
        x_params = common.init_clients(self.specs, k1, data.num_clients)
        xs0, ys0 = sample_client_batches(data.train_x, data.train_y, k2,
                                         batch_size)
        y_track = self._grads(x_params, xs0, ys0, k3)
        # distinct buffers: the engine donates the carry, and XLA rejects the
        # same buffer appearing twice in a donated argument
        state = {"x": x_params, "y": y_track,
                 "g": jax.tree_util.tree_map(jnp.copy, y_track)}
        return self.align_push_sum_state(state)

    def local_update(self, state, xs, ys, r, key):
        # one communication round = one realized graph: both mixes share the
        # round's fault realization (drawn in-jit off key's fault stream).
        # Under a push-sum plan (directed/learned W) the weight scalar rides
        # the x mix as a joint leaf (gradient-push): gradients are taken at
        # the de-biased z = x/w, and the tracker mixes with the same matrix.
        if self._push_sum:
            from repro.topology.mixing import push_sum_debias
            mixed = self.mix({"x": state["x"], "w": state["w"]}, r, key)
            x_new, w_new = mixed["x"], mixed["w"]
        else:
            x_new = self.mix(state["x"], r, key)
        x_new = jax.tree_util.tree_map(lambda x, y: x - self.lr * y,
                                       x_new, state["y"])
        z = push_sum_debias(x_new, w_new) if self._push_sum else x_new
        g_new = self._grads(z, xs, ys, key)
        y_new = self.mix(state["y"], r, key)
        y_new = jax.tree_util.tree_map(lambda y, a, b: y + a - b,
                                       y_new, g_new, state["g"])
        out = {"x": x_new, "y": y_new, "g": g_new}
        if self._push_sum:
            out["w"] = w_new
        return out, {}

    def sharded_local_update(self, state, xs, ys, r, key, ctx):
        """The gossip crosses client-shard boundaries, so it runs as a
        ppermute halo exchange of just the boundary rows (bounded-bandwidth
        graphs), a slice-local gather (shard-resident edges) or a gather
        round-trip (anything else); gradients are per-client with the global
        key split's shard slice. Same mixing arithmetic on the same neighbor
        values as ``local_update`` — see ``repro.topology.mixing``. When the
        engine carried prefetched halos (``sharded_prefetch``), both mixes
        consume boundary rows whose ppermute was issued at the end of the
        previous round, overlapping the transfer with that round's compute —
        valid because both mixes read the round-START x and y, which is
        exactly what was prefetched."""
        from repro.engine.strategy import current_halos
        halos = current_halos()
        if self._push_sum:
            from repro.topology.mixing import push_sum_debias
            mixed = self.mix_sharded(
                {"x": state["x"], "w": state["w"]}, r, key, ctx,
                halo=None if halos is None else halos["xw"])
            x_new, w_new = mixed["x"], mixed["w"]
        else:
            x_new = self.mix_sharded(
                state["x"], r, key, ctx,
                halo=None if halos is None else halos["x"])
        x_new = jax.tree_util.tree_map(lambda x, y: x - self.lr * y,
                                       x_new, state["y"])
        z = push_sum_debias(x_new, w_new) if self._push_sum else x_new
        g_new = self._grads_keyed(z, xs, ys, ctx.shard_keys(key))
        y_new = self.mix_sharded(state["y"], r, key, ctx,
                                 halo=None if halos is None else halos["y"])
        y_new = jax.tree_util.tree_map(lambda y, a, b: y + a - b,
                                       y_new, g_new, state["g"])
        out = {"x": x_new, "y": y_new, "g": g_new}
        if self._push_sum:
            out["w"] = w_new
        return out, {}

    def paged_local_update(self, state, xs, ys, r, key, pctx):
        """Cohort-paged gossip round: the same call sequence as
        ``local_update`` with the mixes resolving neighbor reads through the
        cohort slot map (the planner paged in every participant's
        in-neighbors) and gradients keyed by the global key split's cohort
        slice — participant rows are bit-identical to the resident step."""
        if self._push_sum:
            from repro.topology.mixing import push_sum_debias
            mixed = self.mix_paged({"x": state["x"], "w": state["w"]}, r,
                                   key, pctx)
            x_new, w_new = mixed["x"], mixed["w"]
        else:
            x_new = self.mix_paged(state["x"], r, key, pctx)
        x_new = jax.tree_util.tree_map(lambda x, y: x - self.lr * y,
                                       x_new, state["y"])
        z = push_sum_debias(x_new, w_new) if self._push_sum else x_new
        g_new = self._grads_keyed(z, xs, ys, pctx.cohort_keys(key))
        y_new = self.mix_paged(state["y"], r, key, pctx)
        y_new = jax.tree_util.tree_map(lambda y, a, b: y + a - b,
                                       y_new, g_new, state["g"])
        out = {"x": x_new, "y": y_new, "g": g_new}
        if self._push_sum:
            out["w"] = w_new
        return out, {}

    def sharded_prefetch(self, state, ctx):
        """Issue the next round's boundary-row ppermutes from the end-of-
        round state (x and y are mixed at round start, so the rows a shard
        will need are known as soon as the round's update lands). Only the
        halo path prefetches — local/gather/identity paths have nothing to
        overlap. Under push-sum the x halo carries the weight scalar too
        (``sharded_local_update`` mixes them jointly)."""
        from repro.topology.mixing import select_mix_path, halo_start
        if self._mix_plan is None:
            return None
        if select_mix_path(self._mix_plan, ctx) != "halo":
            return None
        if self._push_sum:
            return {"xw": halo_start({"x": state["x"], "w": state["w"]},
                                     self._mix_plan, ctx),
                    "y": halo_start(state["y"], self._mix_plan, ctx)}
        return {"x": halo_start(state["x"], self._mix_plan, ctx),
                "y": halo_start(state["y"], self._mix_plan, ctx)}

    def eval_params(self, state):
        if "w" in state:
            from repro.topology.mixing import push_sum_debias
            return push_sum_debias(state["x"], state["w"])
        return state["x"]

    # ------------------------------------------------------ byte accounting
    def log_communication(self, net, state, r: int, mask=None,
                          phase_key=None, faults=None) -> None:
        """§4.5-style gossip accounting: every alive directed edge carries
        the sender's BOTH shared quantities — the noised model x̃ and the
        gradient tracker ỹ (one exchange per round mixes both, see
        ``local_update``). Absent cohort members (sampling schedule) and
        dropped links / churned nodes (the round's fault realization,
        re-derived from ``phase_key``) contribute zero bytes. Under a
        correlated fault process (``faults`` — the engine's replayed
        ``HostFaults``) the realized keep matrix supersedes the topology's
        i.i.d. draw, mirroring the traced mix."""
        if self._mix_plan is None or self.topology is None:
            return
        keep = None
        if faults is not None:
            keep = faults.keep
        elif self._mix_plan.faulty and phase_key is not None:
            from repro.topology.faults import host_fault_masks
            keep, _ = host_fault_masks(phase_key, r, 1, self._mix_plan.M,
                                       self._mix_plan.drop_prob,
                                       self._mix_plan.churn_prob)
        from repro.topology.accounting import log_gossip_round
        log_gossip_round(net, self.topology,
                         {"x": state["x"], "y": state["y"]}, r, mask=mask,
                         keep=keep)


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.3,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          dp: bool = True, schedule=None, topology=None, network=None):
    M, R = train_y.shape[:2]
    feat, classes = train_x.shape[-1], int(jnp.max(jnp.asarray(train_y))) + 1
    delta = delta or 1.0 / R
    schedule = schedule or FullParticipation()
    sigma = (dp_lib.noble_sigma(epsilon, delta, sample_rate=batch_size / R,
                                rounds=rounds) if dp else 0.0)
    # σ stays Eq. 12 (Noble); the ledger reports the RDP-accounted spend it
    # actually induces (amplified by the schedule's client fraction)
    ledger = (PrivacyLedger(sigma=sigma, delta=delta, sample_rate=batch_size / R,
                            client_rate=schedule.client_fraction(M))
              if dp else None)

    strategy = DPDSGTStrategy(feat_dim=feat, num_classes=classes, lr=lr,
                              clip=clip, sigma=sigma if dp else 0.0,
                              topology=topology)
    data = FederatedData(train_x, train_y, test_x, test_y)
    state, hist = Engine(strategy, eval_every=eval_every, schedule=schedule,
                         ledger=ledger, network=network).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(seed),
        batch_size=batch_size)
    return state["x"], hist, sigma
