"""DP-SCAFFOLD (Noble et al. [40]): FedAvg + control variates correcting
client drift under heterogeneity; DP noise on the clipped per-example
gradients, RDP-accounted toward the honest-but-curious server."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.config import DPConfig
from repro.core import dp as dp_lib


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          local_steps: int = 2, dp: bool = True):
    M, R = train_y.shape
    feat, classes = train_x.shape[-1], int(jnp.max(train_y)) + 1
    specs, apply_fn = common.make_model(feat, classes)
    delta = delta or 1.0 / R
    q = batch_size / R
    sigma = dp_lib.calibrate_sigma(epsilon, delta, q, rounds * local_steps) if dp else 0.0

    gp = jax.tree_util.tree_map(
        lambda t: t[0], common.init_clients(specs, jax.random.PRNGKey(seed), 1))
    zeros = jax.tree_util.tree_map(jnp.zeros_like, gp)
    c_global = zeros
    c_clients = common.broadcast_like(zeros, M)
    sample = common.batch_sampler(train_x, train_y, batch_size, seed)

    @jax.jit
    def round_step(gp, c_global, c_clients, xs, ys, key):
        params0 = common.broadcast_like(gp, M)

        def one(p0, ci, x, y, k):
            def body(pp, i):
                g = common.client_grad(apply_fn, pp, x, y, jax.random.fold_in(k, i),
                                       dp_cfg=DPConfig(clip_norm=clip), sigma=sigma)
                # SCAFFOLD drift correction: g - c_i + c
                corr = jax.tree_util.tree_map(lambda gg, cc, cg: gg - cc + cg,
                                              g, ci, c_global)
                return common.sgd_update(pp, corr, lr), None
            pK, _ = jax.lax.scan(body, p0, jnp.arange(local_steps))
            # option II control-variate update
            new_ci = jax.tree_util.tree_map(
                lambda cc, cg, a, b: cc - cg + (a - b) / (local_steps * lr),
                ci, c_global, p0, pK)
            return pK, new_ci

        newp, newc = jax.vmap(one)(params0, c_clients, xs, ys,
                                   jax.random.split(key, M))
        gp_new = common.tree_mean(newp)
        c_new = common.tree_mean(newc)
        return gp_new, c_new, newc

    history = []
    key = jax.random.PRNGKey(seed + 1)
    for r in range(rounds):
        xs, ys = sample()
        gp, c_global, c_clients = round_step(gp, c_global, c_clients, xs, ys,
                                             jax.random.fold_in(key, r))
        if r % eval_every == 0 or r == rounds - 1:
            params = common.broadcast_like(gp, M)
            acc = common.evaluate_clients(apply_fn, params, test_x, test_y)
            history.append((r, float(jnp.mean(acc))))
    return gp, history, sigma

