"""DP-SCAFFOLD (Noble et al. [40]): FedAvg + control variates correcting
client drift under heterogeneity; DP noise on the clipped per-example
gradients, RDP-accounted toward the honest-but-curious server.

Engine form: state carries the global model plus the global/per-client
control variates; ``local_update`` runs the drift-corrected DP local steps
and the option-II control-variate update, ``aggregate`` means both back.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.config import DPConfig
from repro.core import dp as dp_lib
from repro.engine import (Engine, FederatedData, FullParticipation,
                          PrivacyLedger, Strategy, register_strategy,
                          runtime_sigma)


@register_strategy("scaffold")
@dataclass(eq=False)
class ScaffoldStrategy(Strategy):
    feat_dim: int = 0
    num_classes: int = 2
    lr: float = 0.5          # matches the module train() default
    clip: float = 1.0
    sigma: float = 0.0
    local_steps: int = 2

    def __post_init__(self):
        self.specs, self.apply_fn = common.make_model(self.feat_dim,
                                                      self.num_classes)

    def init(self, key, data: FederatedData, batch_size):
        gp = jax.tree_util.tree_map(
            lambda t: t[0], common.init_clients(self.specs, key, 1))
        zeros = jax.tree_util.tree_map(jnp.zeros_like, gp)
        return {"global": gp, "c_global": zeros,
                "c_clients": common.broadcast_like(zeros, data.num_clients)}

    def local_update_keyed(self, state, xs, ys, r, keys):
        M = ys.shape[0]
        params0 = common.broadcast_like(state["global"], M)
        c_global = state["c_global"]

        def one(p0, ci, x, y, k):
            def body(pp, i):
                g = common.client_grad(
                    self.apply_fn, pp, x, y, jax.random.fold_in(k, i),
                    dp_cfg=DPConfig(clip_norm=self.clip),
                    sigma=runtime_sigma(self.sigma))
                # SCAFFOLD drift correction: g - c_i + c
                corr = jax.tree_util.tree_map(lambda gg, cc, cg: gg - cc + cg,
                                              g, ci, c_global)
                return common.sgd_update(pp, corr, self.lr), None
            pK, _ = jax.lax.scan(body, p0, jnp.arange(self.local_steps))
            # option II control-variate update
            new_ci = jax.tree_util.tree_map(
                lambda cc, cg, a, b: cc - cg + (a - b) / (self.local_steps * self.lr),
                ci, c_global, p0, pK)
            return pK, new_ci

        newp, newc = jax.vmap(one)(params0, state["c_clients"], xs, ys, keys)
        return {"clients": newp, "c_clients": newc,
                "c_global": c_global}, {}

    def local_update(self, state, xs, ys, r, key):
        M = ys.shape[0]
        return self.local_update_keyed(state, xs, ys, r,
                                       jax.random.split(key, M))

    def aggregate(self, mid, r, key):
        return {"global": common.tree_mean(mid["clients"]),
                "c_global": common.tree_mean(mid["c_clients"]),
                "c_clients": mid["c_clients"]}

    # ------------------------------------------------------- sharded engine
    # The carry mixes a client-stacked leaf (c_clients) with replicated
    # server leaves (global, c_global): state_client_stacked stays True and
    # the exact-size spec match shards only the (M, ...) leaf. The mid-round
    # tree swaps "global" for the trained "clients" stack, so the default
    # gather round-trip cannot be reused — these hooks gather the two
    # stacked subtrees explicitly and run the single-device means verbatim
    # (bit-exact), keeping c_clients shard-resident throughout.

    def sharded_aggregate(self, mid, r, key, ctx):
        return {"global": common.tree_mean(ctx.gather(mid["clients"])),
                "c_global": common.tree_mean(ctx.gather(mid["c_clients"])),
                "c_clients": mid["c_clients"]}

    def merge_participation(self, prev_state, new_state, mask):
        """Absent clients keep their control variate; the global quantities
        are cohort-weighted in ``aggregate_masked``."""
        sel = lambda o, n: jnp.where(
            mask.reshape((-1,) + (1,) * (n.ndim - 1)) > 0, n, o)
        out = dict(new_state)
        out["c_clients"] = jax.tree_util.tree_map(
            sel, prev_state["c_clients"], new_state["c_clients"])
        return out

    def aggregate_masked(self, mid, r, key, mask):
        w = mask / jnp.maximum(jnp.sum(mask), 1.0)
        wmean = lambda stacked: jax.tree_util.tree_map(
            lambda t: jnp.einsum("m...,m->...", t, w), stacked)
        return {"global": wmean(mid["clients"]),
                "c_global": wmean(mid["c_clients"]),
                "c_clients": mid["c_clients"]}

    def sharded_aggregate_masked(self, mid, r, key, ctx, mask, local_mask):
        w = mask / jnp.maximum(jnp.sum(mask), 1.0)
        wmean = lambda stacked: jax.tree_util.tree_map(
            lambda t: jnp.einsum("m...,m->...", t, w), stacked)
        return {"global": wmean(ctx.gather(mid["clients"])),
                "c_global": wmean(ctx.gather(mid["c_clients"])),
                "c_clients": mid["c_clients"]}

    def eval_params(self, state):
        return state["global"]

    def evaluate(self, state, test_x, test_y):
        params = common.broadcast_like(state["global"], test_y.shape[0])
        return common.evaluate_clients(self.apply_fn, params, test_x, test_y)


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          local_steps: int = 2, dp: bool = True, schedule=None):
    M, R = train_y.shape[:2]
    feat, classes = train_x.shape[-1], int(jnp.max(jnp.asarray(train_y))) + 1
    delta = delta or 1.0 / R
    schedule = schedule or FullParticipation()
    q = batch_size / R
    q_eff = q * schedule.client_fraction(M)
    sigma = (dp_lib.calibrate_sigma(epsilon, delta, q_eff, rounds * local_steps)
             if dp else 0.0)
    ledger = (PrivacyLedger(sigma=sigma, delta=delta, sample_rate=q,
                            client_rate=schedule.client_fraction(M),
                            local_steps=local_steps) if dp else None)

    strategy = ScaffoldStrategy(feat_dim=feat, num_classes=classes, lr=lr,
                                clip=clip, sigma=sigma, local_steps=local_steps)
    data = FederatedData(train_x, train_y, test_x, test_y)
    state, hist = Engine(strategy, eval_every=eval_every, schedule=schedule,
                         ledger=ledger).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(seed),
        batch_size=batch_size)
    return state["global"], hist, sigma
