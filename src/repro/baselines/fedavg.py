"""DP-FedAvg (McMahan et al. [35] + record-level DP toward an honest-but-
curious server). Noise is RDP-accounted for the subsampled Gaussian over T
rounds with user sampling ratio u (paper §4.2.1 / Noble et al.).

Engine form: state is the single global model; ``local_update`` broadcasts it
to M clients and runs K DP local steps, ``aggregate`` draws the user cohort
mask on device and takes the cohort-weighted mean back to a global model.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.config import DPConfig
from repro.core import dp as dp_lib
from repro.engine import (Engine, FederatedData, FullParticipation,
                          PrivacyLedger, Strategy, register_strategy,
                          runtime_sigma)


@register_strategy("fedavg")
@dataclass(eq=False)
class FedAvgStrategy(Strategy):
    feat_dim: int = 0
    num_classes: int = 2
    lr: float = 0.5
    clip: float = 1.0
    sigma: float = 0.0
    local_steps: int = 1
    user_ratio: float = 1.0
    # sharded cohort reduction: "psum" = per-shard partial weighted sums
    # tree-reduced with one lax.psum (no (M, ...) stack ever materializes on
    # a single slice — bit-close to the gather path, verified in
    # tests/test_sharded_engine.py); "gather" = all_gather → single-device
    # aggregate verbatim (bit-exact but O(M) memory per slice)
    reduce: str = "psum"

    def __post_init__(self):
        self.specs, self.apply_fn = common.make_model(self.feat_dim,
                                                      self.num_classes)

    def init(self, key, data: FederatedData, batch_size):
        return jax.tree_util.tree_map(
            lambda t: t[0], common.init_clients(self.specs, key, 1))

    def local_update_keyed(self, gp, xs, ys, r, keys):
        params = common.broadcast_like(gp, ys.shape[0])

        def one(p, x, y, k):
            def body(pp, i):
                g = common.client_grad(
                    self.apply_fn, pp, x, y, jax.random.fold_in(k, i),
                    dp_cfg=DPConfig(clip_norm=self.clip),
                    sigma=runtime_sigma(self.sigma))
                return common.sgd_update(pp, g, self.lr), None
            p2, _ = jax.lax.scan(body, p, jnp.arange(self.local_steps))
            return p2

        return jax.vmap(one)(params, xs, ys, keys), {}

    def local_update(self, gp, xs, ys, r, key):
        M = ys.shape[0]
        return self.local_update_keyed(gp, xs, ys, r,
                                       jax.random.split(key, M))

    def state_client_stacked(self, state) -> bool:
        # server-style carry: ONE global model, replicated across the client
        # mesh; only the mid-round (M, ...) local-update stacks are sharded
        return False

    def _user_mask(self, key, M):
        """Strategy-level user sampling draw — shared by the single-device
        aggregate and the psum path so both realize the identical cohort.
        The empty draw falls back to one random participant so the global
        model is always defined."""
        k1, k2 = jax.random.split(key)
        mask = (jax.random.uniform(k1, (M,)) < self.user_ratio).astype(jnp.float32)
        fallback = jnp.zeros((M,)).at[jax.random.randint(k2, (), 0, M)].set(1.0)
        return jnp.where(jnp.sum(mask) > 0, mask, fallback)

    def aggregate(self, clients, r, key):
        """Strategy-level user sampling (the pre-schedule path; NOT
        amplification-accounted — prefer an engine ClientSampling schedule
        for that)."""
        M = jax.tree_util.tree_leaves(clients)[0].shape[0]
        return self.aggregate_masked(clients, r, key, self._user_mask(key, M))

    def merge_participation(self, prev_state, new_state, mask):
        # server-style state: the cohort is applied as aggregation weights,
        # nothing to freeze per client
        return new_state

    def aggregate_masked(self, clients, r, key, mask):
        """Engine-drawn cohort replaces the strategy's own user sampling:
        the global model is the cohort-weighted mean (the schedule guarantees
        a non-empty cohort)."""
        w = mask / jnp.maximum(jnp.sum(mask), 1.0)
        return jax.tree_util.tree_map(
            lambda n: jnp.einsum("m...,m->...", n, w), clients)

    # ------------------------------------------------------- sharded engine
    def _psum_mean(self, clients, w_full, ctx):
        """Cohort mean as a psum tree-reduction: every shard contracts its
        own client rows against its slice of the full (M,) weight vector
        (padded slots carry weight 0), then one lax.psum combines the
        partials. The (M, ...) stack never materializes on a slice — the
        reduction is O(model) per shard instead of the gather's O(M·model)."""
        local_w = ctx.shard_rows(w_full)
        partial = jax.tree_util.tree_map(
            lambda t: jnp.einsum("m...,m->...", t, local_w), clients)
        return jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t, ctx.axis), partial)

    def sharded_aggregate(self, clients, r, key, ctx):
        if self.reduce == "gather":
            full = ctx.gather(clients)
            return ctx.scatter_like(self.aggregate(full, r, key), full)
        # identical (replicated) user-sampling draw to the single-device path
        mask = self._user_mask(key, ctx.M)
        return self._psum_mean(clients, mask / jnp.maximum(jnp.sum(mask), 1.0),
                               ctx)

    def sharded_aggregate_masked(self, clients, r, key, ctx, mask, local_mask):
        if self.reduce == "gather":
            full = ctx.gather(clients)
            return ctx.scatter_like(self.aggregate_masked(full, r, key, mask),
                                    full)
        return self._psum_mean(clients, mask / jnp.maximum(jnp.sum(mask), 1.0),
                               ctx)

    def eval_params(self, state):
        return state  # unused: evaluate() broadcasts

    def evaluate(self, state, test_x, test_y):
        params = common.broadcast_like(state, test_y.shape[0])
        return common.evaluate_clients(self.apply_fn, params, test_x, test_y)


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          user_ratio: float = 1.0, local_steps: int = 1, dp: bool = True,
          schedule=None):
    """``schedule`` (a RoundSchedule) moves user sampling into the engine;
    σ is then RDP-calibrated at the amplified rate q_batch · q_client, and the
    returned ``History.metrics`` carries the cumulative (ε, δ) per eval round."""
    M, R = train_y.shape[:2]
    feat, classes = train_x.shape[-1], int(jnp.max(jnp.asarray(train_y))) + 1
    delta = delta or 1.0 / R
    schedule = schedule or FullParticipation()
    q = batch_size / R
    q_eff = q * schedule.client_fraction(M)
    sigma = (dp_lib.calibrate_sigma(epsilon, delta, q_eff, rounds * local_steps)
             if dp else 0.0)
    ledger = (PrivacyLedger(sigma=sigma, delta=delta, sample_rate=q,
                            client_rate=schedule.client_fraction(M),
                            local_steps=local_steps) if dp else None)

    strategy = FedAvgStrategy(feat_dim=feat, num_classes=classes, lr=lr,
                              clip=clip, sigma=sigma, local_steps=local_steps,
                              user_ratio=user_ratio)
    data = FederatedData(train_x, train_y, test_x, test_y)
    state, hist = Engine(strategy, eval_every=eval_every, schedule=schedule,
                         ledger=ledger).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(seed),
        batch_size=batch_size)
    return state, hist, sigma
