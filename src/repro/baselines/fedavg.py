"""DP-FedAvg (McMahan et al. [35] + record-level DP toward an honest-but-
curious server). Noise is RDP-accounted for the subsampled Gaussian over T
rounds with user sampling ratio u (paper §4.2.1 / Noble et al.).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import common
from repro.config import DPConfig
from repro.core import dp as dp_lib


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          user_ratio: float = 1.0, local_steps: int = 1, dp: bool = True):
    M, R = train_y.shape
    feat, classes = train_x.shape[-1], int(jnp.max(train_y)) + 1
    specs, apply_fn = common.make_model(feat, classes)
    delta = delta or 1.0 / R
    q = batch_size / R
    sigma = dp_lib.calibrate_sigma(epsilon, delta, q, rounds * local_steps) if dp else 0.0

    global_params = jax.tree_util.tree_map(
        lambda t: t[0], common.init_clients(specs, jax.random.PRNGKey(seed), 1))
    sample = common.batch_sampler(train_x, train_y, batch_size, seed)
    rng = np.random.default_rng(seed + 7)

    @jax.jit
    def round_step(gp, xs, ys, key, mask):
        params = common.broadcast_like(gp, M)

        def one(p, x, y, k):
            def body(pp, i):
                g = common.client_grad(
                    apply_fn, pp, x, y, jax.random.fold_in(k, i),
                    dp_cfg=DPConfig(clip_norm=clip), sigma=sigma)
                return common.sgd_update(pp, g, lr), None
            p2, _ = jax.lax.scan(body, p, jnp.arange(local_steps))
            return p2

        new = jax.vmap(one)(params, xs, ys, jax.random.split(key, M))
        # server average over the sampled user cohort
        w = mask / jnp.maximum(jnp.sum(mask), 1.0)
        avg = jax.tree_util.tree_map(
            lambda n: jnp.einsum("m...,m->...", n, w), new)
        return avg

    history = []
    key = jax.random.PRNGKey(seed + 1)
    for r in range(rounds):
        xs, ys = sample()
        mask = (rng.random(M) < user_ratio).astype(np.float32)
        if mask.sum() == 0:
            mask[rng.integers(M)] = 1.0
        global_params = round_step(global_params, xs, ys,
                                   jax.random.fold_in(key, r), jnp.asarray(mask))
        if r % eval_every == 0 or r == rounds - 1:
            params = common.broadcast_like(global_params, M)
            acc = common.evaluate_clients(apply_fn, params, test_x, test_y)
            history.append((r, float(jnp.mean(acc))))
    return global_params, history, sigma

