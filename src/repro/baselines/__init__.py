"""The paper's comparison methods (§4.2.1), all in JAX on the same substrate
and all run by the unified federation engine (``repro.engine``) — each module
defines a registered Strategy plus a thin legacy-signature ``train`` wrapper:

  local        — per-client training, no communication (strong non-IID baseline)
  centralized  — pooled-data upper reference (with/without HC features)
  fedavg       — DP-FedAvg (server honest-but-curious, RDP-accounted noise)
  scaffold     — DP-SCAFFOLD (Noble et al. 2022): control variates + DP
  proxyfl      — Kalra et al. 2023: proxy sharing over a directed exponential graph
  dp_dsgt      — Bayrooti et al. 2023: DP decentralized SGD with gradient tracking
"""
from repro.baselines.common import evaluate_clients, sgd_update
from repro.baselines import local, centralized, fedavg, scaffold, proxyfl, dp_dsgt
