"""ProxyFL (Kalra et al. [25]): each client keeps a private model and a proxy
model trained by deep mutual learning; proxies circulate over a DIRECTED
EXPONENTIAL graph (at round t, client i sends to (i + 2^(t mod ⌈log2 M⌉)) mod
M) with DP-SGD on the proxy. The paper's closest decentralized competitor —
no similarity grouping, no handcrafted-feature requirement.

Engine form: the exponential-graph shift is computed from the traced round
index, so the whole exchange schedule lives inside the scanned round body.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.core import distill, dp as dp_lib
from repro.engine import (Engine, FederatedData, FullParticipation,
                          PrivacyLedger, Strategy, register_strategy,
                          runtime_sigma)


@register_strategy("proxyfl")
@dataclass(eq=False)
class ProxyFLStrategy(Strategy):
    feat_dim: int = 0
    num_classes: int = 2
    lr: float = 0.5
    clip: float = 1.0
    sigma: float = 0.0
    alpha: float = 0.5
    beta: float = 0.5

    def __post_init__(self):
        self.specs, self.apply_fn = common.make_model(self.feat_dim,
                                                      self.num_classes)

    def init(self, key, data: FederatedData, batch_size):
        M = data.num_clients
        return {"private": common.init_clients(self.specs, key, M),
                "proxy": common.init_clients(self.specs,
                                             jax.random.fold_in(key, 1), M)}

    def local_update_keyed(self, state, xs, ys, r, keys):
        apply_fn = self.apply_fn

        def one(theta, w, x, y, k):
            w_logits = apply_fn(w, x)

            def private_obj(p):
                return distill.private_loss(apply_fn(p, x), w_logits, y, self.beta)
            g_t = jax.grad(private_obj)(theta)

            def proxy_obj(p, b):
                tgt = apply_fn(jax.lax.stop_gradient(theta), b["x"])
                return distill.proxy_loss(apply_fn(p, b["x"]), tgt, b["y"],
                                          self.alpha)
            if self.sigma > 0:
                g_w = dp_lib.dp_gradients(proxy_obj, w, {"x": x, "y": y}, k,
                                          clip=self.clip,
                                          sigma=runtime_sigma(self.sigma))
            else:
                g_w = jax.grad(lambda p: proxy_obj(p, {"x": x, "y": y}))(w)
            return (common.sgd_update(theta, g_t, self.lr),
                    common.sgd_update(w, g_w, self.lr))

        private, proxy = jax.vmap(one)(state["private"], state["proxy"], xs, ys,
                                       keys)
        return {"private": private, "proxy": proxy}, {}

    def local_update(self, state, xs, ys, r, key):
        M = ys.shape[0]
        return self.local_update_keyed(state, xs, ys, r,
                                       jax.random.split(key, M))

    def aggregate(self, state, r, key):
        """Receive neighbor's proxy (directed exponential graph), average.

        Under the sharded engine the default ``Strategy.sharded_aggregate``
        gathers the full (M, ...) stacks and runs this verbatim — the
        exponential-graph shift crosses shard boundaries, and the gather
        keeps the modulus at the TRUE client count (inside the shard region
        the local leading dim would be m, silently shrinking the graph)."""
        # M is a static shape, so log2m is a trace-time constant — derived
        # here (not in init) so engine-resumed external states work too
        M = jax.tree_util.tree_leaves(state["proxy"])[0].shape[0]
        log2m = max(1, math.ceil(math.log2(M)))
        shift = jnp.left_shift(1, jnp.mod(r, log2m))
        received = jax.tree_util.tree_map(
            lambda t: jnp.roll(t, shift, axis=0), state["proxy"])
        proxy = jax.tree_util.tree_map(lambda a, b: 0.5 * (a + b),
                                       state["proxy"], received)
        return {"private": state["private"], "proxy": proxy}

    def eval_params(self, state):
        return state["private"]


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          alpha: float = 0.5, beta: float = 0.5, dp: bool = True,
          schedule=None):
    M, R = train_y.shape[:2]
    feat, classes = train_x.shape[-1], int(jnp.max(jnp.asarray(train_y))) + 1
    delta = delta or 1.0 / R
    schedule = schedule or FullParticipation()
    sigma = (dp_lib.noble_sigma(epsilon, delta, sample_rate=batch_size / R,
                                rounds=rounds, local_steps=1) if dp else 0.0)
    ledger = (PrivacyLedger(sigma=sigma, delta=delta, sample_rate=batch_size / R,
                            client_rate=schedule.client_fraction(M))
              if dp else None)

    strategy = ProxyFLStrategy(feat_dim=feat, num_classes=classes, lr=lr,
                               clip=clip, sigma=sigma, alpha=alpha, beta=beta)
    data = FederatedData(train_x, train_y, test_x, test_y)
    state, hist = Engine(strategy, eval_every=eval_every, schedule=schedule,
                         ledger=ledger).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(seed),
        batch_size=batch_size)
    return state["private"], hist, sigma
