"""ProxyFL (Kalra et al. [25]): each client keeps a private model and a proxy
model trained by deep mutual learning; proxies circulate over a DIRECTED
EXPONENTIAL graph (at round t, client i sends to (i + 2^(t mod ⌈log2 M⌉)) mod
M) with DP-SGD on the proxy. The paper's closest decentralized competitor —
no similarity grouping, no handcrafted-feature requirement."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.core import distill, dp as dp_lib


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          epsilon: float = 15.0, delta: float = None, clip: float = 1.0,
          alpha: float = 0.5, beta: float = 0.5, dp: bool = True):
    M, R = train_y.shape
    feat, classes = train_x.shape[-1], int(jnp.max(train_y)) + 1
    specs, apply_fn = common.make_model(feat, classes)
    delta = delta or 1.0 / R
    sigma = (dp_lib.noble_sigma(epsilon, delta, sample_rate=batch_size / R,
                                rounds=rounds, local_steps=1) if dp else 0.0)

    key = jax.random.PRNGKey(seed)
    private = common.init_clients(specs, key, M)
    proxy = common.init_clients(specs, jax.random.fold_in(key, 1), M)
    sample = common.batch_sampler(train_x, train_y, batch_size, seed)
    log2m = max(1, math.ceil(math.log2(M)))

    @jax.jit
    def local_step(private, proxy, xs, ys, key):
        def one(theta, w, x, y, k):
            t_logits = apply_fn(theta, x)
            w_logits = apply_fn(w, x)

            def private_obj(p):
                return distill.private_loss(apply_fn(p, x), w_logits, y, beta)
            g_t = jax.grad(private_obj)(theta)

            def proxy_obj(p, b):
                tgt = apply_fn(jax.lax.stop_gradient(theta), b["x"])
                return distill.proxy_loss(apply_fn(p, b["x"]), tgt, b["y"], alpha)
            if dp and sigma > 0:
                g_w = dp_lib.dp_gradients(proxy_obj, w, {"x": x, "y": y}, k,
                                          clip=clip, sigma=sigma)
            else:
                g_w = jax.grad(lambda p: proxy_obj(p, {"x": x, "y": y}))(w)
            return (common.sgd_update(theta, g_t, lr),
                    common.sgd_update(w, g_w, lr))
        return jax.vmap(one)(private, proxy, xs, ys, jax.random.split(key, M))

    @jax.jit
    def exchange(proxy, shift):
        """Receive neighbor's proxy (directed exponential graph), average."""
        received = jax.tree_util.tree_map(lambda t: jnp.roll(t, shift, axis=0), proxy)
        return jax.tree_util.tree_map(lambda a, b: 0.5 * (a + b), proxy, received)

    history = []
    for r in range(rounds):
        xs, ys = sample()
        private, proxy = local_step(private, proxy, xs, ys, jax.random.fold_in(key, r + 2))
        proxy = exchange(proxy, 2 ** (r % log2m))
        if r % eval_every == 0 or r == rounds - 1:
            acc = common.evaluate_clients(apply_fn, private, test_x, test_y)
            history.append((r, float(jnp.mean(acc))))
    return private, history, sigma
