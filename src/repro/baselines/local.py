"""Local training baseline: each client trains alone (paper §4.2.1).

The paper runs it WITHOUT DP (local data never leaves the device, so no noise
is needed) — the relevant comparison for Fig. 7.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.baselines import common
from repro.engine import (Engine, FederatedData, Strategy, register_strategy,
                          runtime_sigma)


@register_strategy("local")
@dataclass(eq=False)
class LocalStrategy(Strategy):
    feat_dim: int = 0
    num_classes: int = 2
    lr: float = 0.5
    dp_cfg: Optional[object] = None
    sigma: float = 0.0
    kernels: Optional[object] = None

    def __post_init__(self):
        self.specs, self.apply_fn = common.make_model(self.feat_dim,
                                                      self.num_classes)

    def init(self, key, data: FederatedData, batch_size):
        return common.init_clients(self.specs, key, data.num_clients)

    def local_update_keyed(self, params, xs, ys, r, keys):
        def one(p, x, y, k):
            g = common.client_grad(self.apply_fn, p, x, y, k,
                                   dp_cfg=self.dp_cfg,
                                   sigma=runtime_sigma(self.sigma),
                                   kernels=self.kernels)
            return common.sgd_update(p, g, self.lr)
        return jax.vmap(one)(params, xs, ys, keys), {}

    def local_update(self, params, xs, ys, r, key):
        M = ys.shape[0]
        return self.local_update_keyed(params, xs, ys, r,
                                       jax.random.split(key, M))

    def eval_params(self, state):
        return state


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          dp_cfg=None, sigma: float = 0.0, schedule=None):
    feat, classes = train_x.shape[-1], int(jnp.max(jnp.asarray(train_y))) + 1
    strategy = LocalStrategy(feat_dim=feat, num_classes=classes, lr=lr,
                             dp_cfg=dp_cfg, sigma=sigma)
    data = FederatedData(train_x, train_y, test_x, test_y)
    state, hist = Engine(strategy, eval_every=eval_every,
                         schedule=schedule).fit(
        data, rounds=rounds, key=jax.random.PRNGKey(seed),
        batch_size=batch_size)
    return state, hist
