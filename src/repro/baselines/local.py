"""Local training baseline: each client trains alone (paper §4.2.1).

The paper runs it WITHOUT DP (local data never leaves the device, so no noise
is needed) — the relevant comparison for Fig. 7.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines import common


def train(train_x, train_y, test_x, test_y, *, rounds: int = 100, lr: float = 0.5,
          batch_size: int = 32, seed: int = 0, eval_every: int = 20,
          dp_cfg=None, sigma: float = 0.0):
    M = train_y.shape[0]
    feat, classes = train_x.shape[-1], int(jnp.max(train_y)) + 1
    specs, apply_fn = common.make_model(feat, classes)
    params = common.init_clients(specs, jax.random.PRNGKey(seed), M)
    sample = common.batch_sampler(train_x, train_y, batch_size, seed)

    @jax.jit
    def step(params, xs, ys, key):
        def one(p, x, y, k):
            g = common.client_grad(apply_fn, p, x, y, k, dp_cfg=dp_cfg, sigma=sigma)
            return common.sgd_update(p, g, lr)
        return jax.vmap(one)(params, xs, ys, jax.random.split(key, M))

    history = []
    key = jax.random.PRNGKey(seed + 1)
    for r in range(rounds):
        xs, ys = sample()
        params = step(params, xs, ys, jax.random.fold_in(key, r))
        if r % eval_every == 0 or r == rounds - 1:
            acc = common.evaluate_clients(apply_fn, params, test_x, test_y)
            history.append((r, float(jnp.mean(acc))))
    return params, history
