"""Shared machinery for the baseline strategies: every method trains ONE model
per client (stacked (M, ...) pytrees) on the same features/data as P4. The
round loop itself lives in ``repro.engine`` — these are the building blocks
the Strategy hooks are written in."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dp as dp_lib
from repro.core.small_models import accuracy, linear_apply, linear_specs
from repro.models.layers import softmax_cross_entropy
from repro.models.module import init_params


def make_model(feat_dim: int, num_classes: int):
    specs = linear_specs(feat_dim, num_classes)
    return specs, linear_apply


def ce_loss(apply_fn):
    def loss(params, batch):
        return softmax_cross_entropy(apply_fn(params, batch["x"]), batch["y"])
    return loss


def sgd_update(params, grads, lr: float):
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def client_grad(apply_fn, params, x, y, key, *, dp_cfg=None, sigma: float = 0.0,
                kernels=None):
    """Gradient for one client, optionally DP (per-example clip + noise).
    ``sigma`` may be the engine's traced runtime value (always DP-on then) —
    the DP-path decision must stay static, so it tests host-zero-ness."""
    from repro.kernels.dp_clip.ref import static_zero_sigma
    loss = ce_loss(apply_fn)
    if dp_cfg is not None and dp_cfg.enabled and not static_zero_sigma(sigma):
        if (apply_fn is linear_apply and not dp_cfg.microbatches
                and not dp_cfg.per_example_chunk):
            # linear softmax model: the whole round fuses into the dp_round
            # kernel family (closed-form per-example grads on the Pallas
            # path; the ref backend runs the composed pipeline verbatim)
            from repro.kernels import dispatch
            return dispatch.dp_round(loss, params, x, y, key,
                                     clip=dp_cfg.clip_norm, sigma=sigma,
                                     kernels=kernels)
        return dp_lib.dp_gradients(loss, params, {"x": x, "y": y}, key,
                                   clip=dp_cfg.clip_norm, sigma=sigma,
                                   microbatches=dp_cfg.microbatches,
                                   per_example_chunk=dp_cfg.per_example_chunk,
                                   kernels=kernels)
    return jax.grad(loss)(params, {"x": x, "y": y})


def init_clients(specs, key, M: int):
    return jax.vmap(lambda k: init_params(specs, k))(jax.random.split(key, M))


def evaluate_clients(apply_fn, stacked_params, xs, ys):
    """(M,) per-client test accuracy."""
    return jax.vmap(lambda p, x, y: accuracy(apply_fn(p, x), y))(stacked_params, xs, ys)


def tree_mean(stacked):
    return jax.tree_util.tree_map(lambda t: jnp.mean(t, axis=0), stacked)


def broadcast_like(tree, M: int):
    return jax.tree_util.tree_map(
        lambda t: jnp.broadcast_to(t[None], (M,) + t.shape), tree)
