"""Run-level telemetry: phase spans, a JSONL event log, a run manifest, an
opt-in in-jit metrics tap, and opt-in profiler capture.

A ``Telemetry`` object owns one run directory:

  * ``events.jsonl``  — append-only event stream (spans, per-round tap
    records, eval points, profiler captures); one JSON object per line so
    ``launch/monitor.py`` can tail a live run;
  * ``manifest.json`` — config/strategy/topology fingerprints, the mesh,
    per-phase records, the cumulative (ε, δ)/accuracy trajectory copied
    from ``History`` at every eval boundary, and a closing probe snapshot.

The engine integrates through three narrow seams (``Engine._build_chunk`` /
``_dispatch_chunk`` / the eval loop in ``fit``), all of which check
``telemetry is None or not telemetry.enabled`` FIRST — a run without
telemetry takes the exact pre-telemetry code path, builds byte-identical
chunk-cache keys, and traces byte-identical chunks (locked by the
telemetry-off equivalence scenario).

The tap (``tap=True``) restructures the chunk's scan into blocks of
``TAP_BLOCK`` rounds (identical per-round ops and outputs) with one
``io_callback`` per block streaming per-round scalars (loss/grad-norm-style
metrics means, participation count, realized σ, fault up/slow/keep) to the
event log while the chunk is still executing. Because the callbacks are
part of the traced computation, tap on/off participates in the chunk-cache
fingerprint — a tapped chunk is never served to an untapped engine or vice
versa. The
sharded engine keeps its shard_map trace tap-free and streams the same
per-round events host-side from the chunk's stacked metric outputs instead
(same schema, emitted at chunk completion).

Profiler capture: ``profile_chunk=N`` wraps the Nth dispatched chunk in
``jax.profiler.trace`` (Perfetto trace under ``<run_dir>/profile``).
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.obs.probes import REGISTRY

# ---------------------------------------------------------------------------
# Active-telemetry routing: the io_callback target is this module-level
# dispatcher, NOT a bound method — cached tapped chunks stay reusable across
# Telemetry instances because the sink is resolved per execution. The slot is
# a process-wide global (NOT thread-local): XLA delivers host callbacks on
# its own worker thread, so a thread-local set on the dispatching thread
# would be invisible to the sink.
# ---------------------------------------------------------------------------

_ACTIVE: "Optional[Telemetry]" = None


def current_telemetry() -> "Optional[Telemetry]":
    return _ACTIVE


# The tap's field names never cross the device boundary: at trace time the
# ordered key tuple is interned here and only a small integer schema id
# rides the callback (one flat f32 vector instead of a dict pytree — each
# extra operand costs a host transfer per round). Ids are process-lifetime,
# like the chunk cache, so cached tapped chunks resolve their schema on
# every later execution.
_TAP_SCHEMAS: Dict[int, tuple] = {}
_TAP_SCHEMA_IDS: Dict[tuple, int] = {}


def _schema_id(keys: tuple) -> int:
    sid = _TAP_SCHEMA_IDS.get(keys)
    if sid is None:
        sid = len(_TAP_SCHEMAS)
        _TAP_SCHEMA_IDS[keys] = sid
        _TAP_SCHEMAS[sid] = keys
    return sid


def _tap_sink(sid, r0, table) -> None:
    """Execution-time sink — deliberately minimal (runs on XLA's callback
    thread): append the raw block, format at drain time."""
    tel = current_telemetry()
    if tel is None:
        return
    tel._tap_append(int(sid), int(r0), np.asarray(table, np.float32))


# Rounds per streamed block. A per-round io_callback stalls the scanned
# round pipeline (~0.3–0.5 ms per call on CPU — measured in bench_obs, and
# most of it is XLA host-callback dispatch, not the Python sink), so the
# tap scans in blocks of TAP_BLOCK rounds and streams one (block, fields)
# table per block: the per-round tax drops by ~TAP_BLOCK× while every
# round still lands in the event log.
TAP_BLOCK = 32


def tap_scan(body, state, rs, rt):
    """Tapped twin of ``lax.scan(body, state, rs)``: identical per-round
    ops and identical stacked outputs (the tap-on ≡ tap-off bit-exactness
    contract), but scanned in blocks of ``TAP_BLOCK`` rounds with one
    unordered io_callback per block streaming the block's per-round
    scalars. Only traced when the engine's tap is on, so the tap-off trace
    contains no callback (and no nested scan) at all. The engine's
    ``jax.effects_barrier()`` inside the activation window guarantees
    every callback lands before the chunk span closes."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import io_callback

    length = int(rs.shape[0])
    K = min(TAP_BLOCK, length)

    def emit(r0, n, metrics, aux):
        keys, cols = [], []

        def flat(v):
            return v.reshape(v.shape[0], -1).astype(jnp.float32)

        for k, v in (metrics or {}).items():
            keys.append(k)
            cols.append(jnp.mean(flat(v), axis=1))
        for k, v in (aux or {}).items():
            if k == "participation":
                keys.append(k)
                cols.append(jnp.sum(flat(v), axis=1))
            elif k.startswith("fault_"):
                keys.append(k)
                cols.append(jnp.mean(flat(v), axis=1))
        if rt and "sigma" in rt:
            keys.append("sigma")
            cols.append(jnp.broadcast_to(
                jnp.asarray(rt["sigma"], jnp.float32), (n,)))
        sid = _schema_id(tuple(keys))
        table = (jnp.stack(cols, axis=1) if cols
                 else jnp.zeros((n, 0), jnp.float32))
        io_callback(_tap_sink, None, jnp.int32(sid),
                    jnp.asarray(r0, jnp.int32), table, ordered=False)

    def block(state, rs_block):
        state, ys = jax.lax.scan(body, state, rs_block)
        metrics, aux = ys
        emit(rs_block[0], int(rs_block.shape[0]), metrics, aux)
        return state, ys

    nblocks, rem = divmod(length, K)
    state, ys = jax.lax.scan(block, state,
                             rs[:nblocks * K].reshape(nblocks, K))
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((nblocks * K,) + a.shape[2:]), ys)
    if rem:
        state, ys_tail = block(state, rs[nblocks * K:])
        ys = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), ys, ys_tail)
    return state, ys


class Telemetry:
    """One training run's observability sink. ``enabled=False`` is the
    provably-free off switch: the engine treats it exactly like
    ``telemetry=None`` (no spans, no tap, no files, unchanged cache keys)."""

    def __init__(self, run_dir: Optional[str] = None, *, tap: bool = False,
                 enabled: bool = True, profile_chunk: Optional[int] = None):
        self.enabled = bool(enabled) and run_dir is not None
        self.run_dir = run_dir
        self.tap = bool(tap)
        self.profile_chunk = profile_chunk
        self._lock = threading.Lock()
        self._events_f = None
        self._tap_pending: list = []
        self._chunk_idx = 0
        self._manifest: Dict[str, Any] = {"phases": [], "trajectory": []}
        self._manifest_dirty = False
        self._manifest_written = False
        if self.enabled:
            os.makedirs(run_dir, exist_ok=True)

    # ------------------------------------------------------------- low level
    @property
    def events_path(self) -> str:
        return os.path.join(self.run_dir, "events.jsonl")

    @property
    def manifest_path(self) -> str:
        return os.path.join(self.run_dir, "manifest.json")

    def _emit(self, ev: Dict[str, Any]) -> None:
        if not self.enabled:
            return
        ev.setdefault("t", time.time())
        with self._lock:
            self._drain_tap_locked()
            if self._events_f is None:
                self._events_f = open(self.events_path, "a")
            self._events_f.write(json.dumps(ev) + "\n")
            self._events_f.flush()

    # ------------------------------------------------------- tap hot path
    def _tap_append(self, sid: int, start: int, table,
                    source: Optional[str] = None) -> None:
        """Tap hot path (the io_callback sink and the sharded post-chunk
        stream land here): buffer one raw (rounds, fields) block; JSON
        formatting and file I/O happen once per flush boundary, not once
        per round."""
        with self._lock:
            self._tap_pending.append((time.time(), sid, start, table,
                                      source))

    def _drain_tap_locked(self) -> None:
        if not self._tap_pending:
            return
        pending, self._tap_pending = self._tap_pending, []
        if self._events_f is None:
            self._events_f = open(self.events_path, "a")
        lines = []
        for t, sid, start, table, source in pending:
            keys = _TAP_SCHEMAS.get(sid, ())
            tail = (f', "source": {json.dumps(source)}, "t": {t!r}}}'
                    if source is not None else f', "t": {t!r}}}')
            if keys and bool(np.isfinite(table).all()):
                # fast path: keys are plain metric names and every value is
                # finite, so hand-built lines are valid JSON — per-row
                # json.dumps is ~5x slower and this runs once per round
                for i, row in enumerate(table.tolist()):
                    mid = "".join(f', "{k}": {v!r}'
                                  for k, v in zip(keys, row))
                    lines.append(
                        f'{{"type": "tap", "round": {start + i}{mid}{tail}')
            elif keys:
                for i, row in enumerate(table):
                    ev: Dict[str, Any] = {"type": "tap", "round": start + i}
                    ev.update(zip(keys, (float(x) for x in row)))
                    if source is not None:
                        ev["source"] = source
                    ev["t"] = t
                    lines.append(json.dumps(ev))
            else:
                lines.extend(
                    f'{{"type": "tap", "round": {start + i}{tail}'
                    for i in range(table.shape[0]))
        self._events_f.write("\n".join(lines) + "\n")
        self._events_f.flush()

    def flush(self) -> None:
        if self._manifest_dirty:
            self._write_manifest()
        with self._lock:
            self._drain_tap_locked()
            if self._events_f is not None:
                self._events_f.flush()

    def _write_manifest(self) -> None:
        with self._lock:
            tmp = self.manifest_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self._manifest, f, indent=1, default=str)
            os.replace(tmp, self.manifest_path)
            self._manifest_dirty = False
            self._manifest_written = True

    def close(self) -> None:
        if self._manifest_dirty:
            self._write_manifest()
        with self._lock:
            self._drain_tap_locked()
            if self._events_f is not None:
                self._events_f.close()
                self._events_f = None

    # ----------------------------------------------------------------- spans
    @contextlib.contextmanager
    def activate(self):
        """Execution-time routing context for the in-jit tap's io_callbacks
        (installed by the engine around chunk dispatch; the engine blocks on
        the chunk inside this context, so the callbacks land before exit)."""
        global _ACTIVE
        prev = _ACTIVE
        _ACTIVE = self
        try:
            yield
        finally:
            _ACTIVE = prev

    @contextlib.contextmanager
    def span(self, name: str, **fields):
        """Wall-clock span: emits {"type": "span", "name": ..., "dt": s}."""
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self._emit(dict({"type": "span", "name": name,
                             "dt": time.perf_counter() - t0}, **fields))

    @contextlib.contextmanager
    def chunk_span(self, **fields):
        """Span around one dispatched chunk, with the trace-vs-execute split
        read off the chunk-cache probe (a cache hit executes without
        tracing) and the chunk's mixing path read off the mix probe. The
        profiler capture of the Nth chunk rides this span."""
        if not self.enabled:
            yield
            return
        idx = self._chunk_idx
        self._chunk_idx += 1
        sel = [n for n in ("engine.chunk_cache", "topology.mix")
               if n in REGISTRY.names()]
        profiled = (self.profile_chunk is not None
                    and idx == self.profile_chunk)
        prof_dir = os.path.join(self.run_dir, "profile")
        prof_cm = contextlib.nullcontext()
        if profiled:
            try:
                import jax
                prof_cm = jax.profiler.trace(prof_dir)
            except Exception:  # profiler unavailable on this backend
                profiled = False
        t0 = time.perf_counter()
        with REGISTRY.deltas(*sel) as d:
            with prof_cm:
                try:
                    yield
                finally:
                    dt = time.perf_counter() - t0
        ev = dict({"type": "span", "name": "chunk", "chunk": idx, "dt": dt},
                  **fields)
        cache = d.get("engine.chunk_cache") or {}
        ev["traced"] = bool(cache.get("traces", 0) > 0)
        ev["cache"] = {k: int(cache.get(k, 0))
                       for k in ("traces", "hits", "misses")}
        mix = d.get("topology.mix") or {}
        if mix.get("calls", 0) > 0:
            paths = {k[len("path_"):]: v for k, v in mix.items()
                     if k.startswith("path_") and v > 0}
            ev["mix_path"] = max(paths, key=paths.get) if paths else None
            ev["collectives"] = {"all_gathers": int(mix.get("all_gathers", 0)),
                                 "ppermutes": int(mix.get("ppermutes", 0))}
        if profiled:
            ev["profile_dir"] = prof_dir
        self._emit(ev)

    # ------------------------------------------------------------ run record
    def begin_phase(self, info: Dict[str, Any]) -> None:
        """Called by ``Engine.fit`` at phase start with the run's identity:
        strategy/schedule/topology fingerprints, mesh, rounds, batch size.
        The first phase writes the manifest eagerly so a live monitor can
        identify the run; later phases only mark it dirty (the atomic
        rewrite is ~0.5 ms of syscalls — real per-fit money in a sweep of
        short phases) and land at the next ``flush``/``close``."""
        if not self.enabled:
            return
        info = dict(info, t=time.time())
        self._manifest["phases"].append(info)
        self._manifest.setdefault("created", time.time())
        if self._manifest_written:
            self._manifest_dirty = True
        else:
            self._write_manifest()
        self._emit(dict({"type": "phase_begin"}, **info))

    def eval_event(self, round_: int, accuracy: float,
                   metrics: Dict[str, float]) -> None:
        """One eval-boundary record, copied from the History entry AFTER it
        is recorded — the JSONL trajectory and the returned History agree
        exactly by construction."""
        if not self.enabled:
            return
        ev = {"type": "eval", "round": int(round_),
              "accuracy": float(accuracy)}
        ev.update({k: float(v) for k, v in metrics.items()})
        self._emit(ev)
        self._manifest["trajectory"].append(
            {k: v for k, v in ev.items() if k not in ("type", "t")})

    def end_phase(self) -> None:
        """Phase close: records the probe snapshot and marks the manifest
        dirty. The on-disk rewrite (an atomic replace, ~0.5 ms of syscalls)
        is deferred to the next ``begin_phase``/``flush``/``close`` — the
        event log is the crash-safe record, so the manifest is allowed to
        run one phase stale while a run is live."""
        if not self.enabled:
            return
        self._manifest["probes"] = REGISTRY.snapshot()
        self._manifest_dirty = True
        self._emit({"type": "phase_end"})

    # ------------------------------------------- sharded (post-chunk) stream
    def emit_tap_stacked(self, start: int, length: int, metrics, aux,
                         rt) -> None:
        """Host-side twin of the in-jit tap for engines whose chunk trace
        must stay tap-free (shard_map regions): emits the same per-round
        event schema from the chunk's stacked metric outputs. Reductions
        are vectorized over the round axis and the per-round records take
        the same buffered drain path as the io_callback sink. ``length``
        is the chunk's round count — the stream covers every round even
        for strategies that surface no per-round metrics."""
        if not (self.enabled and self.tap):
            return
        keys, cols = [], []
        for k, v in (metrics or {}).items():
            a = np.asarray(v, np.float32)
            if a.ndim == 0:
                continue
            keys.append(k)
            cols.append(a.reshape(a.shape[0], -1).mean(axis=1))
        for k, v in (aux or {}).items():
            if k != "participation" and not k.startswith("fault_"):
                continue
            a = np.asarray(v, np.float32)
            if a.ndim == 0:
                continue
            flat = a.reshape(a.shape[0], -1)
            keys.append(k)
            cols.append(flat.sum(axis=1) if k == "participation"
                        else flat.mean(axis=1))
        length = int(length)
        if not length:
            return
        cols = [c[:length] for c in cols]
        if rt and "sigma" in rt:
            keys.append("sigma")
            cols.append(np.full((length,), float(np.asarray(rt["sigma"])),
                                np.float32))
        table = (np.stack(cols, axis=1) if cols
                 else np.zeros((length, 0), np.float32))
        self._tap_append(_schema_id(tuple(keys)), int(start), table,
                         source="chunk")
