"""Observability: unified counter probes, phase spans, a JSONL event
stream, and an opt-in in-jit metrics tap — with a zero-overhead-off
guarantee (telemetry absent or disabled changes nothing: no files, no
spans, unchanged chunk-cache keys, bit-identical traces)."""
from repro.obs.probes import (Probe, ProbeRegistry, REGISTRY, get_probe,
                              probe_deltas, probe_snapshot, reset_probes)
from repro.obs.telemetry import (Telemetry, current_telemetry, tap_scan)

__all__ = [
    "Probe", "ProbeRegistry", "REGISTRY", "get_probe", "probe_deltas",
    "probe_snapshot", "reset_probes", "Telemetry", "current_telemetry",
    "tap_scan",
]
