"""Unified counter probes: one registry over every trace-time stats dict.

The repo grew one module-global counter dict per subsystem — ``CHUNK_STATS``
(compiled-chunk cache), ``MIX_STATS`` (mixing paths/collectives), the
autotuner's hit/miss tallies, the cohort prefetcher's staleness counters —
each with its own ad-hoc reset function and each forcing callers who want
*per-run* numbers into hand-rolled ``before = dict(STATS)`` arithmetic
(and silently inflated numbers when they forget: nothing resets between
Engine instances in one process).

``Probe`` is a ``dict`` subclass, so the existing module globals keep their
exact semantics — ``CHUNK_STATS["hits"] += 1``, ``dict(CHUNK_STATS)``,
``.update(...)`` all behave identically and every pre-existing test passes
unedited — while registration gives every counter group a shared API:

  * ``registry.snapshot()`` — point-in-time copy of every probe;
  * ``registry.reset()``    — zero everything (template-typed zeros);
  * ``probe_deltas(...)``   — a scoped context manager measuring exactly
    what happened inside the ``with`` block, replacing the hand-diffed
    snapshot arithmetic. Scopes nest and compose: each scope owns its own
    entry snapshot, so an inner scope's counts are a subset of the outer's.

This module is deliberately stdlib-only: probe-owning modules (e.g.
``topology.mixing``) import it at module load, before jax is necessarily
initialized.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Iterable, Optional, Tuple


class Probe(dict):
    """A named group of counters. Plain-dict reads/writes remain the hot-path
    increment idiom (``PROBE["hits"] += 1`` at trace time costs one dict op);
    the registry layers snapshot/reset/delta semantics on top."""

    def __init__(self, name: str, counters: Dict[str, float],
                 registry: "Optional[ProbeRegistry]" = None):
        super().__init__(counters)
        self.name = name
        # typed zero template: ``reset`` restores these values; keys added
        # after construction reset to int 0
        self._zeros = dict(counters)
        (registry if registry is not None else REGISTRY).register(self)

    def snapshot(self) -> Dict[str, float]:
        return dict(self)

    def reset(self) -> None:
        for k in self:
            self[k] = self._zeros.get(k, 0)

    def delta_from(self, before: Dict[str, float]) -> Dict[str, float]:
        """Counter movement since ``before`` (a prior ``snapshot()``). Keys
        born after the snapshot count from their typed zero."""
        return {k: v - before.get(k, self._zeros.get(k, 0))
                for k, v in self.items()}

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"Probe({self.name!r}, {dict(self)!r})"


class ProbeRegistry:
    """Process-global name → Probe index. Registration happens at module
    import of the probe's owner, so ``snapshot()`` covers exactly the
    subsystems the process has loaded."""

    def __init__(self):
        self._probes: "Dict[str, Probe]" = {}
        self._lock = threading.Lock()

    def register(self, probe: Probe) -> Probe:
        with self._lock:
            self._probes[probe.name] = probe
        return probe

    def get(self, name: str) -> Probe:
        try:
            return self._probes[name]
        except KeyError:
            raise KeyError(
                f"no probe named {name!r} is registered (loaded probes: "
                f"{sorted(self._probes)})") from None

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._probes))

    def _select(self, names: Optional[Iterable[str]]) -> Tuple[Probe, ...]:
        if names is None:
            return tuple(self._probes[n] for n in self.names())
        return tuple(self.get(n) for n in names)

    def snapshot(self, names: Optional[Iterable[str]] = None
                 ) -> Dict[str, Dict[str, float]]:
        return {p.name: p.snapshot() for p in self._select(names)}

    def reset(self, names: Optional[Iterable[str]] = None) -> None:
        for p in self._select(names):
            p.reset()

    @contextlib.contextmanager
    def deltas(self, *names: str):
        """Scoped measurement: yields a ``ProbeDeltas`` whose per-probe
        counter movements cover exactly the ``with`` block. With no names,
        every probe registered at scope entry is measured."""
        sel = self._select(names or None)
        d = ProbeDeltas({p.name: p.snapshot() for p in sel}, self)
        try:
            yield d
        finally:
            d.finalize()


class ProbeDeltas:
    """The result object of a ``deltas`` scope. Inside the scope,
    ``d[name]`` reads the movement so far (live); after the scope it is
    frozen at the block's exit values. Mapping-style access only covers the
    probes the scope selected."""

    def __init__(self, before: Dict[str, Dict[str, float]],
                 registry: ProbeRegistry):
        self._before = before
        self._registry = registry
        self._frozen: Optional[Dict[str, Dict[str, float]]] = None

    def finalize(self) -> None:
        if self._frozen is None:
            self._frozen = {n: self._registry.get(n).delta_from(b)
                            for n, b in self._before.items()}

    def __getitem__(self, name: str) -> Dict[str, float]:
        if self._frozen is not None:
            return dict(self._frozen[name])
        if name not in self._before:
            raise KeyError(f"probe {name!r} was not selected by this scope")
        return self._registry.get(name).delta_from(self._before[name])

    def get(self, name: str, default=None):
        try:
            return self[name]
        except KeyError:
            return default

    def asdict(self) -> Dict[str, Dict[str, float]]:
        return {n: self[n] for n in self._before}

    def keys(self):
        return self._before.keys()


#: The process-global registry every subsystem probe registers with.
REGISTRY = ProbeRegistry()


def get_probe(name: str) -> Probe:
    return REGISTRY.get(name)


def probe_snapshot(names: Optional[Iterable[str]] = None):
    return REGISTRY.snapshot(names)


def reset_probes(names: Optional[Iterable[str]] = None) -> None:
    REGISTRY.reset(names)


def probe_deltas(*names: str):
    """Module-level alias for ``REGISTRY.deltas`` — the scoped-delta API:

        with probe_deltas("engine.chunk_cache") as d:
            engine.fit(...)
        print(d["engine.chunk_cache"])   # {"traces": 1, "hits": 3, ...}
    """
    return REGISTRY.deltas(*names)
