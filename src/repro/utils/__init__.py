from repro.utils.pytree import (
    tree_flatten_concat,
    tree_unflatten_concat,
    global_norm,
    tree_add,
    tree_scale,
    tree_zeros_like,
    param_count,
    tree_size_bytes,
)
