"""Pytree utilities used across the framework (pure JAX, no deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_flatten_concat(tree, dtype=jnp.float32):
    """Flatten a pytree of arrays into a single 1-D vector.

    Used by the P4 grouping phase (l1-norm over ``vec(w_i)``, paper Eq. 3)
    and by the DP clipping kernel (per-example flat gradients).
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), dtype)
    return jnp.concatenate([jnp.ravel(l).astype(dtype) for l in leaves])


def tree_unflatten_concat(flat, tree):
    """Inverse of :func:`tree_flatten_concat` given a template ``tree``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(jnp.reshape(flat[off : off + n], l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def global_norm(tree):
    """l2 norm over every leaf of a pytree (DP clipping, Eq. 10)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def tree_add(a, b):
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_l1_distance(a, b):
    """Paper Eq. 3: dissimilarity(i, j) = ||vec(w_i) - vec(w_j)||_1."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(
        jnp.sum(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)))
        for x, y in zip(leaves_a, leaves_b)
    )


def param_count(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))


def tree_size_bytes(tree) -> int:
    return int(
        sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))
    )


def split_like(key, tree):
    """One PRNG key per leaf, as a pytree shaped like ``tree``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))
