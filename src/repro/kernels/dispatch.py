"""Kernel backend dispatch + tile-size autotuning — the single entry point
through which the framework reaches its compute kernels.

Selection policy (replaces the old bare ``use_pallas: bool``):

  * ``auto``      — compiled Pallas on TPU, pure-jnp reference elsewhere.
                    The Pallas interpreter is NEVER chosen automatically: it
                    is strictly slower than the jnp oracle it validates.
  * ``pallas``    — compiled Pallas; raises on platforms without Mosaic
                    support rather than silently degrading.
  * ``interpret`` — Pallas interpreter, for explicit kernel debugging only.
  * ``ref``       — the pure-jnp oracle.

Tile sizes are autotuned on first use and cached per
``(kernel, shape, dtype, backend)``; explicit tiles in ``KernelConfig``
bypass the tuner. The cache is process-global — every jit trace after the
first hits it, so tracing inside vmap/scan pays the search exactly once.

Fused DP-SGD entry points (paper Eqs. 10–11 hot loop): ``dp_clip`` /
``dp_clip_flat`` fuse flatten→norm→scale→accumulate→noise so the (B, D)
per-example gradient matrix is read at most twice (one norm pass, one
scale-accumulate pass with the 1/denom mean folded into the scales) and the
Gaussian noise is a single (D,) draw on the flat output buffer — no
per-leaf noise loop.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import KernelConfig
from repro.obs.probes import Probe
from repro.kernels.dp_clip import kernel as dp_kernel, ops as dp_ops, ref as dp_ref
from repro.kernels.dp_round import (kernel as dpr_kernel, ops as dpr_ops,
                                    ref as dpr_ref)
from repro.kernels.l1_distance import kernel as l1_kernel, ops as l1_ops, ref as l1_ref
from repro.utils.pytree import tree_flatten_concat, tree_unflatten_concat

# Platforms with a Pallas compile path (Mosaic). GPU/Triton is untested in
# this repo, so it is deliberately NOT auto-selected.
_PALLAS_PLATFORMS = ("tpu",)

_BACKENDS = ("auto", "pallas", "interpret", "ref")


def resolve_backend(requested: str = "auto", platform: Optional[str] = None) -> str:
    """Map a requested backend to a concrete one ("pallas"|"interpret"|"ref").

    ``interpret`` is only ever returned when explicitly requested."""
    if requested not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {requested!r}; "
                         f"expected one of {_BACKENDS}")
    platform = platform or jax.default_backend()
    if requested == "auto":
        return "pallas" if platform in _PALLAS_PLATFORMS else "ref"
    if requested == "pallas" and platform not in _PALLAS_PLATFORMS:
        raise ValueError(
            f"backend='pallas' requires one of {_PALLAS_PLATFORMS}, got "
            f"{platform!r}; use backend='interpret' for explicit debugging "
            f"or 'auto'/'ref' for the jnp reference")
    return requested


# ---------------------------------------------------------------------------
# Autotuner — cached per (kernel, shape, dtype, backend)
# ---------------------------------------------------------------------------

_TuneKey = Tuple[str, Tuple[int, ...], str, str]
_TUNE_CACHE: Dict[_TuneKey, Tuple[int, ...]] = {}
# registry-backed probe (see repro.obs): hit/miss tallies plus the search
# cost itself — how many candidate tilings were timed and the wall-clock
# seconds the searches spent, per scope via probe_deltas("kernels.autotune")
_TUNE_STATS = Probe("kernels.autotune", {"hits": 0, "misses": 0,
                                         "candidates_timed": 0,
                                         "search_seconds": 0.0})


def clear_autotune_cache() -> None:
    _TUNE_CACHE.clear()
    _TUNE_STATS.reset()


def autotune_cache_stats() -> Dict[str, int]:
    return dict(_TUNE_STATS, entries=len(_TUNE_CACHE))


def autotune(kernel_name: str, shape: Sequence[int], dtype, backend: str,
             candidates: Sequence[Tuple[int, ...]],
             time_fn: Callable[[Tuple[int, ...]], float],
             trials: int = 2) -> Tuple[int, ...]:
    """Pick the fastest candidate tiling for ``kernel_name`` on ``shape``.

    ``time_fn(candidate) -> seconds`` runs one timed call; candidates that
    raise are skipped. The winner is memoized per (kernel, shape, dtype,
    backend) so repeated traces (vmap/scan/re-jit) never re-search."""
    key: _TuneKey = (kernel_name, tuple(int(s) for s in shape),
                     jnp.dtype(dtype).name, backend)
    if key in _TUNE_CACHE:
        _TUNE_STATS["hits"] += 1
        return _TUNE_CACHE[key]
    _TUNE_STATS["misses"] += 1
    search_t0 = time.perf_counter()
    best, best_t = None, float("inf")
    for cand in candidates:
        try:
            t = min(float(time_fn(cand)) for _ in range(max(1, trials)))
        except Exception:
            continue
        _TUNE_STATS["candidates_timed"] += 1
        if t < best_t:
            best, best_t = tuple(cand), t
    _TUNE_STATS["search_seconds"] += time.perf_counter() - search_t0
    if best is None:
        best = tuple(candidates[0])
    _TUNE_CACHE[key] = best
    return best


def _timed(fn, *args) -> float:
    jax.block_until_ready(fn(*args))  # compile / warm up
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def _dp_clip_candidates(B: int, D: int):
    tbs = [tb for tb in (8, 16, 32) if tb <= max(8, B)]
    tds = [td for td in (2048, 8192, 16384) if td <= max(2048, D)]
    return [(tb, td) for tb in tbs for td in tds] or [(8, 2048)]


def _dp_round_candidates(F: int):
    tfs = [tf for tf in (128, 256, 512) if tf <= max(128, F)]
    return [(tf,) for tf in tfs] or [(128,)]


def _l1_candidates(M: int, D: int):
    tms = [tm for tm in (8, 16) if tm <= max(8, M)]
    tds = [td for td in (2048, 8192) if td <= max(2048, D)]
    return [(tm, td) for tm in tms for td in tds] or [(8, 2048)]


def dp_clip_tiles(shape: Tuple[int, int], dtype, cfg: KernelConfig,
                  backend: str) -> Tuple[int, int]:
    if cfg.dp_clip_tile != (0, 0):
        return cfg.dp_clip_tile
    if backend != "pallas" or not cfg.autotune:
        return (dp_kernel.DEFAULT_TB, dp_kernel.DEFAULT_TD)
    B, D = shape

    def time_fn(cand):
        tb, td = cand
        x = jnp.zeros(shape, dtype)
        return _timed(lambda a: dp_ops.clip_accumulate_flat(
            a, 1.0, interpret=False, tb=tb, td=td), x)

    return autotune("dp_clip", shape, dtype, backend,
                    _dp_clip_candidates(B, D), time_fn,
                    trials=cfg.autotune_trials)


def dp_round_tiles(shape: Tuple[int, int, int], dtype, cfg: KernelConfig,
                   backend: str) -> Tuple[int]:
    """shape = (B, F, C) of the fused round."""
    if cfg.dp_round_tile != 0:
        return (cfg.dp_round_tile,)
    if backend != "pallas" or not cfg.autotune:
        return (dpr_kernel.DEFAULT_TF,)
    B, F, C = shape

    def time_fn(cand):
        (tf,) = cand
        params = {"w": jnp.zeros((F, C), dtype), "b": jnp.zeros((C,), dtype)}
        x = jnp.zeros((B, F), dtype)
        y = jnp.zeros((B,), jnp.int32)
        return _timed(lambda p, a, b: dpr_ops.dp_round_linear(
            p, a, b, clip=1.0, interpret=False, tf=tf), params, x, y)

    return autotune("dp_round", shape, dtype, backend,
                    _dp_round_candidates(F), time_fn,
                    trials=cfg.autotune_trials)


def _mix_halo_candidates(m: int):
    """Row-block widths for the halo mix-step arithmetic; (0,) is the
    untiled lowering (today's default) and always a candidate."""
    return [(0,)] + [(tm,) for tm in (8, 16, 32, 64, 128) if tm < m]


def _halo_mix_probe(buf, idx, s, w, tm: int):
    """The halo mix step's per-row arithmetic on a receive buffer, blocked
    in rows of ``tm`` (0 = untiled) — the shape the autotuner times. Row
    arithmetic is row-independent, so every tile width is bit-identical;
    only the lowering changes."""
    m = idx.shape[0]
    t = buf[:m]

    def block(sl):
        acc = s[sl, None] * t[sl]
        for k in range(idx.shape[1]):
            acc = acc + w[sl, k:k + 1] * buf[idx[sl, k]]
        return acc

    if tm <= 0 or tm >= m:
        return block(slice(None))
    return jnp.concatenate([block(slice(i0, min(i0 + tm, m)))
                            for i0 in range(0, m, tm)], axis=0)


def mix_halo_tiles(shape: Tuple[int, int, int, int], dtype,
                   cfg: KernelConfig, backend: str) -> Tuple[int]:
    """shape = (m, H, degree, feat): local rows, halo rows, neighbor slots,
    flattened trailing size of the mixed leaf. Same policy as the other
    dispatchers: explicit tile bypasses, non-pallas/no-autotune takes the
    static default (untiled, i.e. the pre-autotune lowering), otherwise the
    cached search runs once per (shape, dtype, backend)."""
    if cfg.mix_halo_tile != 0:
        return (cfg.mix_halo_tile,)
    if backend != "pallas" or not cfg.autotune:
        return (0,)
    m, H, d, f = shape

    def time_fn(cand):
        (tm,) = cand
        buf = jnp.zeros((m + H, f), dtype)
        idx = jnp.zeros((m, max(d, 1)), jnp.int32)
        s = jnp.ones((m,), dtype)
        w = jnp.zeros((m, max(d, 1)), dtype)
        return _timed(lambda b: _halo_mix_probe(b, idx, s, w, tm), buf)

    return autotune("mix_halo", shape, dtype, backend,
                    _mix_halo_candidates(m), time_fn,
                    trials=cfg.autotune_trials)


def l1_tiles(shape: Tuple[int, int], dtype, cfg: KernelConfig,
             backend: str) -> Tuple[int, int]:
    if cfg.l1_tile != (0, 0):
        return cfg.l1_tile
    if backend != "pallas" or not cfg.autotune:
        return (l1_kernel.DEFAULT_TM, l1_kernel.DEFAULT_TD)
    M, D = shape

    def time_fn(cand):
        tm, td = cand
        x = jnp.zeros(shape, dtype)
        return _timed(lambda a: l1_ops.pairwise_l1(
            a, interpret=False, tm=tm, td=td), x)

    return autotune("l1_distance", shape, dtype, backend,
                    _l1_candidates(M, D), time_fn,
                    trials=cfg.autotune_trials)


# ---------------------------------------------------------------------------
# Dispatched kernel entry points
# ---------------------------------------------------------------------------

def _cfg(kernels: Optional[KernelConfig]) -> KernelConfig:
    return kernels if kernels is not None else KernelConfig()


def clip_accumulate(flat, clip: float, *, denom: float = 1.0,
                    kernels: Optional[KernelConfig] = None):
    """flat: (B, D) per-example grads -> Σ_b clipped(g_b)/denom (D,) fp32.

    Reads (B, D) at most twice on every backend (norm pass +
    scale-accumulate pass with the mean folded into the scales)."""
    cfg = _cfg(kernels)
    backend = resolve_backend(cfg.backend)
    if backend == "ref":
        return dp_ref.clip_accumulate(flat, clip, denom=denom)
    tb, td = dp_clip_tiles(tuple(flat.shape), flat.dtype, cfg, backend)
    return dp_ops.clip_accumulate_flat(flat, clip, denom=denom,
                                       interpret=(backend == "interpret"),
                                       tb=tb, td=td)


def dp_clip_flat(flat, clip: float, key=None, *, sigma: float = 0.0,
                 denom: float = 1.0, kernels: Optional[KernelConfig] = None):
    """Fused DP-SGD numerator on a flat (B, D) matrix: clipped mean plus the
    Eq. 11 Gaussian drawn once on the (D,) output buffer. The draw is
    identical across backends (same key -> bit-equal noise); sigma > 0
    without a key raises."""
    # a traced σ counts as positive: fail before the clip passes, not after
    if not dp_ref.static_zero_sigma(sigma) and key is None:
        raise ValueError("sigma > 0 requires a PRNG key (privacy guard)")
    out = clip_accumulate(flat, clip, denom=denom, kernels=kernels)
    return dp_ref.add_flat_noise(out, key, sigma, clip, denom)


def dp_clip(per_example_grads, clip: float, key=None, *, sigma: float = 0.0,
            denom: Optional[float] = None,
            kernels: Optional[KernelConfig] = None):
    """Fused flatten→norm→scale→accumulate→noise over a per-example gradient
    pytree (leading example dim B on every leaf) -> noised mean pytree.

    The (B, D) matrix is materialized once by the flatten and then read at
    most twice; noise is one flat (D,) draw, killing the per-leaf loop."""
    flat = jax.vmap(tree_flatten_concat)(per_example_grads)      # (B, D)
    if denom is None:
        denom = float(flat.shape[0])
    out = dp_clip_flat(flat, clip, key, sigma=sigma, denom=denom,
                       kernels=kernels)
    template = jax.tree_util.tree_map(lambda g: g[0], per_example_grads)
    return tree_unflatten_concat(out, template)


def dp_round(loss_fn, params, x, y, key=None, *, clip: float,
             sigma: float = 0.0, denom=None,
             kernels: Optional[KernelConfig] = None):
    """Fused local DP round: per-example grad → clip → accumulate → noise in
    one kernel family (linear softmax model; ``loss_fn`` is only used by the
    ref backend, which runs the composed autodiff pipeline verbatim — the
    ref path is therefore bit-identical to not fusing at all). The Pallas
    path uses the closed-form gradient: two matmul passes over the batch
    instead of a B-way per-example gradient stack plus two clip passes."""
    if not dp_ref.static_zero_sigma(sigma) and key is None:
        raise ValueError("sigma > 0 requires a PRNG key (privacy guard)")
    cfg = _cfg(kernels)
    backend = resolve_backend(cfg.backend)
    if backend == "ref":
        return dpr_ref.dp_round_reference(loss_fn, params, x, y, key,
                                          clip=clip, sigma=sigma)
    B, F = x.shape
    C = params["b"].shape[0]
    (tf,) = dp_round_tiles((B, F, C), x.dtype, cfg, backend)
    return dpr_ops.dp_round_linear(params, x, y, key, clip=clip, sigma=sigma,
                                   denom=denom,
                                   interpret=(backend == "interpret"), tf=tf)


def pairwise_l1(weights, kernels: Optional[KernelConfig] = None):
    """weights: (M, D) -> (M, M) ℓ1 distances (paper Eq. 3)."""
    cfg = _cfg(kernels)
    backend = resolve_backend(cfg.backend)
    if backend == "ref":
        return l1_ref.pairwise_l1(weights)
    tm, td = l1_tiles(tuple(weights.shape), weights.dtype, cfg, backend)
    return l1_ops.pairwise_l1(weights, interpret=(backend == "interpret"),
                              tm=tm, td=td)
