"""Pallas TPU kernels for the framework's compute hot-spots.

  dp_clip          — per-example gradient clip-scale-accumulate, the DP-SGD
                     throughput bottleneck (paper Phase 2 inner loop)
  l1_distance      — pairwise ℓ1 over flattened client weights (Phase 1)
  flash_attention  — blocked online-softmax attention (prefill at 32k/500k)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle). Backend selection and tile autotuning
live in ``dispatch.py`` (``RunConfig.kernels``): compiled Pallas on TPU, the
jnp reference on CPU, and the interpreter only when explicitly requested for
debugging.
"""
