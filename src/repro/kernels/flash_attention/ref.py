"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q, k, v: (BH, S, d) -> (BH, S, d)."""
    BH, S, d = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
