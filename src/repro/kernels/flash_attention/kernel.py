"""Pallas flash-attention kernel (causal + optional sliding window).

Grid (batch·heads, num_q_blocks, num_kv_blocks); kv innermost so the online-
softmax state (m, l, acc) lives in VMEM scratch across the reduction. Block
shapes default to (128, head_dim) — MXU-aligned for the q·kᵀ and p·v matmuls;
fp32 running state, inputs any float dtype.

This is the TPU-target realization of the pure-JAX chunked path in
repro.models.attention (which the CPU dry-run lowers); both are validated
against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, causal: bool, window: int, block_q: int, block_k: int,
                  num_kv: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (TQ, d)
    k = k_ref[0].astype(jnp.float32)                    # (TK, d)
    s = q @ k.T                                         # (TQ, TK)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v_ref[0].astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-37)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q, k, v: (BH, S, d) with equal q/kv head counts (GQA is expanded by the
    ops wrapper). Returns (BH, S, d) in q.dtype."""
    from jax.experimental.pallas import tpu as pltpu

    BH, S, d = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(
        _flash_kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, num_kv=nk, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
