"""jit'd wrapper: (b, s, h, d) GQA layout -> flash kernel layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import kernel


def flash_attention_gqa(q, k, v, *, causal: bool = True, window: int = 0,
                        interpret: bool = True, block_q: int = 128,
                        block_k: int = 128):
    """q: (b, s, hq, d); k, v: (b, s, hkv, d) -> (b, s, hq, d)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    kx = jnp.repeat(k, g, axis=2)
    vx = jnp.repeat(v, g, axis=2)
    def to_bh(t):
        return t.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    o = kernel.flash_attention(to_bh(q), to_bh(kx), to_bh(vx), causal=causal,
                               window=window, block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return o.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
