"""Pallas kernels for the fused DP round on the linear client model.

Two passes over the (B, F) batch, tiled on the feature axis (F is the only
axis that grows with model size; B and C are round-constants):

  1. ``logits_xsq`` — forward matmul x·w accumulated over F tiles, fused
     with the per-example ‖x‖² reduction (the clip-norm factor), so the
     batch is read once for both.
  2. ``wgrad``      — xᵀ·(scaled dlogits): one (tf, C) output tile per F
     tile, no cross-tile accumulation.

Between the passes the host-side op computes softmax−onehot, the factored
per-example clip scales, and the bias gradient — O(B·C) work that stays in
jnp. MXU matmuls accumulate in f32 via ``preferred_element_type``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TF = 512


def _logits_xsq_kernel(x_ref, w_ref, b_ref, logits_ref, xsq_ref):
    f = pl.program_id(0)

    @pl.when(f == 0)
    def _():
        logits_ref[...] = jnp.broadcast_to(b_ref[...].astype(jnp.float32),
                                           logits_ref.shape)
        xsq_ref[...] = jnp.zeros_like(xsq_ref)

    x = x_ref[...].astype(jnp.float32)              # (B, TF)
    logits_ref[...] += jnp.dot(x, w_ref[...].astype(jnp.float32),
                               preferred_element_type=jnp.float32)
    xsq_ref[...] += jnp.sum(x * x, axis=1)


@functools.partial(jax.jit, static_argnames=("tf", "interpret"))
def logits_xsq(x, w, b, tf: int = DEFAULT_TF, interpret: bool = True):
    """x: (B, F), w: (F, C), b: (C,) -> (logits (B, C) f32, ‖x‖² (B,) f32).
    F % tf == 0 (callers pad)."""
    B, F = x.shape
    C = w.shape[1]
    tf = min(tf, F)
    assert F % tf == 0, (F, tf)
    return pl.pallas_call(
        _logits_xsq_kernel,
        grid=(F // tf,),
        in_specs=[
            pl.BlockSpec((B, tf), lambda f: (0, f)),
            pl.BlockSpec((tf, C), lambda f: (f, 0)),
            pl.BlockSpec((C,), lambda f: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((B, C), lambda f: (0, 0)),
            pl.BlockSpec((B,), lambda f: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, b)


def _wgrad_kernel(x_ref, sdl_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)              # (B, TF)
    out_ref[...] = jnp.dot(x.T, sdl_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tf", "interpret"))
def wgrad(x, sdl, tf: int = DEFAULT_TF, interpret: bool = True):
    """x: (B, F), sdl: (B, C) scaled dlogits -> xᵀ·sdl (F, C) f32."""
    B, F = x.shape
    C = sdl.shape[1]
    tf = min(tf, F)
    assert F % tf == 0, (F, tf)
    return pl.pallas_call(
        _wgrad_kernel,
        grid=(F // tf,),
        in_specs=[
            pl.BlockSpec((B, tf), lambda f: (0, f)),
            pl.BlockSpec((B, C), lambda f: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tf, C), lambda f: (f, 0)),
        out_shape=jax.ShapeDtypeStruct((F, C), jnp.float32),
        interpret=interpret,
    )(x, sdl)
