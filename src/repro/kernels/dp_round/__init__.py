from repro.kernels.dp_round import ops, ref
