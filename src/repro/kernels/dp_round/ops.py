"""jit'd wrapper for the fused DP round on the linear model.

Pads (B, F, C) to tile/lane multiples, runs the two Pallas passes with the
O(B·C) clip/scale work between them in jnp, and adds the canonical flat
noise. Backend/tile selection lives in ``repro.kernels.dispatch``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dp_clip.ref import add_flat_noise
from repro.kernels.dp_round import kernel
from repro.kernels.dp_round.ref import softmax_dlogits


def _pad2(x, mb, mf):
    B, F = x.shape
    pb, pf = (-B) % mb, (-F) % mf
    if pb or pf:
        x = jnp.pad(x, ((0, pb), (0, pf)))
    return x


def dp_round_linear(params, x, y, key=None, *, clip: float,
                    sigma: float = 0.0, denom=None, tf: int = 512,
                    interpret: bool = True):
    """Fused local DP round for the linear softmax model.

    Pads B to a sublane multiple and F to the feature tile; padded batch
    rows and padded classes are sliced away BEFORE the softmax (a padded
    class would shift real probabilities), and padded rows re-enter pass B
    with zero scaled-dlogits, so they contribute exactly nothing."""
    B, F = x.shape
    C = params["b"].shape[0]
    if denom is None:
        denom = float(B)
    tf = min(tf, max(128, F))
    Bp = -(-B // 8) * 8
    Cp = -(-C // 128) * 128
    xp = _pad2(x, 8, tf)
    wp = jnp.pad(params["w"], ((0, xp.shape[1] - F), (0, Cp - C)))
    bp = jnp.pad(params["b"], (0, Cp - C))
    logits, xsq = kernel.logits_xsq(xp, wp, bp, tf=tf, interpret=interpret)
    logits, xsq = logits[:B, :C], xsq[:B]
    dl = softmax_dlogits(logits, y)
    norms = jnp.sqrt(jnp.sum(dl * dl, axis=-1) * (1.0 + xsq))
    scales = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12)) / denom
    sdl = dl * scales[:, None]
    b_grad = jnp.sum(sdl, axis=0)
    sdl_p = jnp.pad(sdl, ((0, Bp - B), (0, Cp - C)))
    w_grad = kernel.wgrad(xp, sdl_p, tf=tf, interpret=interpret)[:F, :C]
    flat = jnp.concatenate([b_grad, w_grad.ravel()])
    flat = add_flat_noise(flat, key, sigma, clip, denom)
    return {"b": flat[:C].astype(params["b"].dtype),
            "w": flat[C:].reshape((F, C)).astype(params["w"].dtype)}
