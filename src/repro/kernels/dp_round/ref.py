"""Oracles for the fused DP-round kernel family.

The sharded/engine DP hot loop composes, per client per round:

    per-example grad (vmap) → per-example l2 clip → accumulate → noise

For the linear softmax model (the paper's §4 client model) the per-example
gradient has a closed form — dl = softmax(logits) − onehot(y), grad_w =
xᵀ dl, grad_b = Σ dl, with per-example norm² = ‖dl‖²·(1 + ‖x‖²) — so the
whole round collapses into one kernel: two matmul passes over the batch
instead of a B-way vmapped autodiff stack plus two more passes over the
(B, D) per-example matrix.

Two oracles, used at different trust levels:

  * ``dp_round_reference`` — the existing composed pipeline itself
    (``repro.core.dp.dp_gradients``), called lazily. This IS the semantics
    the megakernel must match; tests compare against it bit-for-bit on the
    ref backend.
  * ``dp_round_closed`` — the closed-form jnp oracle the Pallas kernel is
    checked against (allclose; the closed form reorders the autodiff sums).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip.ref import add_flat_noise


def dp_round_reference(loss_fn, params, x, y, key, *, clip: float,
                       sigma: float = 0.0):
    """The composed DP pipeline, verbatim: per-example autodiff → fused
    clip/accumulate/noise through the dispatch layer. Lazy import — dp_round
    is reachable from ``repro.core.dp`` itself via the dispatch module."""
    from repro.core import dp as dp_lib
    return dp_lib.dp_gradients(loss_fn, params, {"x": x, "y": y}, key,
                               clip=clip, sigma=sigma)


def softmax_dlogits(logits, y):
    """(B, C) ∂CE/∂logits for integer labels: softmax − onehot, f32."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return p - jax.nn.one_hot(y, logits.shape[-1], dtype=jnp.float32)


def linear_grads_closed(params, x, y):
    """Closed-form per-example gradient factors for the linear model.

    Returns ``(dl, xsq)``: dl (B, C) is the logit gradient, xsq (B,) the
    per-example ‖x‖². The full per-example gradient is (dl_b, x_b ⊗ dl_b)
    with squared norm ‖dl_b‖² · (1 + ‖x_b‖²) — never materialized."""
    x32 = x.astype(jnp.float32)
    logits = x32 @ params["w"].astype(jnp.float32) + params["b"]
    dl = softmax_dlogits(logits, y)
    return dl, jnp.sum(x32 * x32, axis=-1)


def dp_round_closed(params, x, y, key=None, *, clip: float,
                    sigma: float = 0.0, denom=None):
    """Closed-form fused round in plain jnp: per-example clip scales from
    the factored norm, then two matmuls build the clipped-mean gradient.
    Noise goes through the one canonical flat-noise helper on the
    [b, w.ravel()] layout (dict-sorted leaf order) so the same key draws
    bit-identical noise to the composed pipeline."""
    B = x.shape[0]
    if denom is None:
        denom = float(B)
    dl, xsq = linear_grads_closed(params, x, y)
    norms = jnp.sqrt(jnp.sum(dl * dl, axis=-1) * (1.0 + xsq))
    scales = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12)) / denom
    sdl = dl * scales[:, None]                       # (B, C)
    b_grad = jnp.sum(sdl, axis=0)                    # (C,)
    w_grad = x.astype(jnp.float32).T @ sdl           # (F, C)
    flat = jnp.concatenate([b_grad, w_grad.ravel()])
    flat = add_flat_noise(flat, key, sigma, clip, denom)
    C = b_grad.shape[0]
    return {"b": flat[:C].astype(params["b"].dtype),
            "w": flat[C:].reshape(w_grad.shape).astype(params["w"].dtype)}
