"""Pallas kernels for DP-SGD per-example clipping (paper Eqs. 10–11 hot loop).

Two passes over the (B, D) per-example flat-gradient matrix:

  1. ``sq_norms``        — per-example Σ g², tiled over D (VMEM-resident
                           (TB, TD) tiles; fp32 accumulation into (B,) out).
  2. ``scale_accumulate``— Σ_b scale_b · g_b, tiled over (B, D); the B grid
                           axis accumulates into the (TD,) output tile.

Tiling: TD = 16k lanes (128-aligned; 8·16k·4 B ≈ 0.5 MB per tile, well under
the ~16 MB v5e VMEM even with double buffering), TB = 8 sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TB = 8
DEFAULT_TD = 16384


def _sq_norm_kernel(x_ref, out_ref):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.sum(x * x, axis=1)


@functools.partial(jax.jit, static_argnames=("tb", "td", "interpret"))
def sq_norms(x, tb: int = DEFAULT_TB, td: int = DEFAULT_TD, interpret: bool = True):
    """x: (B, D) -> per-example squared l2 norms (B,). B % tb == D % td == 0."""
    B, D = x.shape
    tb, td = min(tb, B), min(td, D)
    assert B % tb == 0 and D % td == 0, (B, tb, D, td)
    grid = (B // tb, D // td)
    return pl.pallas_call(
        _sq_norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tb, td), lambda b, d: (b, d))],
        out_specs=pl.BlockSpec((tb,), lambda b, d: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(x)


def _scale_acc_kernel(x_ref, s_ref, out_ref):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)          # (TB, TD)
    s = s_ref[...].astype(jnp.float32)          # (TB,)
    out_ref[...] += jnp.einsum("bd,b->d", x, s)


@functools.partial(jax.jit, static_argnames=("tb", "td", "interpret"))
def scale_accumulate(x, scales, tb: int = DEFAULT_TB, td: int = DEFAULT_TD,
                     interpret: bool = True):
    """x: (B, D), scales: (B,) -> Σ_b scales_b · x_b  (D,) fp32."""
    B, D = x.shape
    tb, td = min(tb, B), min(td, D)
    assert B % tb == 0 and D % td == 0, (B, tb, D, td)
    grid = (D // td, B // tb)                   # B innermost: accumulation axis
    return pl.pallas_call(
        _scale_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, td), lambda d, b: (b, d)),
            pl.BlockSpec((tb,), lambda d, b: (b,)),
        ],
        out_specs=pl.BlockSpec((td,), lambda d, b: (d,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(x, scales)
