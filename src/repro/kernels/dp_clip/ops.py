"""jit'd wrappers: per-example clipped-gradient accumulation over pytrees.

Pads (B, D) to tile multiples, runs the two Pallas passes, and maps the flat
result back onto the gradient pytree. Backend/tile selection lives in
``repro.kernels.dispatch``; these wrappers take explicit ``interpret`` /
tile arguments (interpret defaults to True so direct CPU use keeps working).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dp_clip import kernel
from repro.utils.pytree import tree_flatten_concat, tree_unflatten_concat


def _pad_to(x, mb, md):
    B, D = x.shape
    pb = (-B) % mb
    pd = (-D) % md
    if pb or pd:
        x = jnp.pad(x, ((0, pb), (0, pd)))
    return x


def clip_accumulate_flat(x, clip: float, denom: float = 1.0,
                         interpret: bool = True, tb: int = 8, td: int = 16384):
    """x: (B, D) per-example flat grads -> Σ_b clipped(g_b)/denom (D,).

    Two passes over (B, D): a norm pass and a scale-accumulate pass; the
    /denom mean is folded into the per-example scales."""
    B, D = x.shape
    td = min(td, max(128, D))
    xp = _pad_to(x, tb, td)
    sq = kernel.sq_norms(xp, tb=tb, td=td, interpret=interpret)[:B]
    norms = jnp.sqrt(sq)
    scales = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12)) / denom
    scales = jnp.pad(scales, (0, xp.shape[0] - B))
    out = kernel.scale_accumulate(xp, scales, tb=tb, td=td, interpret=interpret)
    return out[:D]


def clip_accumulate_tree(per_example_grads, clip: float, interpret: bool = True):
    """per_example_grads: pytree with leading example dim (B, ...) on every
    leaf -> pytree of Σ_b clipped(g_b) (the DP-SGD numerator of Eq. 11)."""
    flat = jax.vmap(tree_flatten_concat)(per_example_grads)      # (B, D)
    summed = clip_accumulate_flat(flat, clip, interpret=interpret)
    template = jax.tree_util.tree_map(lambda g: g[0], per_example_grads)
    return tree_unflatten_concat(summed, template)
