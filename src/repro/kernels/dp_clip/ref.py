"""Pure-jnp oracle for the dp_clip kernels."""
from __future__ import annotations

import jax.numpy as jnp


def sq_norms(x):
    return jnp.sum(jnp.square(x.astype(jnp.float32)), axis=1)


def scale_accumulate(x, scales):
    return jnp.einsum("bd,b->d", x.astype(jnp.float32), scales.astype(jnp.float32))


def clip_accumulate(x, clip: float):
    """Full fused reference: Σ_b clip(g_b) with per-example l2 clipping."""
    norms = jnp.sqrt(sq_norms(x))
    scales = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))
    return scale_accumulate(x, scales)
