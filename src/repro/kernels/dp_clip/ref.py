"""Pure-jnp oracle for the dp_clip kernels.

``dp_clip_reference`` is the fused DP-SGD reference the dispatch layer's
"ref" backend executes verbatim: the (B, D) per-example matrix is read
exactly twice (norm pass, scale-accumulate pass) and the Gaussian noise is a
single (D,) draw on the flat buffer — no per-leaf noise loop.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sq_norms(x):
    return jnp.sum(jnp.square(x.astype(jnp.float32)), axis=1)


def scale_accumulate(x, scales):
    return jnp.einsum("bd,b->d", x.astype(jnp.float32), scales.astype(jnp.float32))


def clip_accumulate(x, clip: float, denom: float = 1.0):
    """Σ_b clip(g_b)/denom with per-example l2 clipping; the /denom mean is
    folded into the per-example scales (one multiply, no extra (D,) pass)."""
    norms = jnp.sqrt(sq_norms(x))                       # read 1 of (B, D)
    scales = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12)) / denom
    return scale_accumulate(x, scales)                  # read 2 of (B, D)


def static_zero_sigma(sigma) -> bool:
    """True only for a *host* zero: a traced σ (the engine's runtime noise
    multiplier, see ``repro.engine.strategy.runtime_sigma``) is only ever
    injected on DP-on traces, so it counts as positive."""
    return isinstance(sigma, (int, float)) and not sigma


def add_flat_noise(out, key, sigma: float, clip: float, denom: float):
    """Eq. 11 noise on a flat buffer: out + (2C/denom)·σ·N(0, 1).

    THE canonical noise expression — every backend and the chunked path call
    this one helper, which is what makes the same-key draw bit-identical
    across them. sigma > 0 without a key is a silent privacy violation, so
    it raises.

    The scale is computed as an explicit float32 product so a traced σ (the
    engine's runtime argument) and a trace-baked constant σ round identically
    — the sharded/chunk-cache equivalence tests compare them bit-for-bit."""
    if static_zero_sigma(sigma):
        return out
    if key is None:
        raise ValueError("sigma > 0 requires a PRNG key (refusing to return "
                         "unnoised gradients from a DP path)")
    scale = jnp.float32(2.0 * clip / denom) * jnp.asarray(sigma, jnp.float32)
    return out + scale * jax.random.normal(key, out.shape, jnp.float32)


def dp_clip_reference(x, clip: float, key=None, *, sigma: float = 0.0,
                      denom: float = 1.0):
    """Fused flatten→norm→scale→accumulate→noise semantics on a flat (B, D)
    matrix: mean of clipped per-example gradients plus Eq. 11 noise drawn
    once on the (D,) output buffer."""
    return add_flat_noise(clip_accumulate(x, clip, denom=denom),
                          key, sigma, clip, denom)
