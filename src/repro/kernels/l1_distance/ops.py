"""jit'd wrapper for the pairwise-ℓ1 Pallas kernel.

Pads to tile multiples, runs the upper-triangle kernel, and mirrors the
result back to the full symmetric matrix (lower-triangle tiles are never
computed — halved Phase-1 grouping FLOPs)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.l1_distance import kernel


def pairwise_l1(x, interpret: bool = True, tm: int = 8, td: int = 8192):
    M, D = x.shape
    td = min(td, max(128, D))
    pm = (-M) % tm
    pd = (-D) % td
    xp = jnp.pad(x, ((0, pm), (0, pd)))
    raw = kernel.pairwise_l1(xp, tm=tm, td=td, interpret=interpret)[:M, :M]
    # mirrored write-back: unvisited lower tiles are masked out by triu, the
    # (exactly-zero) diagonal comes from the diagonal tiles themselves
    upper = jnp.triu(raw)
    return upper + jnp.triu(raw, 1).T
