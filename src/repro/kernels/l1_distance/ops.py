"""jit'd wrapper for the pairwise-ℓ1 Pallas kernel (pads to tile multiples)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.l1_distance import kernel


def pairwise_l1(x, interpret: bool = True, tm: int = 8, td: int = 8192):
    M, D = x.shape
    td = min(td, max(128, D))
    pm = (-M) % tm
    pd = (-D) % td
    xp = jnp.pad(x, ((0, pm), (0, pd)))
    out = kernel.pairwise_l1(xp, tm=tm, td=td, interpret=interpret)
    return out[:M, :M]
