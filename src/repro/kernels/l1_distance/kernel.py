"""Pallas kernel: pairwise ℓ1 distance between client weight vectors
(paper Eq. 3, Phase-1 grouping).

Grid (Mi, Mj, Dk): each step loads (TM, TD) row/col tiles and accumulates
|x_i − x_j| partial sums into the (TM, TM) output tile; the D axis is
innermost so the output tile stays VMEM-resident across the reduction.
VPU-only (abs/add) — no MXU use, which is why this beats an einsum-based
|a−b| formulation that would materialize (M, M, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TM = 8
DEFAULT_TD = 8192


def _l1_kernel(xi_ref, xj_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    xi = xi_ref[...].astype(jnp.float32)        # (TM, TD)
    xj = xj_ref[...].astype(jnp.float32)        # (TM, TD)
    out_ref[...] += jnp.sum(jnp.abs(xi[:, None, :] - xj[None, :, :]), axis=2)


@functools.partial(jax.jit, static_argnames=("tm", "td", "interpret"))
def pairwise_l1(x, tm: int = DEFAULT_TM, td: int = DEFAULT_TD, interpret: bool = True):
    """x: (M, D) -> (M, M) ℓ1 distances. M % tm == D % td == 0."""
    M, D = x.shape
    tm, td = min(tm, M), min(td, D)
    assert M % tm == 0 and D % td == 0, (M, tm, D, td)
    grid = (M // tm, M // tm, D // td)
    return pl.pallas_call(
        _l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, td), lambda i, j, k: (i, k)),
            pl.BlockSpec((tm, td), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tm, tm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, M), jnp.float32),
        interpret=interpret,
    )(x, x)
