"""Pallas kernel: pairwise ℓ1 distance between client weight vectors
(paper Eq. 3, Phase-1 grouping).

The distance matrix is symmetric, so the grid enumerates only the
T(T+1)/2 upper-triangle tile pairs (T = M/TM) via a linearized pair index —
half the FLOPs and half the HBM traffic of the rectangular (Mi, Mj) sweep.
Grid (P, Dk): each step loads (TM, TD) row/col tiles and accumulates
|x_i − x_j| partial sums into the (TM, TM) output tile; the D axis is
innermost so the output tile stays VMEM-resident across the reduction.
Lower-triangle tiles are never written — the ops wrapper mirrors the upper
triangle back (``tri + strict_tri.T``). VPU-only (abs/add) — no MXU use,
which is why this beats an einsum-based |a−b| formulation that would
materialize (M, M, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TM = 8
DEFAULT_TD = 8192


def tri_decode(p):
    """Linear pair index p -> tile coords (row, col) with row <= col.

    Enumeration: p = col·(col+1)/2 + row over the triangle. The float sqrt
    inverse is followed by an integer correction step so the decode is exact
    despite fp32 rounding (validated in tests up to ~10⁶ pairs)."""
    pf = p.astype(jnp.float32)
    c = jnp.floor((jnp.sqrt(8.0 * pf + 1.0) - 1.0) * 0.5).astype(p.dtype)
    c = jnp.where((c + 1) * (c + 2) // 2 <= p, c + 1, c)
    c = jnp.where(c * (c + 1) // 2 > p, c - 1, c)
    r = p - c * (c + 1) // 2
    return r, c


def _l1_kernel(xi_ref, xj_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    xi = xi_ref[...].astype(jnp.float32)        # (TM, TD) rows
    xj = xj_ref[...].astype(jnp.float32)        # (TM, TD) cols
    out_ref[...] += jnp.sum(jnp.abs(xi[:, None, :] - xj[None, :, :]), axis=2)


@functools.partial(jax.jit, static_argnames=("tm", "td", "interpret"))
def pairwise_l1(x, tm: int = DEFAULT_TM, td: int = DEFAULT_TD, interpret: bool = True):
    """x: (M, D) -> (M, M) with only the upper-triangle tiles written
    (mirror with the ops wrapper). M % tm == D % td == 0."""
    M, D = x.shape
    tm, td = min(tm, M), min(td, D)
    assert M % tm == 0 and D % td == 0, (M, tm, D, td)
    T = M // tm
    grid = (T * (T + 1) // 2, D // td)          # D innermost: reduction axis
    return pl.pallas_call(
        _l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, td), lambda p, k: (tri_decode(p)[0], k)),
            pl.BlockSpec((tm, td), lambda p, k: (tri_decode(p)[1], k)),
        ],
        out_specs=pl.BlockSpec((tm, tm), lambda p, k: tri_decode(p)),
        out_shape=jax.ShapeDtypeStruct((M, M), jnp.float32),
        interpret=interpret,
    )(x, x)
