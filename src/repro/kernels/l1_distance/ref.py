"""Pure-jnp oracle for the l1_distance kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_l1(x):
    """x: (M, D) -> (M, M), row-blocked to avoid (M, M, D)."""
    def row(w):
        return jnp.sum(jnp.abs(x.astype(jnp.float32) - w.astype(jnp.float32)[None, :]),
                       axis=-1)
    return jax.lax.map(row, x)
