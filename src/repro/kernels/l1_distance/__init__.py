from repro.kernels.l1_distance import ops, ref
