"""Per-architecture smoke tests (brief §f): a REDUCED variant of each family
runs one forward + one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import ARCHITECTURES, get_config, get_reduced_config
from repro.models.api import build_model, make_train_step
from repro.utils.pytree import param_count


def _batch(cfg, key, b=2, s=32):
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        batch["codes"] = jax.random.randint(key, (b, s, cfg.audio_codebooks),
                                            0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        from repro.models.frontends import synth_mrope_positions, synth_vision_embeds
        batch["vision_embeds"] = synth_vision_embeds(key, cfg, b)
        batch["mrope_positions"] = synth_mrope_positions(cfg, b, s)
    return batch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_reduced_forward_and_train_step(arch, key):
    cfg = get_reduced_config(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    if cfg.moe.num_experts:
        assert cfg.moe.num_experts <= 4
    api = build_model(cfg)
    params = api.init(key)
    batch = _batch(cfg, key)

    loss, metrics = jax.jit(api.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    train_step, opt = make_train_step(api, TrainConfig(learning_rate=1e-3,
                                                       warmup_steps=1,
                                                       total_steps=10))
    opt_state = opt.init(params)
    new_params, new_opt, m = jax.jit(train_step)(params, opt_state, batch)
    # parameters moved, no NaNs anywhere
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_full_config_metadata(arch):
    """The FULL configs carry the exact assigned dimensions + citation."""
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.source, f"{arch} missing source citation"
    assert cfg.num_layers >= 12
    # exact assigned dims (spot checks across the table)
    table = {
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }
    L, d, h, kv, ff, V = table[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, V)


def test_llama_1b_param_count(key):
    """llama3.2-1b full config should land near its nominal 1.24B params."""
    cfg = get_config("llama3.2-1b")
    api = build_model(cfg)
    n = 0
    import numpy as np
    from repro.models.module import is_spec
    for _, s in jax.tree_util.tree_flatten_with_path(api.specs, is_leaf=is_spec)[0]:
        n += int(np.prod(s.shape))
    assert 1.1e9 < n < 1.4e9, n
