"""Sharded-vs-single-device equivalence scenarios, executed as a subprocess
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` by
``tests/test_sharded_engine.py`` (the flag must be set before the first jax
init, hence the process boundary — same recipe as the mini dry-run).

Prints ONE JSON object: scenario name -> equivalence record. The host-side
tests assert on the records, so a failure names the exact scenario."""
from __future__ import annotations

import json
import sys


def _leaves(tree):
    import jax
    import numpy as np
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


def tree_bit_equal(a, b) -> bool:
    import numpy as np
    return all(np.array_equal(x, y) for x, y in zip(_leaves(a), _leaves(b)))


def tree_maxdiff(a, b) -> float:
    import numpy as np
    return float(max(np.max(np.abs(x.astype(np.float64) - y.astype(np.float64)))
                     for x, y in zip(_leaves(a), _leaves(b))))


def main() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import topology as topo_lib
    from repro.baselines.dp_dsgt import DPDSGTStrategy
    from repro.baselines.fedavg import FedAvgStrategy
    from repro.baselines.local import LocalStrategy
    from repro.baselines.proxyfl import ProxyFLStrategy
    from repro.baselines.scaffold import ScaffoldStrategy
    from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
    from repro.core.p2p import P2PNetwork
    from repro.core.p4 import P4Strategy, P4Trainer
    from repro.engine import (AsyncStaleness, ClientSampling, ClientShardCtx,
                              Engine, FederatedData, ShardedEngine)
    from repro.launch.mesh import make_client_mesh
    from repro.topology.mixing import (edges_shard_resident, make_plan,
                                       mix_stats_snapshot, reset_mix_stats)

    assert len(jax.devices()) == 8, jax.devices()
    mesh8 = make_client_mesh()
    results = {"devices": len(jax.devices())}

    rng = np.random.default_rng(0)
    M, feat, classes, n = 8, 12, 3, 32
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, n))
    xs = protos[ys] + rng.normal(size=(M, n, feat)).astype(np.float32) * 0.4
    X, Y = xs, ys.astype(np.int32)
    data8 = FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))
    data6 = FederatedData(X[:6], Y[:6], jnp.asarray(X[:6]), jnp.asarray(Y[:6]))
    key = jax.random.PRNGKey(0)

    def compare(name, mk_strategy, schedule=None, data=data8, rounds=8,
                batch=8, mesh=mesh8, faults=None):
        mk_sched = schedule if schedule is not None else (lambda: None)
        mk_faults = faults if faults is not None else (lambda: None)
        st1, h1 = Engine(mk_strategy(), eval_every=3, schedule=mk_sched(),
                         faults=mk_faults()).fit(
            data, rounds=rounds, key=key, batch_size=batch)
        # collective probe: trace-time counts over the sharded run only (the
        # single-device mix never touches MIX_STATS). Counts are per chunk
        # trace, so "0 gathers" is asserted as all_gathers == 0 outright.
        reset_mix_stats()
        st2, h2 = ShardedEngine(mk_strategy(), eval_every=3, mesh=mesh,
                                schedule=mk_sched(), faults=mk_faults()).fit(
            data, rounds=rounds, key=key, batch_size=batch)
        results[name] = {
            "mix_stats": mix_stats_snapshot(),
            "rounds_equal": h1.rounds == h2.rounds,
            "accuracy_bit_equal": h1.accuracy == h2.accuracy,
            "accuracy_maxdiff": float(max(abs(a - b) for a, b in
                                          zip(h1.accuracy, h2.accuracy))),
            "metrics_maxdiff": float(max(
                (max(abs(p - q) for p, q in zip(v, h2.metrics[k]))
                 for k, v in h1.metrics.items()), default=0.0)),
            "state_bit_equal": tree_bit_equal(st1, st2),
            "state_maxdiff": tree_maxdiff(st1, st2),
        }

    dp = DPConfig(clip_norm=1.0)
    compare("local_full", lambda: LocalStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, dp_cfg=dp, sigma=0.7))
    compare("local_full_uneven", lambda: LocalStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, dp_cfg=dp, sigma=0.7),
        data=data6)
    compare("local_sampling_uneven", lambda: LocalStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5),
        schedule=lambda: ClientSampling(q=0.5), data=data6)

    # the gather reduction keeps the strict bit-exact contract; the default
    # psum tree-reduction is verified separately (tolerance + vs-gather)
    compare("fedavg_full", lambda: FedAvgStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.5,
        user_ratio=0.8, reduce="gather"))
    compare("fedavg_sampling", lambda: FedAvgStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4,
        reduce="gather"),
        schedule=lambda: ClientSampling(q=0.6))
    compare("fedavg_async0", lambda: FedAvgStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4,
        reduce="gather"),
        schedule=lambda: AsyncStaleness(staleness=0))

    # psum-tree cohort reduction (the default): bit-close to single-device
    # and to the gather path on the same mesh
    compare("fedavg_psum_full", lambda: FedAvgStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.5,
        user_ratio=0.8))
    compare("fedavg_psum_sampling", lambda: FedAvgStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4),
        schedule=lambda: ClientSampling(q=0.6))

    def fedavg_sharded(reduce):
        strat = FedAvgStrategy(feat_dim=feat, num_classes=classes, lr=0.5,
                               clip=1.0, sigma=0.5, user_ratio=0.8,
                               reduce=reduce)
        return ShardedEngine(strat, eval_every=3, mesh=mesh8).fit(
            data8, rounds=8, key=key, batch_size=8)

    st_p, h_p = fedavg_sharded("psum")
    st_g, h_g = fedavg_sharded("gather")
    results["fedavg_psum_vs_gather"] = {
        "rounds_equal": h_p.rounds == h_g.rounds,
        "accuracy_maxdiff": float(max(abs(a - b) for a, b in
                                      zip(h_p.accuracy, h_g.accuracy))),
        "state_maxdiff": tree_maxdiff(st_p, st_g),
    }

    # ---------------- scaffold / proxyfl: sharded-hook ports ----------------
    compare("scaffold_full", lambda: ScaffoldStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4))
    compare("scaffold_sampling", lambda: ScaffoldStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4),
        schedule=lambda: ClientSampling(q=0.6))
    compare("scaffold_uneven", lambda: ScaffoldStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4),
        data=data6)
    compare("proxyfl_full", lambda: ProxyFLStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4))
    compare("proxyfl_uneven", lambda: ProxyFLStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4),
        data=data6)

    compare("dsgt_full", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5))
    compare("dsgt_full_uneven", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5),
        data=data6)
    compare("dsgt_sampling", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.4),
        schedule=lambda: ClientSampling(q=0.5))
    compare("dsgt_async2", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.4),
        schedule=lambda: AsyncStaleness(staleness=2))

    # -------------- topology subsystem: non-ring graphs + faults ------------
    # ISSUE 5 acceptance: a non-ring topology (4-regular circulant expander,
    # edges cross every slice boundary → the gather mixing path) and a faulty
    # run (drop + churn drawn in-jit, replicated across slices)
    expander = topo_lib.k_regular(M, 4)
    compare("dsgt_topology_expander", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=expander))
    compare("dsgt_topology_faulty", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=expander.with_faults(0.25, 0.1)))
    compare("dsgt_gossip_sequence", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=topo_lib.gossip_matchings(M, period=4, seed=0)))

    # ISSUE 7: banded topologies must stay gather-free on the sharded path —
    # keep-masked / i.i.d.-faulty rings route through the halo exchange
    # (dropped mass folds into the diagonal locally, no collective), and the
    # torus rides the general bounded-bandwidth halo schedule
    compare("dsgt_ring_faulty", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=topo_lib.ring(M).with_faults(0.25, 0.1)))
    from repro.resilience import (FaultModel, gilbert_elliott_rates,
                                  make_fault_process)
    ge_fail, ge_repair = gilbert_elliott_rates(0.3, 3.0)
    compare("dsgt_ring_burst", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=topo_lib.ring(M)),
        faults=lambda: make_fault_process(
            FaultModel(link_fail=ge_fail, link_repair=ge_repair), M))
    compare("dsgt_torus", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=topo_lib.torus(4, 2)))

    # ISSUE 9: learned directed graphs mix via push-sum — the weight scalar
    # rides the x mix as a joint leaf, so the sharded lowering must stay
    # equivalent through the same halo/gather path selection. One static
    # estimate, one faulted (the sender-side diagonal fold), and one
    # time-varying two-estimate window.
    lrn_rng = np.random.default_rng(7)
    learner = topo_lib.GraphLearner(M=M, k=3, sigma_dist=0.5, seed=7)
    learned_a = learner.estimate(
        lrn_rng.normal(size=(M, 24)).astype(np.float32))
    learner.estimate(lrn_rng.normal(size=(M, 24)).astype(np.float32))
    learned_tv = learner.current(window=2)
    assert make_plan(learned_a).push_sum
    compare("dsgt_learned_pushsum", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=learned_a))
    compare("dsgt_learned_faulty", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=learned_a.with_faults(0.25, 0.1)))
    compare("dsgt_learned_timevarying", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=learned_tv))

    # shard-resident topology on a 2-slice mesh: the mix needs no collective
    mesh2_t = make_client_mesh(2)
    resident_topo = topo_lib.group_clustered([[0, 1, 2, 3], [4, 5, 6, 7]], M,
                                             bridge=False)
    results["topology_resident_layout"] = {
        "resident_on_2": edges_shard_resident(
            make_plan(resident_topo), ClientShardCtx(mesh2_t, "clients", M)),
        "resident_on_8": edges_shard_resident(
            make_plan(resident_topo), ClientShardCtx(mesh8, "clients", M)),
    }
    compare("dsgt_topology_resident", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=resident_topo), mesh=mesh2_t)
    compare("dsgt_topology_resident_faulty", lambda: DPDSGTStrategy(
        feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
        topology=resident_topo.with_faults(0.3, 0.0)), mesh=mesh2_t)

    # ---------------- P4: strategy-level (fixed groups) across schedules ----
    def p4_cfg(rounds=8):
        return RunConfig(dp=DPConfig(epsilon=15.0, rounds=rounds,
                                     sample_rate=0.5),
                         p4=P4Config(group_size=4, sample_peers=7),
                         train=TrainConfig(learning_rate=0.5))

    def mk_p4(groups, topology=None):
        def mk():
            strat = P4Strategy(trainer=P4Trainer(feat_dim=feat,
                                                 num_classes=classes,
                                                 cfg=p4_cfg()))
            strat.set_groups([list(g) for g in groups], M)
            if topology is not None:
                strat.set_topology(topology)
            return strat
        return mk

    spanning = [[0, 2, 4, 6], [1, 3, 5, 7]]   # every group spans 4 slices
    compare("p4_full_gather", mk_p4(spanning))
    compare("p4_sampling", mk_p4(spanning),
            schedule=lambda: ClientSampling(q=0.5))
    compare("p4_async1", mk_p4(spanning),
            schedule=lambda: AsyncStaleness(staleness=1))

    # pod-resident groups on a 2-slice mesh: aggregation needs no collective
    mesh2 = make_client_mesh(2)
    resident = [[0, 1, 2, 3], [4, 5, 6, 7]]
    probe = mk_p4(resident)()
    ctx2 = ClientShardCtx(mesh2, "clients", M)
    results["p4_resident_layout"] = {
        "resident_on_2": probe._groups_shard_resident(ctx2),
        "resident_on_8": probe._groups_shard_resident(
            ClientShardCtx(mesh8, "clients", M)),
    }
    compare("p4_full_resident", mk_p4(resident), mesh=mesh2)
    compare("p4_sampling_resident", mk_p4(resident), mesh=mesh2,
            schedule=lambda: ClientSampling(q=0.5))

    # fault-injected P4: member↔aggregator links drop in-jit; the resident
    # layout slices the replicated fault mask, the spanning one gathers
    p4_fault_topo = topo_lib.group_clustered(
        [list(g) for g in resident], M).with_faults(0.3, 0.1)
    compare("p4_faulty_resident", mk_p4(resident, p4_fault_topo), mesh=mesh2)
    compare("p4_faulty_gather", mk_p4(spanning, topo_lib.group_clustered(
        [list(g) for g in spanning], M).with_faults(0.3, 0.1)))

    # -------- resilience: correlated fault regimes, sharded ≡ single --------
    # the FaultState carry is replicated across slices (every shard steps the
    # identical Markov transition from the replicated phase key), so every
    # regime must realize the same masks on both layouts
    regimes = {
        "burst": FaultModel(link_fail=ge_fail, link_repair=ge_repair),
        "churn": FaultModel(node_fail=0.25, node_repair=0.4),
        "partition": FaultModel(partition_prob=0.25, partition_repair=0.3),
    }
    for rname, fm in regimes.items():
        compare(f"dsgt_fault_{rname}", lambda: DPDSGTStrategy(
            feat_dim=feat, num_classes=classes, lr=0.3, clip=1.0, sigma=0.5,
            topology=expander),
            faults=lambda: make_fault_process(fm, M))

    straggler = FaultModel(slow_enter=0.3, slow_exit=0.5)
    compare("fedavg_fault_straggler", lambda: FedAvgStrategy(
        feat_dim=feat, num_classes=classes, lr=0.5, clip=1.0, sigma=0.4,
        reduce="gather"),
        schedule=lambda: AsyncStaleness(staleness=1),
        faults=lambda: make_fault_process(straggler, M))
    compare("p4_fault_straggler", mk_p4(spanning),
            schedule=lambda: AsyncStaleness(staleness=1),
            faults=lambda: make_fault_process(straggler, M))

    # failover under combined faults + quorum, on the pod-resident layout
    # (the sliced reach mask) and the gather layout
    failover_fm = FaultModel(link_fail=ge_fail, link_repair=ge_repair,
                             node_fail=0.3, node_repair=0.4, quorum=0.5)
    compare("p4_fault_failover_resident", mk_p4(resident), mesh=mesh2,
            faults=lambda: make_fault_process(failover_fm, M))
    compare("p4_fault_failover_gather", mk_p4(spanning),
            faults=lambda: make_fault_process(failover_fm, M))

    # -------- paged cohorts: PagedEngine ≡ resident Engine (ISSUE 8) -------
    # the host-resident population with paged cohorts must be bit-exact with
    # the resident engine — state AND History — across every strategy ×
    # schedule, including uneven cohort sizes (M=6, fixed-k and Bernoulli
    # draws) and a correlated fault regime. P4's train-loss means under
    # sampling are the one documented difference (cohort mean vs the
    # resident's full-M mean) and are excluded, not asserted loosely.
    from repro.engine.population import PagedEngine

    def compare_paged(name, mk_strategy, schedule=None, data=data8, rounds=8,
                      batch=8, faults=None, mesh=None, exclude_metrics=()):
        mk_sched = schedule if schedule is not None else (lambda: None)
        mk_faults = faults if faults is not None else (lambda: None)
        st1, h1 = Engine(mk_strategy(), eval_every=3, schedule=mk_sched(),
                         faults=mk_faults()).fit(
            data, rounds=rounds, key=key, batch_size=batch)
        st2, h2 = PagedEngine(mk_strategy(), eval_every=3,
                              schedule=mk_sched(), faults=mk_faults(),
                              mesh=mesh).fit(
            data, rounds=rounds, key=key, batch_size=batch)
        excl = set(exclude_metrics)
        results[name] = {
            "rounds_equal": h1.rounds == h2.rounds,
            "accuracy_bit_equal": h1.accuracy == h2.accuracy,
            "accuracy_maxdiff": float(max(abs(a - b) for a, b in
                                          zip(h1.accuracy, h2.accuracy))),
            "metrics_bit_equal": all(v == h2.metrics.get(k)
                                     for k, v in h1.metrics.items()
                                     if k not in excl),
            "excluded_maxdiff": float(max(
                (max(abs(p - q) for p, q in zip(h1.metrics[k], h2.metrics[k]))
                 for k in excl), default=0.0)),
            "state_bit_equal": tree_bit_equal(st1, st2),
            "state_maxdiff": tree_maxdiff(st1, st2),
        }

    def mk_fedavg(sigma=0.4):
        return lambda: FedAvgStrategy(feat_dim=feat, num_classes=classes,
                                      lr=0.5, clip=1.0, sigma=sigma)

    def mk_dsgt(topology=None):
        return lambda: DPDSGTStrategy(feat_dim=feat, num_classes=classes,
                                      lr=0.3, clip=1.0, sigma=0.4,
                                      topology=topology)

    compare_paged("paged_fedavg_full", mk_fedavg(0.5))
    compare_paged("paged_fedavg_sampling_uneven", mk_fedavg(),
                  schedule=lambda: ClientSampling(q=0.6), data=data6)
    compare_paged("paged_fedavg_bernoulli", mk_fedavg(),
                  schedule=lambda: ClientSampling(q=0.5, mode="bernoulli"))
    compare_paged("paged_fedavg_async0", mk_fedavg(),
                  schedule=lambda: AsyncStaleness(staleness=0))
    compare_paged("paged_dsgt_full", mk_dsgt())
    compare_paged("paged_dsgt_sampling", mk_dsgt(),
                  schedule=lambda: ClientSampling(q=0.5))
    compare_paged("paged_dsgt_sampling_uneven", mk_dsgt(),
                  schedule=lambda: ClientSampling(q=0.5), data=data6)
    compare_paged("paged_dsgt_async2", mk_dsgt(),
                  schedule=lambda: AsyncStaleness(staleness=2))
    # non-ring graph: the cohort closure pages in every in-neighbor and the
    # paged mix resolves reads through the slot map's general path
    compare_paged("paged_dsgt_expander_sampling", mk_dsgt(expander),
                  schedule=lambda: ClientSampling(q=0.5))
    compare_paged("paged_p4_full", mk_p4(spanning))
    compare_paged("paged_p4_sampling", mk_p4(spanning),
                  schedule=lambda: ClientSampling(q=0.5),
                  exclude_metrics=("private_loss", "proxy_loss"))
    compare_paged("paged_p4_async1", mk_p4(spanning),
                  schedule=lambda: AsyncStaleness(staleness=1))
    # correlated fault regime: the fault carry is host-replicated and full-M,
    # the planned cohort is a superset of realized participants (faults only
    # remove clients), so the paged run realizes the identical masks
    compare_paged("paged_fedavg_sampling_faulty", mk_fedavg(),
                  schedule=lambda: ClientSampling(q=0.6),
                  faults=lambda: make_fault_process(
                      FaultModel(node_fail=0.25, node_repair=0.4), M))
    # cohort axis sharded over the clients mesh (GSPMD partitioning of the
    # paged chunk): numerically tight, not bit-exact — partitioned
    # reductions reassociate
    compare_paged("paged_mesh_fedavg_sampling", mk_fedavg(),
                  schedule=lambda: ClientSampling(q=0.6), mesh=mesh8)

    # -------- telemetry: off ≡ never-constructed, tap-on ≡ untapped ---------
    # ISSUE 10 zero-overhead-off contract on the sharded path: a disabled
    # Telemetry must leave the chunk-cache key and every result bit-exact;
    # an ENABLED tap must too (the sharded trace stays tap-free — per-round
    # events stream host-side from the stacked chunk outputs)
    import tempfile

    from repro.obs import Telemetry

    def mk_tel_strat():
        return LocalStrategy(feat_dim=feat, num_classes=classes, lr=0.5,
                             dp_cfg=dp, sigma=0.7)

    st_ref, h_ref = Engine(mk_tel_strat(), eval_every=3).fit(
        data8, rounds=8, key=key, batch_size=8)
    eng_plain = ShardedEngine(mk_tel_strat(), eval_every=3, mesh=mesh8)
    eng_off = ShardedEngine(mk_tel_strat(), eval_every=3, mesh=mesh8,
                            telemetry=Telemetry(None, tap=True))
    tap_dir = tempfile.mkdtemp(prefix="obs_equiv_")
    tel_on = Telemetry(tap_dir, tap=True)
    eng_on = ShardedEngine(mk_tel_strat(), eval_every=3, mesh=mesh8,
                           telemetry=tel_on)
    keys_equal = (eng_plain._chunk_key(8, 8) == eng_off._chunk_key(8, 8)
                  == eng_on._chunk_key(8, 8))
    st_off, h_off = eng_off.fit(data8, rounds=8, key=key, batch_size=8)
    st_on, h_on = eng_on.fit(data8, rounds=8, key=key, batch_size=8)
    tel_on.close()
    with open(tel_on.events_path) as f:
        tap_rounds = sorted(json.loads(line)["round"] for line in f
                            if line.strip()
                            and json.loads(line).get("type") == "tap")
    results["telemetry_off_sharded"] = {
        "chunk_key_unchanged": bool(keys_equal),
        "rounds_equal": h_ref.rounds == h_off.rounds == h_on.rounds,
        "accuracy_bit_equal": (h_ref.accuracy == h_off.accuracy
                               == h_on.accuracy),
        "state_bit_equal": (tree_bit_equal(st_ref, st_off)
                            and tree_bit_equal(st_ref, st_on)),
        "state_maxdiff": max(tree_maxdiff(st_ref, st_off),
                             tree_maxdiff(st_ref, st_on)),
        "tap_rounds": tap_rounds,
    }

    # ---------------- P4 end-to-end: bootstrap -> grouping -> co-train ------
    protos2 = rng.normal(size=(2, 4, 20)).astype(np.float32) * 2
    protos2[0, :, 10:] = 0
    protos2[1, :, :10] = 0
    e_xs, e_ys = [], []
    for c in range(M):
        y = rng.integers(0, 4, 48)
        e_xs.append(protos2[c % 2, y]
                    + rng.normal(size=(48, 20)).astype(np.float32) * 0.5)
        e_ys.append(y)
    EX = np.stack(e_xs)
    EY = np.stack(e_ys).astype(np.int32)

    def p4_e2e(mesh):
        tr = P4Trainer(feat_dim=20, num_classes=4, cfg=RunConfig(
            dp=DPConfig(epsilon=15.0, rounds=12, sample_rate=0.5),
            p4=P4Config(group_size=4, sample_peers=7),
            train=TrainConfig(learning_rate=0.5)))
        st, groups, hist = tr.fit(EX, EY, jnp.asarray(EX), jnp.asarray(EY),
                                  rounds=12, eval_every=5, mesh=mesh)
        return st, groups, hist

    st1, g1, h1 = p4_e2e(None)
    st2, g2, h2 = p4_e2e(mesh8)
    results["p4_end_to_end"] = {
        "groups_equal": g1 == g2,
        "rounds_equal": h1.rounds == h2.rounds,
        "accuracy_bit_equal": h1.accuracy == h2.accuracy,
        "state_bit_equal": tree_bit_equal(st1, st2),
        "metrics_maxdiff": float(max(
            max(abs(p - q) for p, q in zip(v, h2.metrics[k]))
            for k, v in h1.metrics.items())),
    }

    # ---------------- zero-byte accounting for absent clients ---------------
    def p4_net(mesh):
        net = P2PNetwork(M)
        strat = mk_p4(resident)()
        eng_cls = (lambda **kw: ShardedEngine(strat, mesh=mesh, **kw)) \
            if mesh is not None else (lambda **kw: Engine(strat, **kw))
        eng = eng_cls(eval_every=3, network=net,
                      schedule=ClientSampling(q=0.5))
        eng.fit(data8, rounds=8, key=key, batch_size=8)
        return net

    net1, net2 = p4_net(None), p4_net(mesh8)
    sched = ClientSampling(q=0.5)
    _, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))
    masks = {r: np.asarray(sched.draw_mask(
        jax.random.fold_in(jax.random.fold_in(phase_key, r), 3), M))
        for r in range(8)}
    results["zero_byte_accounting"] = {
        "messages_equal": net1.num_messages() == net2.num_messages(),
        "bytes_equal": net1.total_bytes() == net2.total_bytes(),
        "nonzero": net2.num_messages() > 0,
        "endpoints_in_cohort": all(
            masks[m.rnd][m.src] == 1.0 and masks[m.rnd][m.dst] == 1.0
            for m in net2.log),
    }

    print(json.dumps(results))


if __name__ == "__main__":
    sys.exit(main())
