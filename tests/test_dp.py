"""DP machinery: Eqs. 10–12, accountant, per-example vs microbatch grads."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp as dp_lib
from repro.utils.pytree import global_norm


def test_clip_bounds_norm(key):
    tree = {"a": jax.random.normal(key, (8, 8)) * 10, "b": jnp.ones((3,)) * 5}
    clipped, norm = dp_lib.clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    small = jax.tree_util.tree_map(lambda t: t * 1e-3, tree)
    clipped2, _ = dp_lib.clip_by_global_norm(small, 1.0)
    # below the clip, gradients pass through unchanged
    np.testing.assert_allclose(np.asarray(clipped2["a"]), np.asarray(small["a"]),
                               rtol=1e-6)


def test_noble_sigma_eq12_formula():
    """σ_g = s·sqrt(l T K log(2Tl/δ) log(2/δ)) / (ε sqrt(M'))."""
    eps, delta, s, T, K = 15.0, 1e-3, 0.5, 100, 2
    got = dp_lib.noble_sigma(eps, delta, sample_rate=s, rounds=T, local_steps=K)
    want = s * math.sqrt(1 * T * K * math.log(2 * T / delta)
                         * math.log(2 / delta)) / eps
    assert abs(got - want) < 1e-9
    # tighter ε ⇒ more noise; more rounds ⇒ more noise
    assert dp_lib.noble_sigma(3.0, delta, rounds=T) > got
    assert dp_lib.noble_sigma(eps, delta, rounds=4 * T) > got


def test_rdp_accountant_monotone():
    e1 = dp_lib.rdp_epsilon(sigma=2.0, q=0.1, steps=100, delta=1e-5)
    e2 = dp_lib.rdp_epsilon(sigma=4.0, q=0.1, steps=100, delta=1e-5)
    e3 = dp_lib.rdp_epsilon(sigma=2.0, q=0.1, steps=400, delta=1e-5)
    assert e2 < e1 < e3


def test_calibrate_sigma_achieves_target():
    target = 8.0
    sigma = dp_lib.calibrate_sigma(target, 1e-5, q=0.2, steps=200)
    eps = dp_lib.rdp_epsilon(sigma, 0.2, 200, 1e-5)
    assert eps <= target + 1e-2
    # not absurdly conservative either
    eps_lo = dp_lib.rdp_epsilon(sigma * 0.8, 0.2, 200, 1e-5)
    assert eps_lo > target


def _quad_loss(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)


def test_dp_gradients_zero_noise_matches_clipped_mean(key):
    params = {"w": jax.random.normal(key, (4, 2))}
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 4)) * 3
    y = jax.random.normal(jax.random.fold_in(key, 2), (8, 2))
    g = dp_lib.dp_gradients(_quad_loss, params, {"x": x, "y": y},
                            jax.random.fold_in(key, 3), clip=0.1, sigma=0.0)
    # per-example clipped mean: norm of the mean must be <= clip
    assert float(global_norm(g)) <= 0.1 + 1e-6


def test_dp_gradients_sensitivity_bound(key):
    """Core DP invariant: swapping ONE example changes the (pre-noise)
    clipped-mean gradient by at most 2C/n in l2."""
    n, C = 16, 0.5
    params = {"w": jax.random.normal(key, (4, 2))}
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, 4)) * 5
    y = jax.random.normal(jax.random.fold_in(key, 2), (n, 2))
    x2 = x.at[0].set(-x[0] * 7)
    y2 = y.at[0].set(y[0] + 11)
    g1 = dp_lib.dp_gradients(_quad_loss, params, {"x": x, "y": y},
                             key, clip=C, sigma=0.0)
    g2 = dp_lib.dp_gradients(_quad_loss, params, {"x": x2, "y": y2},
                             key, clip=C, sigma=0.0)
    diff = jax.tree_util.tree_map(lambda a, b: a - b, g1, g2)
    assert float(global_norm(diff)) <= 2 * C / n + 1e-6


def test_dp_gradients_noise_statistics(key):
    """Eq. 11 noise scale: std ≈ 2Cσ/n on each coordinate."""
    params = {"w": jnp.zeros((1, 1))}
    batch = {"x": jnp.zeros((4, 1)), "y": jnp.zeros((4, 1))}
    C, sigma, n = 1.0, 3.0, 4
    samples = []
    for i in range(300):
        g = dp_lib.dp_gradients(_quad_loss, params, batch,
                                jax.random.fold_in(key, i), clip=C, sigma=sigma)
        samples.append(float(g["w"][0, 0]))
    std = np.std(samples)
    expect = 2 * C * sigma / n
    assert 0.8 * expect < std < 1.2 * expect


def test_microbatch_matches_per_example_when_mb_is_1(key):
    """microbatches == n reduces to per-example clipping."""
    n = 8
    params = {"w": jax.random.normal(key, (3, 2))}
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, 3)) * 4
    y = jax.random.normal(jax.random.fold_in(key, 2), (n, 2))
    k = jax.random.fold_in(key, 3)
    g_pe = dp_lib.dp_gradients(_quad_loss, params, {"x": x, "y": y}, k,
                               clip=0.3, sigma=0.0, microbatches=0)
    g_mb = dp_lib.dp_gradients(_quad_loss, params, {"x": x, "y": y}, k,
                               clip=0.3, sigma=0.0, microbatches=n)
    np.testing.assert_allclose(np.asarray(g_pe["w"]), np.asarray(g_mb["w"]),
                               rtol=1e-5, atol=1e-6)
