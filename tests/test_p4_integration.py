"""End-to-end P4 behaviour (paper claims, miniature scale):
  - co-training + grouping trains to high per-client accuracy under DP;
  - similarity grouping matches clients with the same task;
  - group aggregation mixes proxies within (and only within) groups;
  - the LM-scale P4 step runs and decreases loss.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import DPConfig, P4Config, RunConfig, TrainConfig, replace
from repro.core.grouping import group_ids
from repro.core.p4 import P4Trainer, group_mean, make_p4_lm_step


def _toy_tasks(M=8, feat=20, classes=4, n=64, seed=0):
    """M clients, 2 task types: task A uses dims [0:10], task B dims [10:20]."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(2, classes, feat)).astype(np.float32) * 2
    protos[0, :, feat // 2:] = 0
    protos[1, :, : feat // 2] = 0
    xs, ys = [], []
    for c in range(M):
        task = c % 2
        y = rng.integers(0, classes, n)
        x = protos[task, y] + rng.normal(size=(n, feat)).astype(np.float32) * 0.5
        xs.append(x)
        ys.append(y)
    return np.stack(xs), np.stack(ys).astype(np.int32)


def _run_cfg(**kw):
    dp = kw.pop("dp", DPConfig(epsilon=15.0, rounds=40, sample_rate=0.5,
                               clip_norm=1.0))
    p4 = kw.pop("p4", P4Config(group_size=4, sample_peers=7))
    return RunConfig(dp=dp, p4=p4, train=TrainConfig(learning_rate=0.5), **kw)


def test_p4_trains_under_dp(key):
    xs, ys = _toy_tasks()
    trainer = P4Trainer(feat_dim=20, num_classes=4, cfg=_run_cfg())
    states, groups, hist = trainer.fit(xs, ys, jnp.asarray(xs), jnp.asarray(ys),
                                       rounds=40, eval_every=39)
    assert hist[-1][1] > 0.8, hist


def test_grouping_matches_tasks(key):
    """Clients with the same task type should end up grouped together."""
    xs, ys = _toy_tasks(M=8)
    trainer = P4Trainer(feat_dim=20, num_classes=4, cfg=_run_cfg())
    states = trainer.init_clients(key, 8)
    xb, yb = jnp.asarray(xs[:, :32]), jnp.asarray(ys[:, :32])
    for r in range(5):   # a few rounds so weights reflect the tasks
        states, _ = trainer.local_round(states, xb, yb, jax.random.fold_in(key, r))
    groups = trainer.form_groups(states, seed=0)
    for g in groups:
        tasks = {i % 2 for i in g}
        assert len(tasks) == 1, f"mixed group {g} (groups={groups})"


def test_aggregation_group_internal(key):
    M = 6
    tree = {"w": jax.random.normal(key, (M, 4))}
    ids = jnp.asarray([0, 0, 0, 1, 1, 1])
    out = group_mean(tree, ids, 2)
    # within-group equality
    np.testing.assert_allclose(np.asarray(out["w"][0]), np.asarray(out["w"][2]),
                               rtol=1e-6)
    # across groups different
    assert float(jnp.max(jnp.abs(out["w"][0] - out["w"][3]))) > 1e-3


def test_private_model_never_noised(key):
    """With lr applied only via DP path on the proxy, the private model of a
    zero-beta client trained on zero gradients must stay put."""
    xs, ys = _toy_tasks(M=4)
    cfg = _run_cfg(dp=DPConfig(epsilon=3.0, rounds=5, sample_rate=0.5,
                               clip_norm=1.0))
    trainer = P4Trainer(feat_dim=20, num_classes=4, cfg=cfg)
    states = trainer.init_clients(key, 4)
    # proxy params change under DP noise even with zero-information batches;
    # private params move only via clean gradients
    xb = jnp.zeros((4, 16, 20))
    yb = jnp.zeros((4, 16), jnp.int32)
    new_states, _ = trainer.local_round(states, xb, yb, key)
    dp_moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        states["proxy"], new_states["proxy"])
    assert max(jax.tree_util.tree_leaves(dp_moved)) > 0  # noise moved proxy


def test_p4_lm_step_runs_and_loss_finite(key):
    from repro.configs import get_reduced_config
    from repro.models.api import build_model
    from repro.optim import make_optimizer
    cfg = get_reduced_config("llama3.2-1b")
    api = build_model(cfg)
    G, b, s = 2, 2, 32
    train_cfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    step = make_p4_lm_step(api, api, train_cfg,
                           DPConfig(epsilon=15.0, microbatches=2, rounds=10),
                           P4Config())
    opt = make_optimizer(train_cfg)
    params = {"private": jax.vmap(api.init)(jax.random.split(key, G)),
              "proxy": jax.vmap(api.init)(jax.random.split(jax.random.fold_in(key, 1), G))}
    opt_states = {"private": jax.vmap(opt.init)(params["private"]),
                  "proxy": jax.vmap(opt.init)(params["proxy"])}
    tokens = jax.random.randint(key, (G, b, s), 0, cfg.vocab_size)
    params, opt_states, metrics = jax.jit(step)(params, opt_states,
                                                {"tokens": tokens}, key)
    assert bool(jnp.isfinite(metrics["loss"]))
    for leaf in jax.tree_util.tree_leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
