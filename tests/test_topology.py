"""Topology-subsystem invariants (ISSUE 5), extending the
``_hypothesis_compat`` property tier:

  * every builder's mixing matrix is symmetric doubly stochastic;
  * every (connectivity-ensuring) builder returns a connected graph;
  * the spectral gap is monotone along the degree chain ring → 4-regular →
    fully connected;
  * gossip iteration converges every node to the global mean;
  * in-jit fault realizations preserve row- AND column-stochasticity
    (constant vectors are fixed points; the global mean is conserved);
  * DP-DSGT on the ``ring`` topology is bit-exact with the pre-refactor
    ``_ring_mix`` trajectory (the ring is literally the special case of the
    general sparse mixing step);

plus unit coverage for plan compilation, value-hashing, routing, and the
per-link byte/hop ledger.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import topology as topo_lib
from repro.config import TopologyConfig
from repro.core.p2p import P2PNetwork
from repro.topology import (MixPlan, is_connected, is_doubly_stochastic,
                            make_plan, make_topology, mix_stacked)

_settings = settings(max_examples=20, deadline=None)

FAMILIES = ["ring", "full", "torus", "kregular", "exponential", "erdos",
            "smallworld"]


def _build(family: str, M: int, k: int, seed: int):
    return make_topology(TopologyConfig(family=family, k=k, seed=seed), M)


# ---------------------------------------------------------------------------
# property tier
# ---------------------------------------------------------------------------

@_settings
@given(st.sampled_from(FAMILIES), st.integers(4, 24), st.integers(2, 6),
       st.integers(0, 5))
def test_mixing_matrices_doubly_stochastic(family, M, k, seed):
    topo = _build(family, M, k, seed)
    w = topo.weights
    assert np.array_equal(w, w.T)
    assert is_doubly_stochastic(w), (family, M, k, seed)
    assert not np.any(np.diag(topo.adjacency))


@_settings
@given(st.sampled_from(FAMILIES), st.integers(4, 24), st.integers(2, 6),
       st.integers(0, 5))
def test_symmetric_graphs_connected(family, M, k, seed):
    topo = _build(family, M, k, seed)
    assert np.array_equal(topo.adjacency, topo.adjacency.T)
    assert topo.is_connected(), (family, M, k, seed)


@_settings
@given(st.integers(6, 32))
def test_spectral_gap_monotone_in_degree(M):
    """Denser circulants mix faster: gap(ring) ≤ gap(4-regular) ≤
    gap(complete) = 1."""
    g2 = topo_lib.k_regular(M, 2).spectral_gap()
    g4 = topo_lib.k_regular(M, 4).spectral_gap()
    gf = topo_lib.fully_connected(M).spectral_gap()
    assert g2 <= g4 + 1e-9 <= gf + 2e-9, (M, g2, g4, gf)
    assert abs(gf - 1.0) < 1e-9


@_settings
@given(st.sampled_from(["ring", "kregular", "exponential", "smallworld"]),
       st.integers(4, 16), st.integers(0, 3))
def test_gossip_iteration_converges_to_global_mean(family, M, seed):
    topo = _build(family, M, 4, seed)
    plan = make_plan(topo)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (M, 3))
    cur = {"w": x}
    for _ in range(400):
        cur = mix_stacked(cur, plan)
    target = np.broadcast_to(np.asarray(jnp.mean(x, axis=0)), (M, 3))
    np.testing.assert_allclose(np.asarray(cur["w"]), target, atol=1e-3)


@_settings
@given(st.floats(0.05, 0.9), st.floats(0.0, 0.5), st.integers(0, 5))
def test_fault_masks_preserve_row_stochasticity(drop, churn, seed):
    """Every realized fault matrix keeps rows summing to 1 (constant vectors
    are fixed points) and, being symmetric, columns too (the global mean is
    conserved) — checked through the jitted mixing step itself."""
    M = 10
    topo = topo_lib.k_regular(M, 4).with_faults(drop, churn)
    plan = make_plan(topo)
    key = jax.random.PRNGKey(seed)
    ones = {"w": jnp.ones((M, 4))}
    x = {"w": jax.random.normal(key, (M, 4))}
    mixf = jax.jit(lambda t, r, k: mix_stacked(t, plan, r, k))
    for r in range(4):
        rk = jax.random.fold_in(key, r)
        out = mixf(ones, r, rk)
        np.testing.assert_allclose(np.asarray(out["w"]), 1.0, atol=1e-5)
        mixed = mixf(x, r, rk)
        np.testing.assert_allclose(float(jnp.mean(mixed["w"])),
                                   float(jnp.mean(x["w"])), atol=1e-5)


# ---------------------------------------------------------------------------
# ring bit-exactness: the acceptance contract of the refactor
# ---------------------------------------------------------------------------

def _legacy_ring_mix(stacked, self_w: float = 0.5):
    """The pre-refactor ``dp_dsgt._ring_mix``, frozen verbatim as the
    reference the new subsystem must reproduce bit-for-bit."""
    def mix(t):
        left = jnp.roll(t, 1, axis=0)
        right = jnp.roll(t, -1, axis=0)
        return self_w * t + (1 - self_w) / 2 * (left + right)
    return jax.tree_util.tree_map(mix, stacked)


def test_ring_plan_bit_exact_with_legacy_ring_mix(key):
    plan = make_plan(topo_lib.ring(8))
    assert plan.ring and plan.uniform == (0.5, 0.25)
    tree = {"w": jax.random.normal(key, (8, 5)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (8,))}
    out = jax.jit(lambda t: mix_stacked(t, plan))(tree)
    ref = jax.jit(_legacy_ring_mix)(tree)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class _LegacyRingDSGT:
    """Factory: DPDSGTStrategy whose mixes are the frozen legacy roll-based
    ring — the pre-refactor trajectory generator."""

    def __new__(cls, **kw):
        from repro.baselines.dp_dsgt import DPDSGTStrategy

        class Legacy(DPDSGTStrategy):
            def mix(self, stacked_tree, r, key):
                return _legacy_ring_mix(stacked_tree)

        return Legacy(**kw)


@pytest.fixture(scope="module")
def dsgt_data():
    rng = np.random.default_rng(0)
    M, feat, classes, n = 8, 12, 3, 32
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, n))
    xs = protos[ys] + rng.normal(size=(M, n, feat)).astype(np.float32) * 0.4
    return xs, ys.astype(np.int32)


def _run_dsgt(strategy, data, key):
    from repro.engine import Engine, FederatedData
    xs, ys = data
    fd = FederatedData(xs, ys, jnp.asarray(xs), jnp.asarray(ys))
    return Engine(strategy, eval_every=3).fit(fd, rounds=8, key=key,
                                              batch_size=8)


def test_dsgt_ring_history_bit_exact_with_prerefactor(dsgt_data, key):
    """ISSUE 5 acceptance: DP-DSGT on ``ring`` via the topology subsystem
    reproduces the pre-refactor ``_ring_mix`` history (and state) exactly."""
    from repro.baselines.dp_dsgt import DPDSGTStrategy
    mk = dict(feat_dim=12, num_classes=3, lr=0.3, clip=1.0, sigma=0.5)
    st_new, h_new = _run_dsgt(DPDSGTStrategy(**mk), dsgt_data, key)
    st_old, h_old = _run_dsgt(_LegacyRingDSGT(**mk), dsgt_data, key)
    assert h_new.rounds == h_old.rounds
    assert h_new.accuracy == h_old.accuracy
    for a, b in zip(jax.tree_util.tree_leaves(st_new),
                    jax.tree_util.tree_leaves(st_old)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dsgt_explicit_ring_equals_default(dsgt_data, key):
    """topology=ring(M) is the same computation as the built-in default."""
    from repro.baselines.dp_dsgt import DPDSGTStrategy
    mk = dict(feat_dim=12, num_classes=3, lr=0.3, clip=1.0, sigma=0.5)
    st1, h1 = _run_dsgt(DPDSGTStrategy(**mk), dsgt_data, key)
    st2, h2 = _run_dsgt(DPDSGTStrategy(topology=topo_lib.ring(8), **mk),
                        dsgt_data, key)
    assert h1.accuracy == h2.accuracy
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dsgt_rejects_mismatched_topology(dsgt_data, key):
    from repro.baselines.dp_dsgt import DPDSGTStrategy
    strat = DPDSGTStrategy(feat_dim=12, num_classes=3,
                           topology=topo_lib.ring(5))
    with pytest.raises(ValueError, match="M=8"):
        _run_dsgt(strat, dsgt_data, key)


# ---------------------------------------------------------------------------
# plans, hashing, time variation
# ---------------------------------------------------------------------------

def test_plan_compilation_flags():
    p = make_plan(topo_lib.ring(8))
    assert isinstance(p, MixPlan) and p.ring and p.period == 1 and p.degree == 2
    p = make_plan(topo_lib.k_regular(8, 4))
    # regular + metropolis ⇒ constant rows: the uniform fast path applies,
    # but the neighbor set is not the cycle so the ring flag must not
    assert not p.ring and p.degree == 4 and p.uniform is not None
    p = make_plan(topo_lib.erdos_renyi(10, 0.3, seed=1))
    assert p.uniform is None               # irregular ⇒ general path
    p = make_plan(topo_lib.gossip_matchings(9, period=4))
    assert p.period == 4 and p.degree == 1
    p = make_plan(topo_lib.ring(8).with_faults(0.2, 0.1))
    assert p.faulty and p.drop_prob == 0.2 and p.churn_prob == 0.1


def test_topology_value_hashing():
    assert topo_lib.ring(8) == topo_lib.ring(8)
    assert hash(topo_lib.ring(8)) == hash(topo_lib.ring(8))
    assert topo_lib.ring(8) != topo_lib.ring(10)
    assert topo_lib.ring(8) != topo_lib.ring(8).with_faults(0.1)
    assert topo_lib.ring(8) != topo_lib.k_regular(8, 2, weighting="uniform")
    tv = topo_lib.gossip_matchings(8, 4, seed=1)
    assert tv == topo_lib.gossip_matchings(8, 4, seed=1)
    assert tv != topo_lib.gossip_matchings(8, 4, seed=2)


def test_group_clustered_matches_groups():
    groups = [[0, 1, 2], [3, 4], [5, 6, 7]]
    topo = topo_lib.group_clustered(groups, 8, bridge=False)
    for g in groups:
        for a in g:
            for b in g:
                if a != b:
                    assert topo.adjacency[a, b]
    assert not topo.adjacency[0, 3] and not topo.is_connected()
    bridged = topo_lib.group_clustered(groups, 8, bridge=True)
    assert bridged.is_connected()


def test_time_varying_union_connected():
    tv = topo_lib.gossip_matchings(8, period=8, seed=0)
    assert tv.is_connected()          # union over the period
    assert not tv.topologies[0].is_connected()   # one matching never is


def test_make_topology_none_and_unknown():
    assert make_topology(TopologyConfig(family="none"), 8) is None
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology(TopologyConfig(family="mystery"), 8)
    with pytest.raises(ValueError, match="groups"):
        make_topology(TopologyConfig(family="group"), 8)


def test_uniform_weighting_requires_regular():
    with pytest.raises(ValueError, match="regular"):
        topo_lib.erdos_renyi(12, 0.3, seed=3, weighting="uniform")


# ---------------------------------------------------------------------------
# routing + per-link accounting
# ---------------------------------------------------------------------------

def test_shortest_hops_and_route():
    topo = topo_lib.ring(8)
    dist, nh = topo_lib.shortest_hops(topo.adjacency)
    assert dist[0, 4] == 4 and dist[0, 1] == 1 and dist[2, 2] == 0
    path = topo_lib.route(nh, dist, 0, 3)
    assert len(path) == dist[0, 3]
    for (u, v) in path:                      # every hop is a physical link
        assert topo.adjacency[u, v]
    assert path[0][0] == 0 and path[-1][1] == 3
    # unreachable pairs degrade to one direct message
    iso = topo_lib.group_clustered([[0, 1], [2, 3]], 4, bridge=False)
    d2, n2 = topo_lib.shortest_hops(iso.adjacency)
    assert d2[0, 2] == -1 and topo_lib.route(n2, d2, 0, 2) == [(0, 2)]


def test_per_link_and_hop_accounting():
    net = P2PNetwork(8)
    topo = topo_lib.ring(8)
    dist, nh = topo_lib.shortest_hops(topo.adjacency)
    payload = {"w": np.ones((3,), np.float32)}
    n = topo_lib.send_routed(net, 0, 3, payload, "proxy_update", 0, dist, nh)
    assert net.total_hops() == 3 and net.relayed_messages() == 2
    assert n == net.total_bytes()
    links = net.per_link()
    assert set(links) == {(0, 1), (1, 2), (2, 3)}
    assert len(set(links.values())) == 1     # same payload on every hop
    summary = topo_lib.per_link_summary(net)
    assert summary["links_used"] == 3 and summary["hops_total"] == 3


def test_dsgt_gossip_byte_accounting_respects_faults(dsgt_data, key):
    """Engine-logged gossip bytes only flow on links the traced fault draw
    kept alive — re-derived host-side from the same phase key."""
    from repro.baselines.dp_dsgt import DPDSGTStrategy
    from repro.engine import Engine, FederatedData
    from repro.topology.faults import host_fault_masks
    xs, ys = dsgt_data
    topo = topo_lib.ring(8).with_faults(0.4, 0.0)
    net = P2PNetwork(8)
    strat = DPDSGTStrategy(feat_dim=12, num_classes=3, lr=0.3,
                           topology=topo)
    fd = FederatedData(xs, ys, jnp.asarray(xs), jnp.asarray(ys))
    Engine(strat, eval_every=2, network=net).fit(fd, rounds=6, key=key,
                                                 batch_size=8)
    assert 0 < net.num_messages() < 6 * 16   # faults dropped some edges
    _, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))
    for m in net.log:
        assert topo.adjacency[m.src, m.dst]
        keep, _ = host_fault_masks(phase_key, m.rnd, 1, 8, 0.4, 0.0)
        assert keep[m.src, m.dst] > 0, m


def test_host_fault_masks_match_in_jit_draws(key):
    """ISSUE 6 satellite: the host-side replay is bit-equal to the in-jit
    ``draw_fault_masks`` realization across streams and rounds."""
    from repro.topology.faults import draw_fault_masks, host_fault_masks
    _, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))

    @jax.jit
    def in_jit(r, stream):
        rk = jax.random.fold_in(phase_key, r)
        return draw_fault_masks(jax.random.fold_in(rk, stream), 8, 0.3, 0.2)

    for stream in (1, 2):
        for r in range(5):
            keep_j, up_j = in_jit(r, stream)
            keep_h, up_h = host_fault_masks(phase_key, r, stream, 8, 0.3, 0.2)
            np.testing.assert_array_equal(keep_h, np.asarray(keep_j))
            np.testing.assert_array_equal(up_h, np.asarray(up_j))


def test_host_realizations_match_scanned_fault_process(key):
    """The correlated chains replay the same way: a traced ``lax.scan`` over
    ``FaultProcess.step`` realizes bit-identical masks to the incremental
    host-side ``host_realizations`` memo."""
    from repro.resilience import FaultModel, FaultProcess, host_realizations
    model = FaultModel(link_fail=0.25, link_repair=0.4, node_fail=0.2,
                       node_repair=0.5, partition_prob=0.2,
                       partition_repair=0.4, slow_enter=0.2, slow_exit=0.6)
    proc = FaultProcess(model, 8)
    _, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))

    def body(state, r):
        state, real = proc.step(state, r, proc.round_key(phase_key, r))
        return state, real

    _, reals = jax.jit(lambda s: jax.lax.scan(body, s, jnp.arange(10)))(
        proc.init_state())
    hosts = host_realizations(proc, phase_key, 0, 0, 10)
    assert len(hosts) == 10
    for r, hf in enumerate(hosts):
        np.testing.assert_array_equal(hf.keep, np.asarray(reals.keep[r]))
        np.testing.assert_array_equal(hf.up, np.asarray(reals.up[r]))
        np.testing.assert_array_equal(hf.slow, np.asarray(reals.slow[r]))
        np.testing.assert_array_equal(hf.age, np.asarray(reals.age[r]))


def test_fedavg_psum_fingerprint_differs_from_gather():
    """reduce is a dataclass field, so the two reduction modes can never
    share a compiled sharded chunk."""
    from repro.baselines.fedavg import FedAvgStrategy
    a = FedAvgStrategy(feat_dim=4, num_classes=2)
    b = FedAvgStrategy(feat_dim=4, num_classes=2, reduce="gather")
    assert a.fingerprint() != b.fingerprint()


# ---------------------------------------------------------------------------
# ISSUE 7: halo-exchange schedules — gather-free sparse mixing
# ---------------------------------------------------------------------------

class _HostCtx:
    """Host-side stand-in for ClientShardCtx: the halo-schedule builder and
    the path predicate only read the layout constants."""

    def __init__(self, M: int, n: int):
        self.M = M
        self.n = n
        self.M_pad = -(-M // n) * n
        self.m = self.M_pad // n


def _halo_covers_exactly(plan, n: int) -> bool:
    """The derived schedule must map every weight-positive slot of every
    round's W to the right global neighbor row — same-slice slots to the
    local block, off-slice slots to the matching position of the matching
    displacement's halo block — and padded/zero-weight slots to a self
    index."""
    from repro.topology.mixing import halo_schedule
    ctx = _HostCtx(plan.M, n)
    sched = halo_schedule(plan, ctx)
    if sched is None:
        return True     # unprofitable layouts legitimately decline
    m = ctx.m
    offsets, blocks = {}, {}
    off = m
    for disp, idx in sched.sends:
        offsets[disp] = off
        blocks[disp] = idx
        off += len(idx)
    for t in range(plan.period):
        for i in range(ctx.M_pad):
            p, li = divmod(i, m)
            for k in range(plan.degree):
                pos = int(sched.buf_idx[t, i, k])
                if i >= plan.M or plan.nbr_w_np[t, i, k] <= 0:
                    if pos != li:
                        return False
                    continue
                j = int(plan.nbr_np[t, i, k])
                if pos < m:                      # local block
                    if p * m + pos != j:
                        return False
                else:                            # halo block of some disp
                    hit = False
                    for disp, idx in sched.sends:
                        o = offsets[disp]
                        if o <= pos < o + len(idx):
                            src = (p - disp) % ctx.n
                            hit = src * m + int(idx[pos - o]) == j
                            break
                    if not hit:
                        return False
    return True


@_settings
@given(st.sampled_from(FAMILIES), st.integers(4, 24), st.integers(2, 6),
       st.integers(0, 5), st.sampled_from([2, 4, 8]))
def test_halo_schedule_covers_nonzero_offslice_entries(family, M, k, seed, n):
    """Property (ISSUE 7): for every builder × (M, devices) layout, the halo
    schedule reconstructs exactly the nonzero off-slice entries of W."""
    plan = make_plan(_build(family, M, k, seed))
    assert _halo_covers_exactly(plan, n), (family, M, k, seed, n)


@_settings
@given(st.integers(8, 24), st.sampled_from([2, 4, 8]), st.integers(0, 3))
def test_time_varying_halo_schedule_covers_every_round(M, n, seed):
    plan = make_plan(topo_lib.gossip_matchings(M, period=4, seed=seed))
    assert _halo_covers_exactly(plan, n)


def test_banded_families_never_gather():
    """The bounded-bandwidth families must take the halo (or cheaper) path
    on an 8-slice layout — the gather fallback is reserved for dense
    graphs."""
    from repro.topology.mixing import select_mix_path
    ctx = _HostCtx(16, 8)
    banded = {
        "ring": topo_lib.ring(16),
        "faulty_ring": topo_lib.ring(16).with_faults(0.3, 0.1),
        "torus": topo_lib.torus(4, 4),
        "k_regular": topo_lib.k_regular(16, 4),
        "clustered": topo_lib.group_clustered(
            [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9, 10, 11], [12, 13, 14, 15]],
            16, bridge=False),
    }
    for name, topo in banded.items():
        path = select_mix_path(make_plan(topo), ctx)
        assert path in ("local", "halo"), (name, path)
    # dense graphs do fall back — the schedule would ship nearly all of M
    dense = select_mix_path(make_plan(topo_lib.fully_connected(16)), ctx)
    assert dense == "gather"


def test_halo_mix_matches_single_device_all_paths():
    """Host-checkable equivalence of the traced halo consume: build the
    (T, M_pad, degree) receive-buffer indexing and apply it in numpy against
    the single-device ``mix_stacked`` for a faulty banded graph."""
    from repro.topology.mixing import halo_schedule, _round_slice
    rng = np.random.default_rng(0)
    for topo in (topo_lib.ring(12), topo_lib.k_regular(12, 4),
                 topo_lib.torus(4, 3)):
        plan = make_plan(topo)
        ctx = _HostCtx(12, 4)
        sched = halo_schedule(plan, ctx)
        assert sched is not None, topo.family
        x = rng.normal(size=(12, 5)).astype(np.float32)
        want = np.asarray(mix_stacked(jnp.asarray(x), plan, 0, None))
        # emulate the traced consume: global buffer = [slice rows | halos]
        m = ctx.m
        got = np.zeros_like(x)
        for p in range(ctx.n):
            halos = []
            for disp, idx in sched.sends:
                src = (p - disp) % ctx.n
                halos.append(x[src * m + np.asarray(idx)])
            buf = np.concatenate([x[p * m:(p + 1) * m]] + halos, axis=0)
            s, w = plan.uniform
            bi = sched.buf_idx[0, p * m:(p + 1) * m]
            acc = buf[bi[:, 0]]
            for kk in range(1, plan.degree):
                acc = acc + buf[bi[:, kk]]
            got[p * m:(p + 1) * m] = s * x[p * m:(p + 1) * m] + w * acc
        np.testing.assert_allclose(got, want, atol=1e-6)


def test_collective_probe_counters():
    """MIX_STATS is a plain trace-time counter dict: snapshot copies,
    reset zeroes."""
    from repro.topology.mixing import (MIX_STATS, mix_stats_snapshot,
                                       reset_mix_stats)
    reset_mix_stats()
    MIX_STATS["ppermutes"] += 3
    snap = mix_stats_snapshot()
    assert snap["ppermutes"] == 3 and snap["all_gathers"] == 0
    MIX_STATS["ppermutes"] += 1
    assert snap["ppermutes"] == 3      # snapshot is a copy
    reset_mix_stats()
    assert mix_stats_snapshot()["ppermutes"] == 0


# ---------------------------------------------------------------------------
# ISSUE 9: fingerprint cache-key hole + learned graphs + push-sum mixing
# ---------------------------------------------------------------------------

def test_fingerprint_distinguishes_adjacency():
    """Regression: two graphs with IDENTICAL W but different adjacency (any
    builder at self_weight=1.0 yields W = I) must not collide in the
    compiled-chunk cache — they differ in byte accounting, routing, and
    fault masks. The old fingerprint hashed only ``weights.tobytes()``."""
    t1 = topo_lib.group_clustered([[0, 1], [2, 3]], 4, bridge=False,
                                  weighting="uniform", self_weight=1.0)
    t2 = topo_lib.group_clustered([[0, 2], [1, 3]], 4, bridge=False,
                                  weighting="uniform", self_weight=1.0)
    assert np.array_equal(t1.weights, np.eye(4))
    assert np.array_equal(t2.weights, np.eye(4))
    assert t1.name == t2.name                      # same name, same W ...
    assert not np.array_equal(t1.adjacency, t2.adjacency)
    assert t1 != t2                                # ... still distinct keys
    assert t1.fingerprint() != t2.fingerprint()


def _learned_topology(M: int, k: int, seed: int):
    rng = np.random.default_rng(seed)
    learner = topo_lib.GraphLearner(M=M, k=k, sigma_dist=0.5, seed=seed)
    return learner.estimate(rng.normal(size=(M, 20)).astype(np.float32))


@_settings
@given(st.integers(4, 16), st.integers(1, 4), st.integers(0, 5))
def test_push_sum_converges_to_global_mean(M, k, seed):
    """Push-sum's de-biased ratio x/w converges to the uniform average on a
    learned (directed, column-stochastic, strongly-connected) graph — the
    estimate plain averaging would bias toward high-in-degree nodes."""
    from repro.topology import push_sum_debias, push_sum_mix
    topo = _learned_topology(M, k, seed)
    assert topo_lib.is_column_stochastic(topo.weights)
    plan = make_plan(topo)
    assert plan.push_sum
    rng = np.random.default_rng(seed)
    x0 = jnp.asarray(rng.normal(size=(M, 3)).astype(np.float32))
    x, w = x0, jnp.ones((M,), jnp.float32)
    for r in range(400):
        x, w = push_sum_mix(x, w, plan, r)
    est = np.asarray(push_sum_debias(x, w))
    np.testing.assert_allclose(est, np.tile(np.mean(np.asarray(x0), axis=0),
                                            (M, 1)), atol=1e-3)


@_settings
@given(st.integers(5, 20), st.integers(2, 4), st.integers(0, 5))
def test_push_sum_reduces_to_symmetric(M, k, seed):
    """On a doubly-stochastic W, push-sum IS the symmetric path: auto-detect
    picks the standard plan, forcing push-sum keeps every weight scalar at 1
    and the de-biased mix matches ``mix_stacked`` within float tolerance."""
    from repro.topology import push_sum_debias, push_sum_mix
    topo = _build("kregular", M, k, seed)
    p_std = make_plan(topo)
    p_ps = make_plan(topo, push_sum=True)
    assert not p_std.push_sum and p_ps.push_sum
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(M, 4)).astype(np.float32))
    a = np.asarray(mix_stacked(x, p_std))
    b, w = push_sum_mix(x, jnp.ones((M,), jnp.float32), p_ps)
    np.testing.assert_allclose(np.asarray(w), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(push_sum_debias(b, w)), a,
                               atol=1e-5)


@_settings
@given(st.integers(4, 12), st.integers(1, 3), st.integers(0, 5))
def test_push_sum_fault_fold_conserves_mass(M, k, seed):
    """The fault fold under push-sum returns dropped mass to the SENDER's
    diagonal (``out_w_np``): any symmetric keep realization leaves every
    realized column summing to 1, so total mass and total weight are
    conserved — the invariant the ratio estimate rests on."""
    from repro.topology import push_sum_mix
    topo = _learned_topology(M, k, seed)
    plan = make_plan(topo)
    rng = np.random.default_rng(seed + 99)
    keep = np.ones((M, M), np.float32)
    for _ in range(max(1, M // 2)):
        i, j = rng.integers(M), rng.integers(M)
        if i != j:
            keep[i, j] = keep[j, i] = 0.0
    x0 = jnp.asarray(rng.normal(size=(M, 2)).astype(np.float32))
    x, w = push_sum_mix(x0, jnp.ones((M,), jnp.float32), plan, 0,
                        key=jax.random.PRNGKey(0), keep=jnp.asarray(keep))
    np.testing.assert_allclose(np.asarray(jnp.sum(x, axis=0)),
                               np.asarray(jnp.sum(x0, axis=0)), atol=1e-4)
    np.testing.assert_allclose(float(jnp.sum(w)), float(M), atol=1e-4)


@_settings
@given(st.integers(2, 20), st.integers(1, 6), st.integers(0, 5))
def test_sparsify_row_stochastic_and_connected(M, k, seed):
    """Learned sparsification always yields a row-stochastic trust matrix
    whose (symmetric) support is connected — via fallback if the kNN graph
    alone is not."""
    from repro.topology import sparsify_similarity
    rng = np.random.default_rng(seed)
    d = np.abs(rng.normal(size=(M, M)))
    d = d + d.T
    np.fill_diagonal(d, 0)
    trust, _ = sparsify_similarity(d, k)
    assert np.all(trust >= 0)
    np.testing.assert_allclose(trust.sum(axis=1), 1.0, atol=1e-9)
    support = (trust > 0) & ~np.eye(M, dtype=bool)
    assert is_connected(support | support.T)


def test_sparsify_connectivity_fallback_triggers():
    """Two far-apart clusters with k=1 give a disconnected kNN graph: the
    ring-union fallback must fire and reconnect the support."""
    from repro.topology import sparsify_similarity
    d = np.full((6, 6), 1000.0)
    for blk in (slice(0, 3), slice(3, 6)):
        d[blk, blk] = 1.0
    np.fill_diagonal(d, 0.0)
    trust, fell_back = sparsify_similarity(d, 1)
    assert fell_back
    support = (trust > 0) & ~np.eye(6, dtype=bool)
    assert is_connected(support | support.T)


def test_make_plan_rejects_non_stochastic():
    """A W that is neither row- nor column-stochastic is a bug in the
    caller; make_plan refuses instead of silently mis-mixing."""
    import dataclasses
    topo = topo_lib.ring(6)
    bad = dataclasses.replace(topo, weights=topo.weights * 0.5)
    with pytest.raises(ValueError, match="row-stochastic"):
        make_plan(bad)


def test_graph_learner_ledger_epsilon_increases():
    """Every re-estimation is one more release of the (DP-protected) client
    weights: the ledger's ε must strictly increase across estimates."""
    from repro.engine import PrivacyLedger
    ledger = PrivacyLedger(sigma=1.0, delta=1e-5)
    learner = topo_lib.GraphLearner(M=6, k=2, sigma_dist=2.0, seed=0)
    rng = np.random.default_rng(0)
    eps = [ledger.epsilon()]
    for _ in range(3):
        learner.estimate(rng.normal(size=(6, 10)).astype(np.float32),
                         ledger=ledger)
        eps.append(ledger.epsilon())
    assert all(b > a for a, b in zip(eps, eps[1:])), eps
    assert len(learner.history) == 3
    assert len(learner.gap_trajectory) == 3


def test_graph_learner_noiseless_release_is_honest():
    """sigma_dist <= 0 means the distances are released without noise — the
    ledger must report ε = ∞, not silently under-account."""
    from repro.engine import PrivacyLedger
    ledger = PrivacyLedger(sigma=1.0, delta=1e-5)
    learner = topo_lib.GraphLearner(M=4, k=1, sigma_dist=0.0, seed=0)
    learner.estimate(np.random.default_rng(0).normal(size=(4, 8))
                     .astype(np.float32), ledger=ledger)
    assert ledger.epsilon() == float("inf")


def test_graph_learner_current_folds_time_varying():
    """``current(window=n)`` folds the last n estimates as a
    TimeVaryingTopology whose fingerprint is distinct per estimate set —
    cache-correct across re-estimations."""
    rng = np.random.default_rng(3)
    learner = topo_lib.GraphLearner(M=6, k=2, sigma_dist=0.5, seed=3)
    t0 = learner.estimate(rng.normal(size=(6, 12)).astype(np.float32))
    assert learner.current() is t0
    t1 = learner.estimate(rng.normal(size=(6, 12)).astype(np.float32))
    tv = learner.current(window=2)
    assert isinstance(tv, topo_lib.TimeVaryingTopology)
    assert tv.period == 2 and tv.topologies == [t0, t1]
    assert t0.fingerprint() != t1.fingerprint()
    assert tv.fingerprint() != t0.fingerprint()
    plan = make_plan(tv)
    assert plan.push_sum and plan.period == 2


def test_learned_dsgt_state_alignment():
    """DSGT's push-sum state carry: entering a push-sum plan grows the (M,)
    weight leaf at 1; leaving folds the bias back into x."""
    from repro.baselines.dp_dsgt import DPDSGTStrategy
    M = 6
    strat = DPDSGTStrategy(feat_dim=4, num_classes=2, lr=0.3)
    strat.set_topology(_learned_topology(M, 2, 0))
    assert strat._mix_plan.push_sum
    state = {"x": jnp.ones((M, 4)), "y": jnp.zeros((M, 4)),
             "g": jnp.zeros((M, 4))}
    state = strat.align_push_sum_state(state)
    assert "w" in state and np.allclose(np.asarray(state["w"]), 1.0)
    state["w"] = state["w"] * 2.0
    strat.set_topology(topo_lib.ring(M))
    back = strat.align_push_sum_state(state)
    assert "w" not in back
    np.testing.assert_allclose(np.asarray(back["x"]), 0.5, atol=1e-6)
