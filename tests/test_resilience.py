"""Resilience subsystem tier: correlated fault chains, straggler freezing,
aggregator failover, crash-safe checkpoints, and bit-exact auto-resume.

The SIGKILL chaos scenario (kill a training subprocess mid-chunk, resume,
assert the History is bit-exact with an uninterrupted run) is marked slow;
everything else runs in tier-1."""
import json
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.local import LocalStrategy
from repro.checkpoint import (CheckpointError, latest_step,
                              load_checkpoint_metadata, restore_checkpoint,
                              save_checkpoint, verify_checkpoint)
from repro.engine import Engine, FederatedData
from repro.resilience import (FaultModel, FaultProcess, FaultRealization,
                              fault_state_at, gilbert_elliott_rates,
                              host_realizations, make_fault_process)


@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(0)
    M, feat, classes, n = 6, 12, 3, 32
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, n))
    xs = protos[ys] + rng.normal(size=(M, n, feat)).astype(np.float32) * 0.4
    return FederatedData(xs, ys.astype(np.int32), jnp.asarray(xs),
                         jnp.asarray(ys.astype(np.int32)))


# ---------------------------------------------------------------------------
# fault chains: invariants of the stepped realizations
# ---------------------------------------------------------------------------

def test_disabled_model_builds_no_process():
    assert not FaultModel().enabled
    assert make_fault_process(FaultModel(), 8) is None
    assert make_fault_process(FaultModel(link_fail=0.1), 8) is not None


def test_gilbert_elliott_rates_realize_the_parameterization():
    fail, repair = gilbert_elliott_rates(0.3, 4.0)
    assert repair == pytest.approx(1 / 4.0)           # mean burst length
    assert fail / (fail + repair) == pytest.approx(0.3)  # stationary drop
    assert gilbert_elliott_rates(0.0, 10.0) == (0.0, 1.0)
    with pytest.raises(ValueError):
        gilbert_elliott_rates(1.5, 4.0)
    with pytest.raises(ValueError):
        gilbert_elliott_rates(0.3, 0.5)


def _run_chain(model, M, rounds, key):
    proc = FaultProcess(model, M)
    state, reals = proc.init_state(), []
    for r in range(rounds):
        state, real = proc.step(state, r, proc.round_key(key, r))
        reals.append(real)
    return reals


def test_realized_keep_symmetric_diag_up(key):
    model = FaultModel(link_fail=0.3, link_repair=0.4, node_fail=0.25,
                       node_repair=0.5, partition_prob=0.3,
                       partition_repair=0.4, slow_enter=0.2, slow_exit=0.5)
    for real in _run_chain(model, 8, 12, key):
        keep = np.asarray(real.keep)
        up = np.asarray(real.up)
        np.testing.assert_array_equal(keep, keep.T)
        np.testing.assert_array_equal(np.diag(keep), up)
        assert set(np.unique(keep)) <= {0.0, 1.0}
        assert set(np.unique(up)) <= {0.0, 1.0}
        # a down endpoint kills every incident edge
        assert np.all(keep <= up[:, None]) and np.all(keep <= up[None, :])


def test_bursty_links_are_absorbing_without_repair(key):
    """link_repair=0: a bad edge never heals — the dropped set can only grow
    (the extreme of burstiness; i.i.d. redraws cannot express this)."""
    model = FaultModel(link_fail=0.3, link_repair=0.0)
    prev = None
    for real in _run_chain(model, 8, 10, key):
        dropped = np.asarray(real.keep) == 0
        if prev is not None:
            assert np.all(dropped | ~prev)   # once dropped, stays dropped
        prev = dropped
    assert prev.any()


def test_partition_cuts_exactly_the_bisection(key):
    model = FaultModel(partition_prob=0.5, partition_repair=0.3)
    M = 8
    saw_active = False
    for real in _run_chain(model, M, 16, key):
        keep = np.asarray(real.keep)
        off = ~np.eye(M, dtype=bool)
        if (keep[off] == 0).any():
            saw_active = True
            # dropped pairs form a complete bipartite cut of a balanced
            # bisection: side(i) differs exactly where keep is 0
            side = keep[0] == 0          # nodes cut from node 0
            side[0] = False
            assert side.sum() == M // 2
            expect = (side[:, None] != side[None, :]) & off
            np.testing.assert_array_equal(keep == 0, expect)
        else:
            np.testing.assert_array_equal(keep, np.ones((M, M)))
    assert saw_active


def test_straggler_age_counts_missed_rounds(key):
    """slow_enter=1, slow_exit=0: everyone is a straggler from round 0 on;
    the realization's age is the PRE-reset count of missed rounds, so a
    recovering client would see its true staleness."""
    model = FaultModel(slow_enter=1.0, slow_exit=0.0)
    reals = _run_chain(model, 4, 6, key)
    for r, real in enumerate(reals):
        np.testing.assert_array_equal(np.asarray(real.slow), np.ones(4))
        np.testing.assert_array_equal(np.asarray(real.active()), np.zeros(4))
        np.testing.assert_array_equal(np.asarray(real.age), np.full(4, r))


def test_host_replay_matches_stepped_chain(key):
    model = FaultModel(link_fail=0.2, link_repair=0.4, node_fail=0.2,
                       node_repair=0.5, slow_enter=0.2, slow_exit=0.6)
    proc = FaultProcess(model, 6)
    reals = _run_chain(model, 6, 8, key)
    frs = host_realizations(proc, key, 0, 3, 8)
    for r, hf in zip(range(3, 8), frs):
        np.testing.assert_array_equal(hf.keep, np.asarray(reals[r].keep))
        np.testing.assert_array_equal(hf.up, np.asarray(reals[r].up))
        np.testing.assert_array_equal(hf.age, np.asarray(reals[r].age))
    state = fault_state_at(proc, key, 0, 5)
    # stepping the replayed state forward continues the same trajectory
    _, real5 = proc.step(state, 5, proc.round_key(key, 5))
    np.testing.assert_array_equal(np.asarray(real5.keep),
                                  np.asarray(reals[5].keep))


# ---------------------------------------------------------------------------
# frozen clients + zero-rate transparency in the engine
# ---------------------------------------------------------------------------

def test_zero_rate_process_is_bit_transparent_for_local(toy, key):
    """An installed process with every chain disabled realizes all-ones
    masks; for a strategy with identity aggregation the faulted round body
    must produce the bit-identical trajectory to no process at all."""
    def fit(faults):
        strat = LocalStrategy(feat_dim=12, num_classes=3, lr=0.5)
        return Engine(strat, eval_every=4, faults=faults).fit(
            toy, rounds=8, key=key, batch_size=8)

    st0, h0 = fit(None)
    st1, h1 = fit(FaultProcess(FaultModel(), 6))
    assert h0.rounds == h1.rounds and h0.accuracy == h1.accuracy
    for a, b in zip(jax.tree_util.tree_leaves(st0),
                    jax.tree_util.tree_leaves(st1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_all_frozen_round_is_a_noop(toy, key):
    """Every client a straggler ⇒ every round freezes: training is discarded
    and the final state equals the init state."""
    strat = LocalStrategy(feat_dim=12, num_classes=3, lr=0.5)
    proc = FaultProcess(FaultModel(slow_enter=1.0, slow_exit=0.0), 6)
    st, hist = Engine(strat, eval_every=4, faults=proc).fit(
        toy, rounds=8, key=key, batch_size=8)
    init_key, _ = jax.random.split(jax.random.fold_in(key, 0x9e37))
    ref = strat.init(init_key, toy, 8)
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert hist.metrics["participation_rate"][-1] == 0.0
    assert hist.metrics["fault_slow"][-1] == 1.0


# ---------------------------------------------------------------------------
# P4 failover: deterministic next-up aggregator + quorum, traced ≡ host
# ---------------------------------------------------------------------------

def _p4_strategy(M=6):
    from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
    from repro.core.p4 import P4Strategy, P4Trainer
    cfg = RunConfig(dp=DPConfig(epsilon=15.0, rounds=8, sample_rate=0.5),
                    p4=P4Config(group_size=3, sample_peers=5),
                    train=TrainConfig(learning_rate=0.5))
    strat = P4Strategy(trainer=P4Trainer(feat_dim=8, num_classes=3, cfg=cfg))
    strat.set_groups([[0, 1, 2], [3, 4, 5]], M)
    return strat


class _FakeHostFaults:
    def __init__(self, up, keep, model):
        self.up = np.asarray(up, np.float32)
        self.keep = np.asarray(keep, np.float32)
        self.slow = np.zeros_like(self.up)
        self.age = np.zeros_like(self.up)
        self.model = model

    @property
    def active(self):
        return self.up


def _full_keep(up):
    up = np.asarray(up, np.float32)
    keep = up[:, None] * up[None, :]
    np.fill_diagonal(keep, up)
    return keep


def test_failover_picks_next_up_member_and_enforces_quorum():
    strat = _p4_strategy()
    model = FaultModel(node_fail=0.5, quorum=0.5)
    # group 0: scheduled aggregator (round 0, rotation 1) is client 0 — down;
    # failover lands on client 1. group 1: 2/3 down — below quorum, silent.
    up = [0, 1, 1, 1, 0, 0]
    hf = _FakeHostFaults(up, _full_keep(up), model)
    plan = strat._host_failover_plan(0, hf)
    assert plan[0] == (1, True, True)          # (aggregator, ok, failed_over)
    agg1, ok1, _ = plan[1]
    assert not ok1

    # the traced mask realizes the same plan: group 0 members reach the
    # stand-in aggregator, group 1 is local-only
    from repro.resilience import ActiveFaults
    real = FaultRealization(keep=jnp.asarray(_full_keep(up)),
                            up=jnp.asarray(up, jnp.float32),
                            slow=jnp.zeros(6), age=jnp.zeros(6))
    mask = np.asarray(strat._process_fault_mask(0, ActiveFaults(real, model)))
    np.testing.assert_array_equal(mask, [0, 1, 1, 0, 0, 0])


def test_failover_rotation_is_deterministic():
    strat = _p4_strategy()
    model = FaultModel(node_fail=0.5, quorum=0.0)
    up = [1, 1, 0, 1, 1, 1]
    hf = _FakeHostFaults(up, _full_keep(up), model)
    # rotation=1: scheduled slot walks 0,1,2,0,... in group 0; round 2's
    # scheduled aggregator (client 2) is down → next-up is client 0
    assert strat._host_failover_plan(0, hf)[0][0] == 0
    assert strat._host_failover_plan(1, hf)[0][0] == 1
    assert strat._host_failover_plan(2, hf)[0] == (0, True, True)


def test_failover_byte_accounting_and_counter():
    from repro.core.p2p import P2PNetwork
    strat = _p4_strategy()
    model = FaultModel(node_fail=0.5, quorum=0.5)
    up = [0, 1, 1, 1, 0, 0]
    hf = _FakeHostFaults(up, _full_keep(up), model)
    net = P2PNetwork(6)
    states = {"proxy": {"w": jnp.zeros((6, 4), jnp.float32)}}
    strat.log_communication(net, states, 0, faults=hf)
    assert strat.failover_count == 1
    # only group 0 exchanged, through the stand-in aggregator 1
    assert net.num_messages() == 2           # 2↔1, both directions
    assert {(m.src, m.dst) for m in net.log} == {(2, 1), (1, 2)}
    # a dropped member↔aggregator link also silences that member
    keep = _full_keep(up)
    keep[2, 1] = keep[1, 2] = 0.0
    net2 = P2PNetwork(6)
    strat.failover_count = 0
    strat.log_communication(net2, states, 0,
                            faults=_FakeHostFaults(up, keep, model))
    assert net2.num_messages() == 0 and strat.failover_count == 0


# ---------------------------------------------------------------------------
# checkpoint durability: atomic writes, corruption detection, retention
# ---------------------------------------------------------------------------

def _tree(seed=0, d=5):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(d, 3)).astype(np.float32),
            "b": rng.normal(size=(3,)).astype(np.float32)}


def test_checkpoint_roundtrip_with_metadata(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t,
                    metadata={"history": {"rounds": [0, 3], "accuracy": [0.5, 0.625]}})
    assert verify_checkpoint(str(tmp_path), 7)
    meta = load_checkpoint_metadata(str(tmp_path), 7)
    assert meta["step"] == 7 and meta["history"]["accuracy"] == [0.5, 0.625]
    restored, step = restore_checkpoint(str(tmp_path), _tree(seed=1))
    assert step == 7
    np.testing.assert_array_equal(restored["w"], t["w"])


def test_corrupt_archive_is_detected_and_skipped(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    save_checkpoint(str(tmp_path), 6, _tree(seed=1))
    path = os.path.join(str(tmp_path), "ckpt_00000006.npz")
    with open(path, "r+b") as f:          # flip bytes mid-archive
        f.seek(os.path.getsize(path) // 2)
        f.write(b"\xde\xad\xbe\xef")
    assert not verify_checkpoint(str(tmp_path), 6)
    assert latest_step(str(tmp_path)) == 3    # falls back to the durable one
    with pytest.raises(CheckpointError, match="integrity"):
        restore_checkpoint(str(tmp_path), _tree(), 6)


def test_latest_step_ignores_tmp_orphans(tmp_path):
    save_checkpoint(str(tmp_path), 4, _tree())
    # a torn write leaves a deterministic .tmp orphan behind
    for orphan in ("ckpt_00000009.npz.tmp", "ckpt_00000009.json.tmp"):
        (tmp_path / orphan).write_bytes(b"torn")
    assert latest_step(str(tmp_path)) == 4


def test_restore_errors_name_the_leaf(tmp_path):
    save_checkpoint(str(tmp_path), 2, _tree(d=5))
    with pytest.raises(ValueError, match=r"leaf 'w' has shape \(5, 3\)"):
        restore_checkpoint(str(tmp_path), _tree(d=9), 2)
    with pytest.raises(ValueError, match="missing leaf 'extra'"):
        restore_checkpoint(str(tmp_path), {**_tree(), "extra": np.zeros(2)}, 2)


def test_keep_last_retention(tmp_path):
    for s in range(5):
        save_checkpoint(str(tmp_path), s, _tree(seed=s), keep_last=2)
    files = sorted(os.listdir(str(tmp_path)))
    assert files == ["ckpt_00000003.json", "ckpt_00000003.npz",
                     "ckpt_00000004.json", "ckpt_00000004.npz"]


# ---------------------------------------------------------------------------
# auto-resume: restored History + state continue the exact trajectory
# ---------------------------------------------------------------------------

def _fit(data, ckpt_dir, key, rounds, faults=None, resume=False):
    strat = LocalStrategy(feat_dim=12, num_classes=3, lr=0.5)
    eng = Engine(strat, eval_every=3, checkpoint_dir=ckpt_dir, faults=faults)
    return eng.fit(data, rounds=rounds, key=key, batch_size=8, resume=resume)


@pytest.mark.parametrize("faulted", [False, True])
def test_resume_is_bit_exact_with_uninterrupted(toy, key, tmp_path, faulted):
    def mk_faults():
        if not faulted:
            return None
        return make_fault_process(
            FaultModel(link_fail=0.2, link_repair=0.5, node_fail=0.15,
                       node_repair=0.5, slow_enter=0.2, slow_exit=0.5), 6)

    full_dir, part_dir = str(tmp_path / "full"), str(tmp_path / "part")
    st_full, h_full = _fit(toy, full_dir, key, 12, mk_faults())
    # interrupted run: stops after round 6's checkpoint (a prefix of the
    # full run's eval boundaries), then auto-resumes to the same horizon
    _fit(toy, part_dir, key, 7, mk_faults())
    assert latest_step(part_dir) == 6
    st_res, h_res = _fit(toy, part_dir, key, 12, mk_faults(), resume=True)

    assert h_res.rounds == h_full.rounds
    assert h_res.accuracy == h_full.accuracy
    assert h_res.metrics == h_full.metrics
    for a, b in zip(jax.tree_util.tree_leaves(st_full),
                    jax.tree_util.tree_leaves(st_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# chaos tier: SIGKILL a training subprocess mid-chunk, resume, compare
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("variant", ["plain", "faulted", "paged"])
def test_sigkill_resume_bit_exact(tmp_path, variant):
    script = os.path.join(os.path.dirname(__file__), "_chaos_resume_main.py")
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    extra = [] if variant == "plain" else [variant]

    base_dir = str(tmp_path / "base")
    p = subprocess.run([sys.executable, script, base_dir, "baseline"] + extra,
                       capture_output=True, text=True, env=env, timeout=420)
    assert p.returncode == 0, p.stderr[-4000:]
    baseline = json.loads(p.stdout.strip().splitlines()[-1])

    crash_dir = str(tmp_path / "crash")
    child = subprocess.Popen([sys.executable, script, crash_dir, "crash"]
                             + extra, stdout=subprocess.DEVNULL,
                             stderr=subprocess.DEVNULL, env=env)
    try:
        deadline = time.time() + 300
        while time.time() < deadline:
            if child.poll() is not None:
                break
            ls = latest_step(crash_dir)
            if ls is not None and ls >= 6:   # at least 3 durable checkpoints
                break
            time.sleep(0.05)
        assert child.poll() is None, \
            "crash-mode run finished before the kill landed"
        os.kill(child.pid, signal.SIGKILL)
        child.wait(timeout=60)
    finally:
        if child.poll() is None:
            child.kill()
    assert child.returncode == -signal.SIGKILL
    killed_at = latest_step(crash_dir)
    assert killed_at is not None and killed_at < 29
    if variant == "paged":
        # the killed run must actually have been exercising the incremental
        # population chain (full + dirty-row delta files)
        pops = [f for f in os.listdir(crash_dir) if f.startswith("pop_")
                and f.endswith(".npz")]
        assert len(pops) >= 2, sorted(os.listdir(crash_dir))

    p = subprocess.run([sys.executable, script, crash_dir, "resume"] + extra,
                       capture_output=True, text=True, env=env, timeout=420)
    assert p.returncode == 0, p.stderr[-4000:]
    resumed = json.loads(p.stdout.strip().splitlines()[-1])

    # ISSUE acceptance: resumed History and final state are bit-exact with
    # the uninterrupted run
    assert resumed == baseline
