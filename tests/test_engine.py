"""Unified federation engine: scan-loop fidelity, strategy registry, the
rotating-aggregator schedule, engine-integrated P2P byte accounting, and the
same-seed smoke comparison against pre-refactor trainer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import dp_dsgt, fedavg, local
from repro.baselines.dp_dsgt import DPDSGTStrategy
from repro.baselines.fedavg import FedAvgStrategy
from repro.baselines.local import LocalStrategy
from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
from repro.core.p2p import (P2PNetwork, aggregator_for_round,
                            simulate_group_round, simulate_phase1)
from repro.core.p4 import P4Strategy, P4Trainer
from repro.engine import (Engine, FederatedData, FullParticipation,
                          available_strategies, eval_rounds, get_strategy,
                          sample_client_batches)


# ---------------------------------------------------------------------------
# fixtures (identical to the pre-refactor test fixtures — the reference
# accuracies below were recorded on these exact arrays before the port)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(0)
    M, feat, classes, n = 6, 16, 3, 48
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    xs, ys = [], []
    for c in range(M):
        y = rng.integers(0, classes, n)
        x = protos[y] + rng.normal(size=(n, feat)).astype(np.float32) * 0.4
        xs.append(x)
        ys.append(y)
    X = np.stack(xs)
    Y = np.stack(ys).astype(np.int32)
    return X, Y, jnp.asarray(X), jnp.asarray(Y)


@pytest.fixture(scope="module")
def p4_toy():
    rng = np.random.default_rng(0)
    M, feat, classes, n = 8, 20, 4, 64
    protos = rng.normal(size=(2, classes, feat)).astype(np.float32) * 2
    protos[0, :, feat // 2:] = 0
    protos[1, :, : feat // 2] = 0
    xs, ys = [], []
    for c in range(M):
        y = rng.integers(0, classes, n)
        x = protos[c % 2, y] + rng.normal(size=(n, feat)).astype(np.float32) * 0.5
        xs.append(x)
        ys.append(y)
    return np.stack(xs), np.stack(ys).astype(np.int32)


def _p4_cfg(rounds=40):
    return RunConfig(dp=DPConfig(epsilon=15.0, rounds=rounds, sample_rate=0.5,
                                 clip_norm=1.0),
                     p4=P4Config(group_size=4, sample_peers=7),
                     train=TrainConfig(learning_rate=0.5))


# ---------------------------------------------------------------------------
# registry + schedule plumbing
# ---------------------------------------------------------------------------

def test_registry_has_all_methods():
    have = available_strategies()
    for name in ("p4", "local", "centralized", "fedavg", "scaffold",
                 "proxyfl", "dp_dsgt"):
        assert name in have, f"{name} missing from registry {have}"
    assert get_strategy("local") is LocalStrategy
    with pytest.raises(KeyError):
        get_strategy("nope")


def test_eval_rounds_matches_legacy_cadence():
    # legacy loops evaluated when r % eval_every == 0 or r == rounds - 1
    for start, rounds, every in [(0, 100, 20), (4, 40, 39), (0, 25, 24),
                                 (4, 100, 20), (0, 1, 20)]:
        legacy = [r for r in range(start, rounds)
                  if r % every == 0 or r == rounds - 1]
        assert eval_rounds(start, rounds, every) == legacy, (start, rounds, every)


def test_sample_client_batches_shapes_and_full_batch(key):
    tx = jnp.arange(2 * 10 * 3, dtype=jnp.float32).reshape(2, 10, 3)
    ty = jnp.tile(jnp.arange(10), (2, 1))
    xs, ys = sample_client_batches(tx, ty, key, 4)
    assert xs.shape == (2, 4, 3) and ys.shape == (2, 4)
    # label/features drawn with the SAME index (paired gather)
    np.testing.assert_allclose(np.asarray(xs[..., 0]) // 3 % 10, np.asarray(ys))
    fx, fy = sample_client_batches(tx, ty, key, None)
    assert fx is tx and fy is ty


# ---------------------------------------------------------------------------
# scan-loop fidelity: the chunked lax.scan is bit-identical to a Python
# per-round loop driving the same strategy hooks with the same fold_in keys
# ---------------------------------------------------------------------------

def test_scan_loop_matches_python_loop(toy, key):
    X, Y, tx, ty = toy
    strategy = LocalStrategy(feat_dim=16, num_classes=3, lr=0.5)
    data = FederatedData(X, Y, tx, ty)
    engine = Engine(strategy, eval_every=7)
    state, hist = engine.fit(data, rounds=20, key=key, batch_size=8)

    # reference: python loop reproducing the engine's key derivation
    init_key, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))
    ref = strategy.init(init_key, data, 8)
    for r in range(20):
        rk = jax.random.fold_in(phase_key, r)
        xs, ys = sample_client_batches(data.train_x, data.train_y,
                                       jax.random.fold_in(rk, 0), 8)
        ref, _ = strategy.local_update(ref, xs, ys, r, jax.random.fold_in(rk, 1))
        ref = strategy.aggregate(ref, r, jax.random.fold_in(rk, 2))

    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)
    assert hist.rounds == [0, 7, 14, 19]


# ---------------------------------------------------------------------------
# schedule-refactor bit fidelity: the FullParticipation schedule reproduces
# the PR-2 chunk body bit-for-bit. The reference below IS the PR-2 body
# (reconstructed verbatim: same key folds, same ops, one jitted lax.scan),
# so the RoundSchedule indirection cannot silently change semantics for any
# of p4 / fedavg / dp_dsgt.
# ---------------------------------------------------------------------------

def _pr2_reference(strategy, data, rounds, key, batch_size):
    init_key, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))
    state = strategy.init(init_key, data, batch_size)

    def run(state, phase_key, train_x, train_y, start):
        def body(state, r):
            rk = jax.random.fold_in(phase_key, r)
            xs, ys = sample_client_batches(
                train_x, train_y, jax.random.fold_in(rk, 0), batch_size)
            state, metrics = strategy.local_update(
                state, xs, ys, r, jax.random.fold_in(rk, 1))
            state = strategy.aggregate(state, r, jax.random.fold_in(rk, 2))
            return state, metrics

        return jax.lax.scan(body, state, start + jnp.arange(rounds))

    out, _ = jax.jit(run)(state, phase_key, data.train_x, data.train_y,
                          jnp.asarray(0, jnp.int32))
    return out


def _bit_fidelity_strategies(toy, p4_toy):
    X, Y, tx, ty = toy
    toy_data = FederatedData(X, Y, tx, ty)
    xs, ys = p4_toy
    p4_data = FederatedData(xs, ys, jnp.asarray(xs), jnp.asarray(ys))

    trainer = P4Trainer(feat_dim=20, num_classes=4, cfg=_p4_cfg())
    p4 = P4Strategy(trainer=trainer)
    p4.set_groups([[0, 2, 4, 6], [1, 3, 5, 7]], 8)
    yield p4, p4_data
    yield FedAvgStrategy(feat_dim=16, num_classes=3, lr=0.5, clip=1.0,
                         sigma=0.7, user_ratio=0.8), toy_data
    yield DPDSGTStrategy(feat_dim=16, num_classes=3, lr=0.3, clip=1.0,
                         sigma=0.6), toy_data


def test_full_participation_bit_identical_to_pr2(toy, p4_toy, key):
    for strategy, data in _bit_fidelity_strategies(toy, p4_toy):
        engine = Engine(strategy, eval_every=100,
                        schedule=FullParticipation())
        state, _ = engine.fit(data, rounds=6, key=key, batch_size=16,
                              evaluate=False)
        ref = _pr2_reference(strategy, data, 6, key, 16)
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_default_schedule_history_identical(toy, key):
    """``Engine()`` (schedule defaulted) and an explicit FullParticipation
    produce the same History object contents — same rounds, same accuracies,
    bit-equal final state."""
    X, Y, tx, ty = toy
    data = FederatedData(X, Y, tx, ty)
    s1 = LocalStrategy(feat_dim=16, num_classes=3, lr=0.5)
    st1, h1 = Engine(s1, eval_every=7).fit(data, rounds=20, key=key,
                                           batch_size=8)
    s2 = LocalStrategy(feat_dim=16, num_classes=3, lr=0.5)
    st2, h2 = Engine(s2, eval_every=7, schedule=FullParticipation()).fit(
        data, rounds=20, key=key, batch_size=8)
    assert h1.rounds == h2.rounds and h1.accuracy == h2.accuracy
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# same-seed smoke vs pre-refactor trainers (references recorded on the seed
# commit with these exact fixtures/seeds before the bespoke loops were
# deleted; RNG streams changed host-numpy -> jax.random, so equivalence is
# statistical: same final accuracy on the easy task, same sigma calibration)
# ---------------------------------------------------------------------------

def test_fedavg_matches_pre_refactor(toy):
    X, Y, tx, ty = toy
    _, hist, sigma = fedavg.train(X, Y, tx, ty, rounds=25, lr=0.5,
                                  batch_size=16, epsilon=15.0, eval_every=24)
    assert abs(sigma - 0.72096) < 1e-4   # accounting unchanged by the port
    assert abs(hist[-1][1] - 1.0) < 0.02  # pre-refactor final acc: 1.0


def test_dp_dsgt_matches_pre_refactor(toy):
    X, Y, tx, ty = toy
    _, hist, sigma = dp_dsgt.train(X, Y, tx, ty, rounds=25, lr=0.3,
                                   batch_size=16, epsilon=15.0, eval_every=24)
    assert abs(sigma - 0.66226) < 1e-4
    assert abs(hist[-1][1] - 1.0) < 0.02  # pre-refactor final acc: 1.0


def test_p4_matches_pre_refactor(p4_toy):
    xs, ys = p4_toy
    trainer = P4Trainer(feat_dim=20, num_classes=4, cfg=_p4_cfg())
    _, groups, hist = trainer.fit(xs, ys, jnp.asarray(xs), jnp.asarray(ys),
                                  rounds=40, eval_every=39)
    # pre-refactor: final acc 1.0, groups split exactly along the 2 tasks
    assert abs(hist[-1][1] - 1.0) < 0.02
    for g in groups:
        assert len({i % 2 for i in g}) == 1, groups


# ---------------------------------------------------------------------------
# rotating-aggregator schedule + engine-integrated byte accounting
# ---------------------------------------------------------------------------

def test_rotating_aggregator_schedule():
    group = [3, 5, 8]
    # rotation=1: advances round-robin every round
    assert [aggregator_for_round(group, r, 1) for r in range(6)] == \
        [3, 5, 8, 3, 5, 8]
    # rotation=2: each member aggregates for 2 consecutive rounds
    assert [aggregator_for_round(group, r, 2) for r in range(6)] == \
        [3, 3, 5, 5, 8, 8]
    # rotation=0 is clamped to 1 (no div-by-zero)
    assert aggregator_for_round(group, 4, 0) == 5


def test_engine_byte_accounting_matches_simulate_group_round(p4_toy):
    xs, ys = p4_toy
    rounds, nb = 8, 4
    net = P2PNetwork(8)
    trainer = P4Trainer(feat_dim=20, num_classes=4, cfg=_p4_cfg(rounds))
    states, groups, _ = trainer.fit(xs, ys, jnp.asarray(xs), jnp.asarray(ys),
                                    rounds=rounds, eval_every=rounds - 1,
                                    bootstrap_rounds=nb, network=net)

    # reference: drive simulate_group_round directly for the same groups and
    # co-training rounds with a per-client proxy payload
    ref = P2PNetwork(8)
    for r in range(nb, rounds):
        for g in groups:
            payload = jax.tree_util.tree_map(lambda t: t[g[0]], states["proxy"])
            simulate_group_round(ref, g, payload, rnd=r, rotation=1)

    assert net.num_messages() == ref.num_messages() > 0
    assert net.total_bytes() == ref.total_bytes()
    for kind in ("proxy_update", "aggregated_model"):
        assert net.num_messages(kind) == ref.num_messages(kind)
        assert net.total_bytes(kind) == ref.total_bytes(kind)
    # per-message payload is ONE client's proxy (not the M-stacked tree)
    per_msg = net.total_bytes("proxy_update") / net.num_messages("proxy_update")
    single = len(__import__("pickle").dumps(
        jax.tree_util.tree_map(np.asarray, jax.tree_util.tree_map(
            lambda t: t[0], states["proxy"])), protocol=4))
    assert abs(per_msg - single) < 0.1 * single


def test_phase1_sends_own_slice_only(key):
    M, D = 4, 32
    stacked = {"w": jax.random.normal(key, (M, D))}
    net = P2PNetwork(M)
    simulate_phase1(net, stacked, [(0, 1), (2, 3)])
    assert net.num_messages("phase1_weights") == 2
    # each message carries ONE client's (D,) slice — well under the stacked size
    import pickle
    single = len(pickle.dumps({"w": np.asarray(stacked["w"][0])}, protocol=4))
    full = len(pickle.dumps({"w": np.asarray(stacked["w"])}, protocol=4))
    per_msg = net.total_bytes("phase1_weights") / 2
    assert per_msg < full / 2
    assert abs(per_msg - single) < 0.25 * single


# ---------------------------------------------------------------------------
# checkpoint hook: save at eval points, resume from the latest round
# ---------------------------------------------------------------------------

def test_engine_checkpoint_resume(toy, key, tmp_path):
    X, Y, tx, ty = toy
    data = FederatedData(X, Y, tx, ty)
    strategy = LocalStrategy(feat_dim=16, num_classes=3, lr=0.5)
    engine = Engine(strategy, eval_every=5, checkpoint_dir=str(tmp_path))
    state, hist = engine.fit(data, rounds=10, key=key, batch_size=8)
    assert hist.rounds == [0, 5, 9]

    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 9

    resumed = Engine(LocalStrategy(feat_dim=16, num_classes=3, lr=0.5),
                     eval_every=5, checkpoint_dir=str(tmp_path))
    state2, hist2 = resumed.fit(data, rounds=20, key=key, batch_size=8,
                                resume=True)
    # the sidecar restores the killed run's record, so the resumed History is
    # the full trajectory: restored prefix + continued rounds
    assert hist2.rounds == [0, 5, 9, 10, 15, 19]
    assert hist2.accuracy[:3] == hist.accuracy  # restored bit-exact
    assert hist2.accuracy[-1] > 0.7
