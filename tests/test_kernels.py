"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode
(brief §c: per kernel, sweep shapes/dtypes, assert_allclose vs ref)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dp_clip import ops as dp_ops, ref as dp_ref
from repro.kernels.dp_round import ops as dpr_ops, ref as dpr_ref
from repro.kernels.flash_attention import kernel as fl_kernel, ops as fl_ops, ref as fl_ref
from repro.kernels.l1_distance import ops as l1_ops, ref as l1_ref


# ---------------------------------------------------------------------------
# dp_clip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,D", [(4, 64), (8, 1000), (16, 4096), (5, 333)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dp_clip_flat_sweep(key, B, D, dtype):
    x = (jax.random.normal(key, (B, D)) * 3).astype(dtype)
    got = dp_ops.clip_accumulate_flat(x, 0.9, tb=4, td=256)
    want = dp_ref.clip_accumulate(x, 0.9)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_dp_clip_tree(key):
    tree = {"w": jax.random.normal(key, (6, 10, 3)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 7))}
    got = dp_ops.clip_accumulate_tree(tree, 0.5)
    # oracle via flat path
    from repro.utils.pytree import tree_flatten_concat
    flat = jax.vmap(tree_flatten_concat)(tree)
    want_flat = dp_ref.clip_accumulate(flat, 0.5)
    got_flat = jnp.concatenate([got["b"].ravel(), got["w"].ravel()])
    # tree order: dict sorted keys -> b then w
    np.testing.assert_allclose(np.asarray(got_flat), np.asarray(want_flat),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# l1_distance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,D", [(4, 128), (10, 500), (16, 2048), (7, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l1_sweep(key, M, D, dtype):
    w = (jax.random.normal(key, (M, D)) * 2).astype(dtype)
    got = l1_ops.pairwise_l1(w, tm=4, td=128)
    want = l1_ref.pairwise_l1(w)
    tol = 1e-4 if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,d,blocks", [(128, 32, (64, 64)), (256, 64, (128, 64)),
                                        (256, 32, (64, 128))])
@pytest.mark.parametrize("window", [0, 100])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(key, S, d, blocks, window, dtype):
    BH = 4
    q = jax.random.normal(key, (BH, S, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, d)).astype(dtype)
    got = fl_kernel.flash_attention(q, k, v, causal=True, window=window,
                                    block_q=blocks[0], block_k=blocks[1])
    want = fl_ref.attention(q, k, v, causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_gqa_wrapper(key):
    b, s, hq, hkv, d = 2, 128, 4, 2, 32
    q = jax.random.normal(key, (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    got = fl_ops.flash_attention_gqa(q, k, v, block_q=64, block_k=64)
    kx, vx = jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2)
    def bh(t):
        return t.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    want = fl_ref.attention(bh(q), bh(kx), bh(vx)).reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked_path(key):
    """The Pallas kernel and the pure-JAX chunked path agree (same algorithm,
    two realizations — kernel is the TPU target, chunked is the dry-run path)."""
    from repro.config import ModelConfig
    from repro.models.attention import _chunked_attention
    cfg = ModelConfig(d_model=64, num_heads=4, num_kv_heads=4, vocab_size=64,
                      dtype="float32")
    b, s, h, d = 1, 256, 4, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    chunked = _chunked_attention(q, k, v, cfg, window=0, q_chunk=64, kv_chunk=64)
    flash = fl_ops.flash_attention_gqa(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(flash),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# dp_round (fused local_update -> clip -> noise megakernel, linear model)
# ---------------------------------------------------------------------------

def _linear_problem(key, B, F, C):
    from repro.baselines.common import ce_loss, linear_apply
    kp, kx, ky = jax.random.split(key, 3)
    params = {"w": jax.random.normal(kp, (F, C)) * 0.3,
              "b": jax.random.normal(jax.random.fold_in(kp, 1), (C,)) * 0.1}
    x = jax.random.normal(kx, (B, F)) * 2
    y = jax.random.randint(ky, (B,), 0, C)
    return ce_loss(linear_apply), params, x, y


@pytest.mark.parametrize("B,F,C", [(3, 32, 3), (8, 130, 10), (17, 64, 10)])
@pytest.mark.parametrize("sigma", [0.0, 1.3])
def test_dp_round_closed_matches_reference(key, B, F, C, sigma):
    """The closed-form oracle reorders the autodiff sums but computes the
    same clipped-mean DP gradient — and the SAME noise bits (one canonical
    flat-noise helper, identical [b, w.ravel()] layout)."""
    loss, params, x, y = _linear_problem(key, B, F, C)
    nk = jax.random.fold_in(key, 7)
    want = dpr_ref.dp_round_reference(loss, params, x, y, nk,
                                      clip=0.8, sigma=sigma)
    got = dpr_ref.dp_round_closed(params, x, y, nk, clip=0.8, sigma=sigma)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B", [3, 8, 17])
@pytest.mark.parametrize("F", [32, 130])
@pytest.mark.parametrize("C", [3, 10])
def test_dp_round_kernel_padding_sweep(key, B, F, C):
    """Interpret-mode Pallas passes vs the closed-form oracle across batch /
    feature / class paddings (B to sublane, F to tile, C to lane)."""
    _, params, x, y = _linear_problem(key, B, F, C)
    nk = jax.random.fold_in(key, 3)
    want = dpr_ref.dp_round_closed(params, x, y, nk, clip=1.1, sigma=0.7)
    got = dpr_ops.dp_round_linear(params, x, y, nk, clip=1.1, sigma=0.7,
                                  tf=128, interpret=True)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5)


def test_dp_round_noise_bit_identical_to_canonical_helper(key):
    """With the same key the fused kernel's noise is exactly the canonical
    Eq. 11 draw added onto its own noiseless output."""
    _, params, x, y = _linear_problem(key, 8, 64, 10)
    nk = jax.random.fold_in(key, 5)
    B = x.shape[0]
    noiseless = dpr_ops.dp_round_linear(params, x, y, clip=0.9)
    noised = dpr_ops.dp_round_linear(params, x, y, nk, clip=0.9, sigma=1.3)
    flat = jnp.concatenate([noiseless["b"], noiseless["w"].ravel()])
    want = dp_ref.add_flat_noise(flat, nk, 1.3, 0.9, float(B))
    C = params["b"].shape[0]
    assert np.array_equal(np.asarray(noised["b"]), np.asarray(want[:C]))
    assert np.array_equal(np.asarray(noised["w"]),
                          np.asarray(want[C:].reshape(params["w"].shape)))
