"""Hypothesis property tests on the system's invariants (brief §c).

Runs with real hypothesis when installed, otherwise via the deterministic
fallback in ``_hypothesis_compat`` — the tier no longer skips on hosts
without hypothesis (it used to be the suite's perpetual "1 skipped")."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dp as dp_lib
from repro.core.grouping import greedy_group_formation
from repro.core.p4 import group_mean
from repro.models.layers import softmax_cross_entropy
from repro.models.rope import apply_rope
from repro.utils.pytree import (global_norm, tree_flatten_concat,
                                tree_unflatten_concat)

_settings = settings(max_examples=25, deadline=None)


@_settings
@given(st.integers(1, 6), st.integers(1, 32), st.floats(0.05, 10.0))
def test_clip_never_exceeds_bound(seed, dim, clip):
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (dim, 3)) * 20,
            "b": jax.random.normal(jax.random.fold_in(key, 1), (dim,)) * 20}
    clipped, _ = dp_lib.clip_by_global_norm(tree, clip)
    assert float(global_norm(clipped)) <= clip * (1 + 1e-4)


@_settings
@given(st.integers(0, 5), st.integers(2, 5), st.integers(2, 48))
def test_rope_preserves_norm(seed, heads, seq):
    """Rotation ⇒ per-head-vector l2 norms unchanged."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, seq, heads, 16))
    pos = jnp.arange(seq)[None]
    y = apply_rope(x, pos)
    n1 = jnp.linalg.norm(x, axis=-1)
    n2 = jnp.linalg.norm(y, axis=-1)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=1e-4, atol=1e-4)


@_settings
@given(st.integers(0, 5), st.integers(2, 20))
def test_cross_entropy_nonnegative_and_bounded_below_by_optimal(seed, classes):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (8, classes)) * 3
    labels = jax.random.randint(jax.random.fold_in(key, 1), (8,), 0, classes)
    ce = float(softmax_cross_entropy(logits, labels))
    assert ce >= 0.0


@_settings
@given(st.integers(0, 5), st.integers(4, 24), st.integers(2, 6))
def test_group_mean_idempotent_and_preserves_sum(seed, M, G):
    """Aggregation is a projection: applying it twice == once; the global sum
    of the stacked tree is preserved (means weighted by group sizes)."""
    key = jax.random.PRNGKey(seed)
    ids = jnp.asarray(np.random.default_rng(seed).integers(0, G, M))
    tree = {"w": jax.random.normal(key, (M, 5))}
    once = group_mean(tree, ids, G)
    twice = group_mean(once, ids, G)
    np.testing.assert_allclose(np.asarray(once["w"]), np.asarray(twice["w"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(jnp.sum(once["w"])),
                               float(jnp.sum(tree["w"])), rtol=1e-4)


@_settings
@given(st.integers(0, 8), st.integers(6, 20), st.integers(2, 6))
def test_grouping_always_partitions(seed, M, T):
    rng = np.random.default_rng(seed)
    d = np.abs(rng.normal(size=(M, M)))
    d = d + d.T
    np.fill_diagonal(d, 0)
    groups = greedy_group_formation(d, group_size=T,
                                    sample_peers=min(M - 1, 5), seed=seed)
    assert sorted(sum(groups, [])) == list(range(M))
    assert all(len(g) <= max(T, 3) for g in groups)


@_settings
@given(st.integers(0, 5))
def test_flatten_roundtrip(seed):
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (3, 4)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (7,))}}
    flat = tree_flatten_concat(tree)
    back = tree_unflatten_concat(flat, tree)
    for l1, l2 in zip(jax.tree_util.tree_leaves(tree),
                      jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)


@_settings
@given(st.floats(1.0, 20.0), st.floats(1.0, 20.0))
def test_noble_sigma_monotone_in_epsilon(e1, e2):
    s1 = dp_lib.noble_sigma(e1, 1e-3)
    s2 = dp_lib.noble_sigma(e2, 1e-3)
    if e1 < e2:
        assert s1 >= s2
    else:
        assert s2 >= s1
