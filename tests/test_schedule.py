"""Round-schedule subsystem: cohort statistics and determinism, frozen
absent-client state, zero-byte accounting for absent clients, and the
AsyncStaleness ≡ synchronous equivalence at staleness 0."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.local import LocalStrategy
from repro.config import ScheduleConfig
from repro.core.p2p import P2PNetwork
from repro.core.p4 import P4Trainer, P4Strategy, masked_group_mean
from repro.engine import (AsyncStaleness, ClientSampling, Engine,
                          FederatedData, FullParticipation, make_schedule)


@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(0)
    M, feat, classes, n = 8, 16, 3, 48
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, n))
    xs = protos[ys] + rng.normal(size=(M, n, feat)).astype(np.float32) * 0.4
    X, Y = xs, ys.astype(np.int32)
    return X, Y, jnp.asarray(X), jnp.asarray(Y)


# ---------------------------------------------------------------------------
# mask draws: q in expectation, determinism
# ---------------------------------------------------------------------------

def test_bernoulli_cohort_rate_and_determinism(key):
    M, q, rounds = 40, 0.3, 200
    sched = ClientSampling(q=q)
    masks = np.stack([np.asarray(sched.draw_mask(jax.random.fold_in(key, r), M))
                      for r in range(rounds)])
    assert set(np.unique(masks)) <= {0.0, 1.0}
    assert abs(masks.mean() - q) < 0.05          # matches q in expectation
    # seed-deterministic: same key → same mask; rounds differ from each other
    again = np.asarray(sched.draw_mask(jax.random.fold_in(key, 7), M))
    np.testing.assert_array_equal(again, masks[7])
    assert not (masks[0] == masks[1]).all() or not (masks[1] == masks[2]).all()


def test_fixed_cohort_exact_size(key):
    M, q = 10, 0.25
    sched = ClientSampling(q=q, mode="fixed")
    k = max(1, round(q * M))
    for r in range(20):
        mask = np.asarray(sched.draw_mask(jax.random.fold_in(key, r), M))
        assert mask.sum() == k, (r, mask)
    assert sched.client_fraction(M) == k / M


def test_client_fraction_defaults():
    assert FullParticipation().client_fraction() == 1.0
    assert ClientSampling(q=0.4).client_fraction(16) == 0.4
    assert AsyncStaleness(staleness=3).client_fraction() == 1.0


def test_make_schedule_from_config():
    assert isinstance(make_schedule(None), FullParticipation)
    assert isinstance(make_schedule(ScheduleConfig()), FullParticipation)
    s = make_schedule(ScheduleConfig(kind="sampling", client_rate=0.5,
                                     mode="fixed"))
    assert isinstance(s, ClientSampling) and s.q == 0.5 and s.mode == "fixed"
    a = make_schedule(ScheduleConfig(kind="async", staleness=4))
    assert isinstance(a, AsyncStaleness) and a.staleness == 4
    with pytest.raises(ValueError):
        make_schedule(ScheduleConfig(kind="nope"))


# ---------------------------------------------------------------------------
# absent clients are bit-frozen through the round
# ---------------------------------------------------------------------------

def test_absent_clients_bit_unchanged(toy, key):
    X, Y, tx, ty = toy
    data = FederatedData(X, Y, tx, ty)
    strategy = LocalStrategy(feat_dim=16, num_classes=3, lr=0.5)
    sched = ClientSampling(q=0.5)
    engine = Engine(strategy, eval_every=100, schedule=sched)
    state0 = strategy.init(key, data, 8)
    before = [np.array(l) for l in jax.tree_util.tree_leaves(state0)]
    phase_key = jax.random.fold_in(key, 123)
    state1, _, aux = engine.run_rounds(state0, data, phase_key, 0, 1, 8)
    mask = np.asarray(aux["participation"])[0]
    assert 0 < mask.sum() < len(mask)  # the draw splits the clients
    after = [np.asarray(l) for l in jax.tree_util.tree_leaves(state1)]
    for b, a in zip(before, after):
        for i, bit in enumerate(mask):
            if bit == 0:
                np.testing.assert_array_equal(a[i], b[i])   # bit-frozen
            else:
                assert not np.array_equal(a[i], b[i])       # actually trained


def test_empty_bernoulli_cohort_is_a_noop_round(toy, key):
    """Bernoulli sampling is exact Poisson — an empty draw is NOT patched
    (that would break the q the accountant assumes). The round must be a
    no-op even for server-style strategies whose cohort-weighted aggregation
    has no cohort to weight."""
    from repro.baselines.fedavg import FedAvgStrategy
    X, Y, tx, ty = toy
    data = FederatedData(X, Y, tx, ty)
    M = Y.shape[0]
    sched = ClientSampling(q=0.01)
    strategy = FedAvgStrategy(feat_dim=16, num_classes=3, lr=0.5, sigma=0.0)
    engine = Engine(strategy, eval_every=100, schedule=sched)
    # find a round whose mask is empty (q=0.01, M=8: almost every round)
    phase_key = jax.random.fold_in(key, 7)
    empty_r = next(
        r for r in range(50)
        if np.asarray(sched.draw_mask(jax.random.fold_in(
            jax.random.fold_in(phase_key, r), 3), M)).sum() == 0)
    state0 = strategy.init(key, data, 8)
    before = [np.array(l) for l in jax.tree_util.tree_leaves(state0)]
    state1, _, aux = engine.run_rounds(state0, data, phase_key, empty_r,
                                       empty_r + 1, 8)
    assert np.asarray(aux["participation"]).sum() == 0
    for b, a in zip(before, jax.tree_util.tree_leaves(state1)):
        np.testing.assert_array_equal(np.asarray(a), b)   # global unchanged


def test_calibrate_unreachable_target_raises():
    from repro.engine import PrivacyLedger
    led = PrivacyLedger(sigma=1.0, delta=1e-5, sample_rate=1.0)
    with pytest.raises(ValueError):
        led.calibrate(0.01, rounds=100000)


def test_resume_restores_ledger_spend(toy, key, tmp_path):
    """A resumed run's ledger must include the rounds spent before the
    restart — the released model's (ε, δ) covers the whole trajectory."""
    from repro.engine import PrivacyLedger
    X, Y, tx, ty = toy
    data = FederatedData(X, Y, tx, ty)

    def make():
        strat = LocalStrategy(feat_dim=16, num_classes=3, lr=0.5)
        led = PrivacyLedger(sigma=2.0, delta=1e-3, sample_rate=0.25)
        return Engine(strat, eval_every=5, checkpoint_dir=str(tmp_path),
                      ledger=led)

    eng = make()
    eng.fit(data, rounds=10, key=key, batch_size=8)
    assert eng.ledger.rounds_seen == 10

    resumed = make()
    _, hist = resumed.fit(data, rounds=20, key=key, batch_size=8, resume=True)
    assert resumed.ledger.rounds_seen == 20       # 10 restored + 10 run
    full = PrivacyLedger(sigma=2.0, delta=1e-3, sample_rate=0.25)
    full.advance(20)
    assert abs(hist.metrics["dp_epsilon"][-1] - full.epsilon()) < 1e-9


def test_history_carries_epsilon_and_participation(toy):
    """ISSUE 3 acceptance: cumulative (ε, δ) for every eval round of a
    ClientSampling run."""
    from repro.baselines import fedavg
    X, Y, tx, ty = toy
    _, hist, sigma = fedavg.train(X, Y, tx, ty, rounds=20, lr=0.5,
                                  batch_size=16, epsilon=10.0, eval_every=6,
                                  schedule=ClientSampling(q=0.5))
    n_evals = len(hist.rounds)
    assert hist.rounds == [0, 6, 12, 18, 19]
    assert len(hist.metrics["dp_epsilon"]) == n_evals
    assert len(hist.metrics["dp_delta"]) == n_evals
    assert len(hist.metrics["participation_rate"]) == n_evals
    eps = hist.metrics["dp_epsilon"]
    assert all(a <= b + 1e-9 for a, b in zip(eps, eps[1:]))  # cumulative
    assert abs(eps[-1] - 10.0) < 1e-6   # calibrated to the target budget


# ---------------------------------------------------------------------------
# masked group mean + zero-byte accounting for absent clients
# ---------------------------------------------------------------------------

def test_masked_group_mean_cohort_only(key):
    M, G = 6, 2
    ids = jnp.asarray([0, 0, 0, 1, 1, 1])
    x = jax.random.normal(key, (M, 4))
    mask = jnp.asarray([1.0, 1.0, 0.0, 0.0, 0.0, 1.0])
    out = np.asarray(masked_group_mean({"w": x}, ids, G, mask)["w"])
    xn = np.asarray(x)
    # present members of group 0 get the mean over {0, 1} only
    np.testing.assert_allclose(out[0], (xn[0] + xn[1]) / 2, rtol=1e-6)
    np.testing.assert_allclose(out[1], (xn[0] + xn[1]) / 2, rtol=1e-6)
    # absent members keep their own values
    np.testing.assert_array_equal(out[2], xn[2])
    np.testing.assert_array_equal(out[3], xn[3])
    np.testing.assert_array_equal(out[4], xn[4])
    # sole present member of group 1 averages with itself
    np.testing.assert_allclose(out[5], xn[5], rtol=1e-6)


def _p4_cfg(rounds=8):
    from repro.config import DPConfig, P4Config, RunConfig, TrainConfig
    return RunConfig(dp=DPConfig(epsilon=15.0, rounds=rounds, sample_rate=0.5),
                     p4=P4Config(group_size=4, sample_peers=7),
                     train=TrainConfig(learning_rate=0.5))


def test_absent_client_zero_bytes(toy, key):
    """Every message logged under a sampling schedule has both endpoints in
    that round's cohort — an absent client contributes zero bytes."""
    X, Y, tx, ty = toy
    M = Y.shape[0]
    trainer = P4Trainer(feat_dim=16, num_classes=3, cfg=_p4_cfg())
    strategy = P4Strategy(trainer=trainer)
    strategy.set_groups([[0, 1, 2, 3], [4, 5, 6, 7]], M)
    sched = ClientSampling(q=0.5)
    net = P2PNetwork(M)
    engine = Engine(strategy, eval_every=3, network=net, schedule=sched)
    data = FederatedData(X, Y, tx, ty)
    engine.fit(data, rounds=8, key=key, batch_size=16)
    assert net.num_messages() > 0

    # recompute each round's mask from the engine's key derivation
    _, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))
    masks = {r: np.asarray(sched.draw_mask(
        jax.random.fold_in(jax.random.fold_in(phase_key, r), 3), M))
        for r in range(8)}
    for m in net.log:
        assert m.rnd in masks
        assert masks[m.rnd][m.src] == 1.0, (m, masks[m.rnd])
        assert masks[m.rnd][m.dst] == 1.0, (m, masks[m.rnd])


# ---------------------------------------------------------------------------
# AsyncStaleness
# ---------------------------------------------------------------------------

class _AvgStrategy(LocalStrategy):
    """Local training + mix-toward-the-mean aggregation, so the async merge
    has an observable effect (LocalStrategy's aggregate is the identity)."""

    def aggregate(self, params, r, key):
        mean = jax.tree_util.tree_map(lambda t: jnp.mean(t, 0), params)
        return jax.tree_util.tree_map(
            lambda m, p: 0.5 * p + 0.5 * jnp.broadcast_to(m[None], p.shape),
            mean, params)


def test_async_staleness_zero_equals_synchronous(toy, key):
    X, Y, tx, ty = toy
    data = FederatedData(X, Y, tx, ty)
    s1 = _AvgStrategy(feat_dim=16, num_classes=3, lr=0.5)
    st1, h1 = Engine(s1, eval_every=5, schedule=FullParticipation()).fit(
        data, rounds=12, key=key, batch_size=8)
    s2 = _AvgStrategy(feat_dim=16, num_classes=3, lr=0.5)
    st2, h2 = Engine(s2, eval_every=5, schedule=AsyncStaleness(staleness=0)).fit(
        data, rounds=12, key=key, batch_size=8)
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h1.rounds == h2.rounds and h1.accuracy == h2.accuracy


def test_async_staleness_skips_between_boundaries(toy, key):
    """With staleness s, no merge happens before round s — a short run is
    bit-identical to never aggregating at all."""
    X, Y, tx, ty = toy
    data = FederatedData(X, Y, tx, ty)
    s1 = _AvgStrategy(feat_dim=16, num_classes=3, lr=0.5)
    st1, _ = Engine(s1, eval_every=100, schedule=AsyncStaleness(staleness=10)).fit(
        data, rounds=3, key=key, batch_size=8)
    s2 = LocalStrategy(feat_dim=16, num_classes=3, lr=0.5)  # identity aggregate
    st2, _ = Engine(s2, eval_every=100).fit(data, rounds=3, key=key,
                                            batch_size=8)
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_staleness_merge_is_discounted(toy, key):
    """At a merge boundary the aggregate is folded in with weight
    (1+s)^(-staleness_pow) — verified against a hand-driven reference."""
    X, Y, tx, ty = toy
    data = FederatedData(X, Y, tx, ty)
    s = 1
    sched = AsyncStaleness(staleness=s, staleness_pow=0.5)
    strat = _AvgStrategy(feat_dim=16, num_classes=3, lr=0.5)
    state, _ = Engine(strat, eval_every=100, schedule=sched).fit(
        data, rounds=2, key=key, batch_size=8)

    # reference: two local rounds (engine key derivation), then one merge
    from repro.engine import sample_client_batches
    ref_strat = _AvgStrategy(feat_dim=16, num_classes=3, lr=0.5)
    init_key, phase_key = jax.random.split(jax.random.fold_in(key, 0x9e37))
    ref = ref_strat.init(init_key, data, 8)
    for r in range(2):
        rk = jax.random.fold_in(phase_key, r)
        xs, ys = sample_client_batches(data.train_x, data.train_y,
                                       jax.random.fold_in(rk, 0), 8)
        ref, _ = ref_strat.local_update(ref, xs, ys, r,
                                        jax.random.fold_in(rk, 1))
        if r % (s + 1) == s:
            agg = ref_strat.aggregate(ref, r, jax.random.fold_in(rk, 2))
            w = (s + 1) ** -0.5
            ref = jax.tree_util.tree_map(
                lambda a, b: (w * a + (1 - w) * b).astype(b.dtype), agg, ref)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)
