"""Substrate: optimizers, schedules, data pipeline, checkpointing, config."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.config import (INPUT_SHAPES, MeshConfig, ModelConfig, RunConfig,
                          TrainConfig, apply_overrides, to_json)
from repro.data.partition import alpha_partition, shard_partition
from repro.data.synthetic import make_image_task_pool
from repro.data.tokens import synth_token_batch
from repro.optim import make_optimizer, make_schedule


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw"])
def test_optimizer_converges_quadratic(name):
    cfg = TrainConfig(optimizer=name, learning_rate=0.1, warmup_steps=1,
                      total_steps=500, schedule="constant", weight_decay=0.0)
    opt = make_optimizer(cfg)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_schedule_shapes():
    cfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine")
    sched = make_schedule(cfg)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0)
    assert float(sched(100)) < 0.01
    assert float(sched(5)) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_shard_partition_limits_classes():
    _, labels, _ = make_image_task_pool("cifar10", samples_per_class=50)
    clients = shard_partition(labels, num_clients=12, classes_per_client=2,
                              samples_per_client=40)
    for idx in clients:
        assert len(idx) == 40
        assert len(np.unique(labels[idx])) <= 2


def test_alpha_partition_mixes():
    _, labels, _ = make_image_task_pool("cifar10", samples_per_class=50)
    clients = alpha_partition(labels, num_clients=10, gamma=0.5,
                              samples_per_client=100)
    for c, idx in enumerate(clients):
        own = c % 10
        frac_own = np.mean(labels[idx] == own)
        assert frac_own > 0.4      # ~50% own-class + iid share


def test_token_stream_has_structure(rng):
    toks = synth_token_batch(rng, 4, 512, 1000)
    assert toks.shape == (4, 512)
    # consecutive deltas live in a small set => learnable
    deltas = np.diff(toks, axis=1) % 1000
    assert len(np.unique(deltas)) < 30


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"params": {"w": jax.random.normal(key, (4, 4)),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 7, tree, metadata={"note": "test"})
    assert latest_step(str(tmp_path)) == 7
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                               np.asarray(tree["params"]["w"]))
    assert restored["params"]["b"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

def test_config_overrides_and_json():
    cfg = RunConfig()
    cfg = apply_overrides(cfg, {"dp.epsilon": "3.0", "model.window": "4096",
                                "p4.similarity": "random"})
    assert cfg.dp.epsilon == 3.0
    assert cfg.model.window == 4096
    assert cfg.p4.similarity == "random"
    s = to_json(cfg)
    assert '"epsilon": 3.0' in s


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["decode_32k"].kind == "decode"


def test_mesh_config():
    m = MeshConfig(multi_pod=True)
    assert m.shape == (2, 16, 16) and m.num_devices == 512
    assert MeshConfig().shape == (16, 16)
