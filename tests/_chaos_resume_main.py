"""Crash-safety chaos harness, executed as a subprocess by
``tests/test_resilience.py``.

Usage: ``python _chaos_resume_main.py <ckpt_dir> <mode> [flags...]``

  baseline — uninterrupted fit over the whole horizon, print the record
  crash    — same fit, but every checkpoint save is followed by a short
             sleep so the parent can observe progress and SIGKILL the
             process mid-training (this mode never prints: it dies)
  resume   — ``fit(resume=True)`` from whatever the killed run left behind

Flags: ``faulted`` adds a correlated fault process, so the chaos tier also
covers the fault-chain fast-forward on resume. ``paged`` runs a
``PagedEngine`` under client sampling — the population lives host-side and
checkpoints incrementally (dirty-row deltas + periodic fulls), and the
crash-mode sleeps also land kills BETWEEN a population save and its plain
checkpoint commit point, covering torn incremental chains. Prints ONE JSON
object with the History lists and a SHA-256 over the final state's leaves —
the parent asserts resumed ≡ baseline bit-exactly.
"""
from __future__ import annotations

import hashlib
import json
import sys
import time

ROUNDS, EVAL_EVERY, SEED = 30, 3, 0


def main() -> None:
    ckpt_dir, mode = sys.argv[1], sys.argv[2]
    flags = set(sys.argv[3:])
    faulted = "faulted" in flags
    paged = "paged" in flags

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.baselines.local import LocalStrategy
    from repro.engine import ClientSampling, Engine, FederatedData, PagedEngine
    from repro.resilience import FaultModel, make_fault_process

    if mode == "crash":
        # slow the saves down so the parent reliably lands its SIGKILL
        # between two checkpoints (mid-chunk), never changing what is saved.
        # the population save is slowed too, so some kills land between a
        # population save and the plain-checkpoint commit point (a torn
        # incremental chain the resume must skip past)
        import repro.checkpoint as ck
        orig = ck.save_checkpoint
        orig_pop = ck.save_population

        def slow_save(*args, **kwargs):
            out = orig(*args, **kwargs)
            time.sleep(0.4)
            return out

        def slow_pop_save(*args, **kwargs):
            out = orig_pop(*args, **kwargs)
            time.sleep(0.2)
            return out

        ck.save_checkpoint = slow_save
        ck.save_population = slow_pop_save

    rng = np.random.default_rng(SEED)
    M, feat, classes, n = 6, 12, 3, 32
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, n))
    xs = protos[ys] + rng.normal(size=(M, n, feat)).astype(np.float32) * 0.4
    data = FederatedData(xs, ys.astype(np.int32), jnp.asarray(xs),
                         jnp.asarray(ys.astype(np.int32)))

    faults = None
    if faulted:
        fm = FaultModel(link_fail=0.2, link_repair=0.5, node_fail=0.15,
                        node_repair=0.5, slow_enter=0.2, slow_exit=0.5)
        faults = make_fault_process(fm, M)

    strategy = LocalStrategy(feat_dim=feat, num_classes=classes, lr=0.5)
    if paged:
        # true compact-cohort paged body (client sampling) with the client
        # population host-resident and incrementally checkpointed; a small
        # full_every is implied by the save count (full_every=8 default vs
        # 10 saves over the horizon => the chain re-roots mid-run)
        engine = PagedEngine(strategy, eval_every=EVAL_EVERY,
                             checkpoint_dir=ckpt_dir, faults=faults,
                             schedule=ClientSampling(q=0.5))
    else:
        engine = Engine(strategy, eval_every=EVAL_EVERY,
                        checkpoint_dir=ckpt_dir, faults=faults)
    state, hist = engine.fit(data, rounds=ROUNDS, key=jax.random.PRNGKey(SEED),
                             batch_size=8, resume=(mode == "resume"))

    sha = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(state):
        sha.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    print(json.dumps({"rounds": hist.rounds, "accuracy": hist.accuracy,
                      "metrics": hist.metrics, "state_sha": sha.hexdigest()}))


if __name__ == "__main__":
    sys.exit(main())
