"""Sharding rules + a miniature end-to-end dry-run (8 fake devices, subprocess
so the XLA device-count flag can't leak into this test process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig
from repro.configs import get_config
from repro.models.module import ParamSpec, partition_specs
from repro.sharding.rules import make_rules, logical_spec


def test_rules_divisibility():
    """Axes are only assigned when the dim divides the mesh axis size."""
    mesh = MeshConfig()                      # data=16, model=16
    qwen3 = get_config("qwen3-14b")          # 40 heads -> not divisible by 16
    r = make_rules(qwen3, mesh)
    assert r["heads"] is None
    assert r["ffn"] == "model"               # 17408 % 16 == 0
    assert r["vocab"] == "model"
    granite = get_config("granite-34b")      # 48 heads, kv=1
    r2 = make_rules(granite, mesh)
    assert r2["heads"] == "model"
    assert r2["kv_heads"] is None            # 1 % 16 != 0
    mix = get_config("mixtral-8x22b")        # 8 experts -> no EP over data=16
    r3 = make_rules(mix, mesh)
    assert r3["experts"] is None
    moon = get_config("moonshot-v1-16b-a3b") # 64 experts -> EP over data
    r4 = make_rules(moon, mesh)
    assert r4["experts"] == "data"


def test_partition_specs_dedupe():
    """A mesh axis may appear at most once per spec."""
    rules = {"experts": "data", "embed": "data", "ffn": "model"}
    spec = {"w": ParamSpec((4, 8, 16), ("experts", "embed", "ffn"))}
    out = partition_specs(spec, rules)
    assert out["w"] == P("data", None, "model")


def test_logical_spec_multi_axis():
    rules = {"batch": ("pod", "data"), "seq": None, "vocab": "model"}
    assert logical_spec(("batch", "seq", "vocab"), rules) == P(("pod", "data"), None, "model")


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """A reduced arch lowers + compiles on a small fake mesh with the same
    machinery the production dry-run uses."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.config import MeshConfig, TrainConfig, InputShape
        from repro.configs import get_reduced_config
        from repro.models.api import (build_model, input_specs, input_shardings,
                                      make_train_step)
        from repro.models.module import partition_specs
        from repro.sharding.rules import make_rules, activation_sharding

        cfg = get_reduced_config("llama3.2-1b")
        mesh_cfg = MeshConfig(data=4, model=2)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = make_rules(cfg, mesh_cfg, kind="train")
        api = build_model(cfg)
        shape = InputShape("mini", 64, 8, "train")
        ns = lambda p: NamedSharding(mesh, p)
        pspecs = partition_specs(api.specs, rules)
        p_shard = jax.tree_util.tree_map(ns, pspecs, is_leaf=lambda x: isinstance(x, P))
        b_specs = input_shardings(cfg, shape, mesh_cfg, rules)
        b_shard = jax.tree_util.tree_map(ns, b_specs, is_leaf=lambda x: isinstance(x, P))
        step, opt = make_train_step(api, TrainConfig())
        params_abs = api.abstract()
        opt_abs = jax.eval_shape(opt.init, params_abs)
        mv = p_shard
        o_shard = {"m": mv, "v": mv, "count": ns(P())}
        with mesh, activation_sharding(mesh, rules):
            lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                              out_shardings=(p_shard, o_shard, None)).lower(
                params_abs, opt_abs, input_specs(cfg, shape))
            compiled = lowered.compile()
        from repro.launch.roofline import cost_analysis_dict
        cost = cost_analysis_dict(compiled)
        print(json.dumps({"flops": cost.get("flops", 0.0),
                          "ok": True}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert p.returncode == 0, p.stderr[-2000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["flops"] > 0


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
    ENTRY %main {
      %ag = f32[16,1024]{1,0} all-gather(f32[2,1024] %x), dimensions={0}
      %ar = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) all-reduce(...)
      %dot = f32[8,8] dot(...)
      %a2a = f32[4,256]{1,0} all-to-all(...)
    }
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 4      # result bytes only
    assert out["all-reduce"] == 2 * 8 * 128 * 2
    assert out["all-to-all"] == 4 * 256 * 4
    assert out["counts"]["all-gather"] == 1


def test_roofline_terms():
    from repro.launch.roofline import roofline_terms
    t = roofline_terms(197e12, 819e9, 50e9)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
