"""Multi-device equivalence tier for the sharded federation engine.

The 8-device scenarios run once in a subprocess (XLA's fake-device flag must
precede jax init — same recipe as the mini dry-run) executing
``tests/_sharded_equivalence_main.py``; each test asserts one scenario's
record, so failures name the exact (strategy × schedule × layout) combo.

In-process tests cover the pieces that don't need fake devices: the global
compiled-chunk cache (σ-sweep reuse + cache_token invalidation), the
calibrate-then-resume ledger composition, host-mesh clamping, and the
degenerate 1-slice client mesh (which exercises the whole shard_map path on
the single real device, keeping the plumbing honest inside tier-1's fast
set)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.local import LocalStrategy
from repro.config import DPConfig
from repro.engine import (CHUNK_STATS, ClientSampling, Engine, FederatedData,
                          PrivacyLedger, ShardedEngine, Strategy,
                          clear_chunk_cache)
from repro.launch.mesh import host_mesh_shape, make_client_mesh


# ---------------------------------------------------------------------------
# 8-fake-device equivalence scenarios (subprocess, module-scoped: one jax
# startup + compile budget amortized over every assertion below)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def equivalence():
    script = os.path.join(os.path.dirname(__file__),
                          "_sharded_equivalence_main.py")
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
    )
    p = subprocess.run([sys.executable, script], capture_output=True,
                       text=True, env=env, timeout=560)
    assert p.returncode == 0, p.stderr[-4000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


def _assert_bit_exact(rec):
    assert rec["rounds_equal"]
    assert rec["accuracy_bit_equal"], rec
    assert rec["state_bit_equal"], rec


@pytest.mark.slow
def test_subprocess_saw_eight_devices(equivalence):
    assert equivalence["devices"] == 8


@pytest.mark.slow
def test_full_participation_bit_exact_histories(equivalence):
    """ISSUE 4 acceptance: sharded FullParticipation histories (and states,
    where the backend's fusion allows) are bit-exact vs the single-device
    engine for p4 / fedavg (gather reduction) / dp_dsgt."""
    for name in ("local_full", "fedavg_full", "p4_full_gather",
                 "p4_full_resident"):
        _assert_bit_exact(equivalence[name])
    # DP-DSGT's gossip runs as a ppermute halo exchange; XLA contracts the
    # mix's multiply-adds differently per layout, so states agree to float
    # ulps while the recorded histories stay bit-equal
    rec = equivalence["dsgt_full"]
    assert rec["rounds_equal"] and rec["accuracy_bit_equal"], rec
    assert rec["state_maxdiff"] < 1e-6, rec


@pytest.mark.slow
def test_uneven_padding_bit_exact(equivalence):
    """M % devices != 0: padded slots never leak into results."""
    _assert_bit_exact(equivalence["local_full_uneven"])
    _assert_bit_exact(equivalence["local_sampling_uneven"])
    rec = equivalence["dsgt_full_uneven"]
    assert rec["rounds_equal"] and rec["accuracy_bit_equal"], rec
    assert rec["state_maxdiff"] < 1e-6, rec


@pytest.mark.slow
def test_client_sampling_equivalence(equivalence):
    """Sampling draws the identical (M,) cohort mask on every slice; states
    match to tight tolerance (bit-exact for the gather-aggregated ones)."""
    _assert_bit_exact(equivalence["fedavg_sampling"])
    _assert_bit_exact(equivalence["p4_sampling"])
    _assert_bit_exact(equivalence["p4_sampling_resident"])
    rec = equivalence["dsgt_sampling"]
    assert rec["rounds_equal"] and rec["accuracy_maxdiff"] < 1e-5, rec
    assert rec["state_maxdiff"] < 1e-6, rec


@pytest.mark.slow
def test_async_staleness_equivalence(equivalence):
    _assert_bit_exact(equivalence["fedavg_async0"])   # s=0 ≡ synchronous
    for name in ("p4_async1", "dsgt_async2"):
        rec = equivalence[name]
        assert rec["rounds_equal"] and rec["accuracy_maxdiff"] < 1e-5, rec
        assert rec["state_maxdiff"] < 1e-6, (name, rec)


@pytest.mark.slow
def test_fedavg_psum_tree_reduction(equivalence):
    """ISSUE 5 satellite: the default psum-tree cohort mean is bit-close to
    both the single-device engine and the gather path on the same mesh."""
    for name in ("fedavg_psum_full", "fedavg_psum_sampling"):
        rec = equivalence[name]
        assert rec["rounds_equal"] and rec["accuracy_maxdiff"] < 1e-5, rec
        assert rec["state_maxdiff"] < 1e-5, (name, rec)
    rec = equivalence["fedavg_psum_vs_gather"]
    assert rec["rounds_equal"] and rec["state_maxdiff"] < 1e-5, rec


@pytest.mark.slow
def test_scaffold_proxyfl_sharded_ports(equivalence):
    """ISSUE 5 satellite (open ROADMAP item): Scaffold and ProxyFL run under
    the ShardedEngine — bit-exact vs single-device, including the mixed
    stacked/replicated Scaffold carry and uneven padding."""
    for name in ("scaffold_full", "scaffold_sampling", "scaffold_uneven",
                 "proxyfl_full", "proxyfl_uneven"):
        _assert_bit_exact(equivalence[name])


@pytest.mark.slow
def test_dsgt_topology_equivalence(equivalence):
    """ISSUE 5 acceptance: sharded ≡ single-device for a non-ring topology
    (4-regular expander, gossip-matching sequence) and the shard-resident
    slice-local mixing path."""
    for name in ("dsgt_topology_expander", "dsgt_gossip_sequence",
                 "dsgt_topology_resident"):
        rec = equivalence[name]
        assert rec["rounds_equal"] and rec["accuracy_bit_equal"], (name, rec)
        assert rec["state_maxdiff"] < 1e-6, (name, rec)


@pytest.mark.slow
def test_dsgt_faulty_topology_equivalence(equivalence):
    """ISSUE 5 acceptance: a faulty run (drop probability > 0) — the in-jit
    fault draws are replicated, so every slice realizes the same topology."""
    for name in ("dsgt_topology_faulty", "dsgt_topology_resident_faulty"):
        rec = equivalence[name]
        assert rec["rounds_equal"] and rec["accuracy_maxdiff"] < 1e-5, (name,
                                                                        rec)
        assert rec["state_maxdiff"] < 1e-6, (name, rec)


@pytest.mark.slow
def test_dsgt_learned_pushsum_equivalence(equivalence):
    """ISSUE 9 acceptance: learned directed graphs (column-stochastic W,
    push-sum weight scalar riding the x mix as a joint leaf) stay sharded ≡
    single-device — static estimate, a two-estimate time-varying window, and
    a faulted run (the sender-side diagonal fold keeps the realized matrix
    column-stochastic)."""
    for name in ("dsgt_learned_pushsum", "dsgt_learned_timevarying"):
        rec = equivalence[name]
        assert rec["rounds_equal"] and rec["accuracy_bit_equal"], (name, rec)
        assert rec["state_maxdiff"] < 1e-6, (name, rec)
    rec = equivalence["dsgt_learned_faulty"]
    assert rec["rounds_equal"] and rec["accuracy_maxdiff"] < 1e-5, rec
    assert rec["state_maxdiff"] < 1e-6, rec


@pytest.mark.slow
def test_banded_topologies_gather_free(equivalence):
    """ISSUE 7 acceptance: banded/bounded-bandwidth graphs (ring, faulty
    ring, keep-masked ring, torus, circulant expander) never fall back to
    the all_gather mixing path — the collective probe records only halo
    ppermutes for them (per chunk trace, so 0 gathers is 0 outright)."""
    for name in ("dsgt_full", "dsgt_ring_faulty", "dsgt_ring_burst",
                 "dsgt_torus", "dsgt_topology_expander",
                 "dsgt_topology_faulty"):
        stats = equivalence[name]["mix_stats"]
        assert stats["all_gathers"] == 0, (name, stats)
        assert stats["path_gather"] == 0, (name, stats)
        assert stats["ppermutes"] > 0, (name, stats)
        assert stats["path_halo"] > 0, (name, stats)
    # shard-resident layout: no collective of either kind in the mix
    stats = equivalence["dsgt_topology_resident"]["mix_stats"]
    assert stats["all_gathers"] == 0 and stats["ppermutes"] == 0, stats
    assert stats["path_local"] > 0, stats


@pytest.mark.slow
def test_banded_faulty_equivalence(equivalence):
    """ISSUE 7 satellite: keep-masked / i.i.d.-faulty rings and the torus
    route through the halo path AND stay equivalent to single-device."""
    for name in ("dsgt_ring_faulty", "dsgt_ring_burst", "dsgt_torus"):
        rec = equivalence[name]
        assert rec["rounds_equal"] and rec["accuracy_maxdiff"] < 1e-5, (name,
                                                                       rec)
        assert rec["state_maxdiff"] < 1e-6, (name, rec)


@pytest.mark.slow
def test_topology_resident_layout(equivalence):
    layout = equivalence["topology_resident_layout"]
    assert layout["resident_on_2"] is True
    assert layout["resident_on_8"] is False   # m=1: 4-cliques must span


@pytest.mark.slow
def test_p4_fault_injection_equivalence(equivalence):
    """Fault-injected P4 group rounds: the member↔aggregator drop masks
    realize identically on the resident (sliced mask) and gather layouts."""
    for name in ("p4_faulty_resident", "p4_faulty_gather"):
        rec = equivalence[name]
        assert rec["rounds_equal"] and rec["accuracy_maxdiff"] < 1e-5, (name,
                                                                        rec)
        assert rec["state_maxdiff"] < 1e-6, (name, rec)


@pytest.mark.slow
def test_correlated_fault_regimes_equivalence(equivalence):
    """ISSUE 6 acceptance: sharded ≡ single-device under the stateful fault
    chains — the ``FaultState`` carry is replicated, so every slice steps the
    identical Gilbert–Elliott / churn / partition realization."""
    for name in ("dsgt_fault_burst", "dsgt_fault_churn",
                 "dsgt_fault_partition"):
        rec = equivalence[name]
        assert rec["rounds_equal"] and rec["accuracy_bit_equal"], (name, rec)
        assert rec["state_maxdiff"] < 1e-6, (name, rec)


@pytest.mark.slow
def test_straggler_chain_equivalence(equivalence):
    """Straggler chains feed AsyncStaleness the realized per-client ages;
    the fault-blended merge matches bit-exactly (FedAvg's server-style fold)
    or to float ulps (P4's stacked per-client blend)."""
    _assert_bit_exact(equivalence["fedavg_fault_straggler"])
    rec = equivalence["p4_fault_straggler"]
    assert rec["rounds_equal"] and rec["accuracy_bit_equal"], rec
    assert rec["state_maxdiff"] < 1e-6, rec


@pytest.mark.slow
def test_aggregator_failover_equivalence(equivalence):
    """Node churn + quorum: the traced failover mask (next-up aggregator,
    below-quorum groups silenced) realizes identically on the resident and
    gather layouts."""
    _assert_bit_exact(equivalence["p4_fault_failover_resident"])
    _assert_bit_exact(equivalence["p4_fault_failover_gather"])


@pytest.mark.slow
def test_p4_group_layouts(equivalence):
    """Groups that fit one slice aggregate without any collective; spanning
    groups take the gather path — both bit-exact."""
    layout = equivalence["p4_resident_layout"]
    assert layout["resident_on_2"] is True
    assert layout["resident_on_8"] is False   # m=1: a group of 4 must span


@pytest.mark.slow
def test_paged_engine_bit_exact(equivalence):
    """ISSUE 8 acceptance (paged ≡ resident tier): the host-resident
    population with paged cohorts reproduces the resident engine bit-exactly
    — final state AND full History (accuracy + every metric) — for
    p4 / fedavg / dp_dsgt across full / sampling / async schedules,
    including uneven cohort sizes (M=6 fixed-k, Bernoulli draws) and a
    non-ring gossip graph whose in-neighbor closure the cohort planner must
    page in."""
    for name in ("paged_fedavg_full", "paged_fedavg_sampling_uneven",
                 "paged_fedavg_bernoulli", "paged_fedavg_async0",
                 "paged_dsgt_full", "paged_dsgt_sampling",
                 "paged_dsgt_sampling_uneven", "paged_dsgt_async2",
                 "paged_dsgt_expander_sampling", "paged_p4_full",
                 "paged_p4_async1"):
        rec = equivalence[name]
        _assert_bit_exact(rec)
        assert rec["metrics_bit_equal"], (name, rec)


@pytest.mark.slow
def test_paged_engine_p4_sampling(equivalence):
    """P4 under sampling: state, accuracy, and every non-train metric stay
    bit-exact; the train-loss means are the one documented paged difference
    (cohort mean vs the resident's full-M mean over never-aggregated local
    passes) and only need to stay in-range."""
    rec = equivalence["paged_p4_sampling"]
    _assert_bit_exact(rec)
    assert rec["metrics_bit_equal"], rec
    assert rec["excluded_maxdiff"] < 2.0, rec


@pytest.mark.slow
def test_paged_engine_fault_regime(equivalence):
    """Paged ≡ resident under a correlated node-churn process: the planned
    cohort is a superset of realized participants (faults only remove
    clients), the fault carry is full-M, and absent clients stay
    bit-frozen."""
    rec = equivalence["paged_fedavg_sampling_faulty"]
    _assert_bit_exact(rec)
    assert rec["metrics_bit_equal"], rec


@pytest.mark.slow
def test_paged_engine_cohort_mesh(equivalence):
    """Cohort axis sharded over the 8-device clients mesh (GSPMD partition
    of the paged chunk): numerically tight vs the resident engine (bit-level
    agreement is not contractual — partitioned reductions may
    reassociate)."""
    rec = equivalence["paged_mesh_fedavg_sampling"]
    assert rec["rounds_equal"], rec
    assert rec["accuracy_maxdiff"] < 1e-5, rec
    assert rec["state_maxdiff"] < 1e-5, rec


@pytest.mark.slow
def test_telemetry_off_and_tap_on_equivalence(equivalence):
    """ISSUE 10 zero-overhead-off contract on the sharded path: a disabled
    Telemetry builds the unchanged chunk-cache key and is bit-exact with no
    telemetry at all — and an ENABLED tap is bit-exact too, because the
    sharded trace stays tap-free (per-round events stream host-side from
    the stacked chunk outputs, covering every round exactly once)."""
    rec = equivalence["telemetry_off_sharded"]
    assert rec["chunk_key_unchanged"], rec
    assert rec["rounds_equal"] and rec["accuracy_bit_equal"], rec
    assert rec["state_bit_equal"], rec
    assert rec["tap_rounds"] == list(range(8)), rec


@pytest.mark.slow
def test_p4_end_to_end_bit_exact(equivalence):
    """Whole trainer pipeline under a client mesh: bootstrap, host-side
    greedy grouping (identical groups — the bootstrap states are bit-exact),
    co-training, privacy ledger."""
    rec = equivalence["p4_end_to_end"]
    assert rec["groups_equal"], rec
    assert rec["rounds_equal"] and rec["accuracy_bit_equal"], rec
    assert rec["state_bit_equal"], rec
    assert rec["metrics_maxdiff"] < 1e-6, rec


@pytest.mark.slow
def test_zero_byte_accounting_for_absent_clients(equivalence):
    """Sharded byte accounting sees the exact single-device cohorts: same
    message/byte totals, and every logged message has both endpoints in that
    round's cohort."""
    rec = equivalence["zero_byte_accounting"]
    assert rec["nonzero"] and rec["messages_equal"] and rec["bytes_equal"], rec
    assert rec["endpoints_in_cohort"], rec


# ---------------------------------------------------------------------------
# compiled-chunk cache: σ sweeps must not re-trace; cache_token bumps must
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(0)
    M, feat, classes, n = 6, 12, 3, 32
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, n))
    xs = protos[ys] + rng.normal(size=(M, n, feat)).astype(np.float32) * 0.4
    X, Y = xs, ys.astype(np.int32)
    return FederatedData(X, Y, jnp.asarray(X), jnp.asarray(Y))


def _dp_local(sigma):
    return LocalStrategy(feat_dim=12, num_classes=3, lr=0.5,
                         dp_cfg=DPConfig(clip_norm=1.0), sigma=sigma)


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


def test_sigma_sweep_reuses_compiled_chunk(toy, key):
    """ISSUE 4 satellite: a sweep over σ with the same (length, batch_size,
    cache_token, mesh) compiles ONE chunk — σ reaches the trace as a runtime
    argument — and the reused chunk is bit-identical to a fresh compile."""
    clear_chunk_cache()
    finals = {}
    for sigma in (0.5, 0.9, 1.3):
        strat = _dp_local(sigma)
        st, _ = Engine(strat, eval_every=100).fit(
            toy, rounds=6, key=key, batch_size=8, evaluate=False)
        finals[sigma] = st
    assert CHUNK_STATS["traces"] == 1, CHUNK_STATS
    assert CHUNK_STATS["misses"] == 1 and CHUNK_STATS["hits"] == 2

    # σ actually flowed into the reused chunk: the noise differs
    assert not all(np.array_equal(a, b) for a, b in
                   zip(_leaves(finals[0.5]), _leaves(finals[1.3])))

    # reuse is bit-faithful: a cold-cache compile at σ=1.3 matches the state
    # the warm chunk produced
    clear_chunk_cache()
    fresh, _ = Engine(_dp_local(1.3), eval_every=100).fit(
        toy, rounds=6, key=key, batch_size=8, evaluate=False)
    for a, b in zip(_leaves(fresh), _leaves(finals[1.3])):
        np.testing.assert_array_equal(a, b)


def test_cache_token_bump_retraces(toy, key):
    clear_chunk_cache()
    strat = _dp_local(0.7)
    Engine(strat, eval_every=100).fit(toy, rounds=6, key=key, batch_size=8,
                                      evaluate=False)
    assert CHUNK_STATS["traces"] == 1
    strat.cache_token += 1   # what set_groups does between P4 phases
    Engine(strat, eval_every=100).fit(toy, rounds=6, key=key, batch_size=8,
                                      evaluate=False)
    assert CHUNK_STATS["traces"] == 2, CHUNK_STATS


def test_target_epsilon_recalibration_reuses_chunk(toy, key):
    """set_sigma no longer invalidates chunks: two target-ε runs share one
    compiled chunk and still land on their own budgets."""
    clear_chunk_cache()
    spent = {}
    for target in (6.0, 12.0):
        strat = _dp_local(1.0)
        ledger = PrivacyLedger(sigma=1.0, delta=1e-3, sample_rate=0.25)
        _, hist = Engine(strat, eval_every=100, ledger=ledger).fit(
            toy, rounds=8, key=key, batch_size=8, target_epsilon=target)
        spent[target] = hist.metrics["dp_epsilon"][-1]
    # the eval cadence splits 8 rounds into a length-1 and a length-7 chunk:
    # two traces for the FIRST target, pure cache hits for the second
    assert CHUNK_STATS["traces"] == 2, CHUNK_STATS
    for target, got in spent.items():
        assert abs(got - target) < 1e-6, spent


def test_sharded_mesh_is_part_of_the_cache_key(toy, key):
    """Same strategy fingerprint, different execution layout → different
    chunk; same layout twice → reuse."""
    clear_chunk_cache()
    mesh = make_client_mesh()   # 1 slice on the test host
    Engine(_dp_local(0.7), eval_every=100).fit(
        toy, rounds=6, key=key, batch_size=8, evaluate=False)
    ShardedEngine(_dp_local(0.7), eval_every=100, mesh=mesh).fit(
        toy, rounds=6, key=key, batch_size=8, evaluate=False)
    assert CHUNK_STATS["traces"] == 2, CHUNK_STATS
    ShardedEngine(_dp_local(0.9), eval_every=100, mesh=mesh).fit(
        toy, rounds=6, key=key, batch_size=8, evaluate=False)
    assert CHUNK_STATS["traces"] == 2, CHUNK_STATS


# ---------------------------------------------------------------------------
# degenerate 1-slice client mesh: the full shard_map path on the real device
# ---------------------------------------------------------------------------

def test_sharded_engine_single_slice_matches_engine(toy, key):
    st1, h1 = Engine(_dp_local(0.6), eval_every=3).fit(
        toy, rounds=7, key=key, batch_size=8)
    st2, h2 = ShardedEngine(_dp_local(0.6), eval_every=3,
                            mesh=make_client_mesh()).fit(
        toy, rounds=7, key=key, batch_size=8)
    assert h1.rounds == h2.rounds and h1.accuracy == h2.accuracy
    for a, b in zip(_leaves(st1), _leaves(st2)):
        np.testing.assert_array_equal(a, b)


def test_sharded_engine_rejects_unkeyed_strategy(toy, key):
    # scaffold/proxyfl are ported now — fabricate a strategy that only has
    # the unkeyed hook to keep the clean-rejection contract covered
    class UnkeyedStrategy(LocalStrategy):
        local_update_keyed = Strategy.local_update_keyed

    strat = UnkeyedStrategy(feat_dim=12, num_classes=3, lr=0.5)
    eng = ShardedEngine(strat, eval_every=100, mesh=make_client_mesh())
    with pytest.raises(NotImplementedError, match="local_update_keyed"):
        eng.fit(toy, rounds=2, key=key, batch_size=8, evaluate=False)


def test_sharded_engine_requires_client_axis(toy):
    import jax as _jax
    mesh = _jax.make_mesh((1, 1), ("data", "model"),
                          devices=_jax.devices()[:1])
    with pytest.raises(ValueError, match="clients"):
        ShardedEngine(_dp_local(0.5), mesh=mesh)


# ---------------------------------------------------------------------------
# ledger: calibrate-then-resume composes onto the restored spend
# ---------------------------------------------------------------------------

def test_calibrate_then_resume_composes_budget(toy, key, tmp_path):
    """Engine.fit double-advance fix: calibration happens AFTER the resume
    branch, for the remaining rounds only, composed on the ledger's restored
    spend — the whole 20-round trajectory lands exactly on the (raised)
    resume budget instead of overshooting it."""

    def make(sigma):
        strat = _dp_local(sigma)
        ledger = PrivacyLedger(sigma=sigma, delta=1e-3, sample_rate=0.25)
        eng = Engine(strat, eval_every=5, checkpoint_dir=str(tmp_path),
                     ledger=ledger)
        return eng, strat

    eng, strat = make(1.0)
    eng.fit(toy, rounds=10, key=key, batch_size=8, target_epsilon=8.0)
    sigma1 = strat.sigma
    assert abs(eng.ledger.epsilon() - 8.0) < 1e-6

    eng2, strat2 = make(sigma1)   # resume at the σ the first run trained with
    _, hist = eng2.fit(toy, rounds=20, key=key, batch_size=8, resume=True,
                       target_epsilon=12.0)
    assert eng2.ledger.rounds_seen == 20
    # rounds 0..10 restored at σ1 already spent ε=8; the recalibrated σ fits
    # rounds 10..20 into the remaining budget. Pre-fix, calibration ran
    # before the resume advanced start_round (sizing σ for 20 fresh rounds)
    # and ignored the restored spend — the trajectory missed the target.
    assert abs(hist.metrics["dp_epsilon"][-1] - 12.0) < 1e-6
    # the recalibration solved a different problem than run 1's (compose onto
    # ε=8 of restored spend), so it found a different σ
    assert abs(strat2.sigma - sigma1) > 1e-3


# ---------------------------------------------------------------------------
# host-mesh clamping (pure) + client-mesh construction
# ---------------------------------------------------------------------------

def test_host_mesh_shape_explicit_clamping():
    assert host_mesh_shape(4, 2, 8) == (4, 2)
    assert host_mesh_shape(16, 16, 8) == (8, 1)    # data eats every device
    assert host_mesh_shape(3, 4, 8) == (3, 2)      # model fits what's left
    assert host_mesh_shape(0, 4, 8) == (1, 4)      # no n//0 crash
    assert host_mesh_shape(2, 0, 8) == (2, 1)
    assert host_mesh_shape(5, 5, 1) == (1, 1)
    assert host_mesh_shape(1, 1, 0) == (1, 1)
    d, m = host_mesh_shape(3, 3, 8)
    assert d * m <= 8 and d >= 1 and m >= 1


def test_make_host_mesh_on_real_devices():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(4, 4)   # clamps to whatever the host has
    n = len(jax.devices())
    d, m = mesh.shape["data"], mesh.shape["model"]
    assert (d, m) == host_mesh_shape(4, 4, n)


def test_make_client_mesh_shape():
    mesh = make_client_mesh()
    assert tuple(mesh.shape.keys()) == ("clients",)
    assert mesh.shape["clients"] == len(jax.devices())
    assert make_client_mesh(1).shape["clients"] == 1
