"""Observability subsystem: probe-registry semantics, ``History.record``
validation, the telemetry event-log/manifest contract (JSONL trajectory ≡
returned History), the in-jit tap's bit-exactness, the zero-overhead-off
guarantee, and the monitor CLI."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.fedavg import FedAvgStrategy
from repro.baselines.local import LocalStrategy
from repro.engine import (CHUNK_STATS, Engine, FederatedData, History,
                          PrivacyLedger, clear_chunk_cache)
from repro.launch import monitor
from repro.obs import (Probe, ProbeRegistry, REGISTRY, Telemetry, get_probe,
                       probe_deltas)
from repro.topology.mixing import MIX_STATS


@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(0)
    M, feat, classes, n = 6, 16, 3, 48
    protos = rng.normal(size=(classes, feat)).astype(np.float32) * 3
    ys = rng.integers(0, classes, size=(M, n)).astype(np.int32)
    xs = protos[ys] + rng.normal(size=(M, n, feat)).astype(np.float32) * 0.4
    return FederatedData(xs, ys, jnp.asarray(xs), jnp.asarray(ys))


def _strategy():
    return FedAvgStrategy(feat_dim=16, num_classes=3, lr=0.5, clip=1.0,
                          sigma=0.7)


# ---------------------------------------------------------------------------
# probe registry
# ---------------------------------------------------------------------------

def test_probe_keeps_plain_dict_semantics():
    reg = ProbeRegistry()
    p = Probe("t.counters", {"hits": 0, "seconds": 0.0}, registry=reg)
    p["hits"] += 3
    p.update(seconds=1.5)
    assert dict(p) == {"hits": 3, "seconds": 1.5}
    assert reg.get("t.counters") is p
    assert reg.snapshot()["t.counters"] == {"hits": 3, "seconds": 1.5}
    p["late_key"] = 7          # keys born after construction reset to int 0
    p.reset()
    assert dict(p) == {"hits": 0, "seconds": 0.0, "late_key": 0}
    assert isinstance(p["seconds"], float)


def test_probe_deltas_nest_and_freeze():
    reg = ProbeRegistry()
    p = Probe("t.nest", {"n": 0}, registry=reg)
    p["n"] += 100              # pre-scope counts must not leak into deltas
    with reg.deltas("t.nest") as outer:
        p["n"] += 1
        with reg.deltas("t.nest") as inner:
            p["n"] += 2
            assert inner["t.nest"]["n"] == 2    # live read inside the scope
        assert inner["t.nest"]["n"] == 2        # frozen at scope exit
        assert outer["t.nest"]["n"] == 3
    p["n"] += 50
    assert outer["t.nest"]["n"] == 3            # outer froze at its own exit
    with pytest.raises(KeyError):
        reg.deltas("t.missing").__enter__()


def test_legacy_stats_dicts_are_registered_probes():
    # the module-global aliases remain the increment idiom; the registry
    # sees every mutation without the owners changing their code
    assert get_probe("engine.chunk_cache") is CHUNK_STATS
    assert get_probe("topology.mix") is MIX_STATS
    with probe_deltas("topology.mix", "engine.chunk_cache") as d:
        MIX_STATS["calls"] += 4
        CHUNK_STATS["hits"] += 1
    assert d["topology.mix"]["calls"] == 4
    assert d["engine.chunk_cache"]["hits"] == 1
    MIX_STATS["calls"] -= 4    # leave the process-lifetime counters as found
    CHUNK_STATS["hits"] -= 1


def test_subsystem_probes_registered_on_import():
    import repro.engine.population    # noqa: F401
    import repro.kernels.dispatch     # noqa: F401
    import repro.resilience           # noqa: F401
    for name in ("engine.prefetch", "kernels.autotune", "resilience.faults"):
        assert name in REGISTRY.names()


# ---------------------------------------------------------------------------
# History.record validation
# ---------------------------------------------------------------------------

def test_history_record_accepts_scalars_and_0d_arrays():
    h = History()
    h.record(0, 0.5, {"a": 1, "b": 2.5, "c": np.float32(3.0),
                      "d": np.asarray(4.0), "e": jnp.asarray(5.0),
                      "f": True})
    assert h.accuracy == [0.5]
    assert h.metrics["d"] == [4.0] and h.metrics["e"] == [5.0]
    assert h.metrics["f"] == [1.0]


def test_history_record_rejects_non_scalars_naming_the_key():
    h = History()
    with pytest.raises(TypeError, match="'grad_norm'.*shape \\(1,\\)"):
        h.record(0, 0.5, {"grad_norm": np.ones((1,))})
    with pytest.raises(TypeError, match="'accuracy'"):
        h.record(0, np.ones((3,)))


# ---------------------------------------------------------------------------
# telemetry: event log / manifest / tap
# ---------------------------------------------------------------------------

def _events(run_dir):
    with open(os.path.join(run_dir, "events.jsonl")) as f:
        return [json.loads(line) for line in f if line.strip()]


def _fit(toy, telemetry=None, rounds=8):
    eng = Engine(_strategy(), eval_every=2, telemetry=telemetry,
                 ledger=PrivacyLedger(sigma=0.7, delta=1e-5))
    return eng.fit(toy, rounds=rounds, key=jax.random.PRNGKey(3),
                   batch_size=8)


def test_tap_event_log_matches_returned_history(toy, tmp_path):
    run_dir = str(tmp_path / "run")
    tel = Telemetry(run_dir, tap=True)
    rounds = 8
    _, hist = _fit(toy, telemetry=tel, rounds=rounds)
    tel.close()

    events = _events(run_dir)
    evals = [e for e in events if e["type"] == "eval"]
    assert [e["round"] for e in evals] == hist.rounds
    assert [e["accuracy"] for e in evals] == pytest.approx(hist.accuracy)
    assert ([e["dp_epsilon"] for e in evals]
            == pytest.approx(hist.metrics["dp_epsilon"]))

    # the tap streamed every scanned round exactly once, σ included
    taps = [e for e in events if e["type"] == "tap"]
    assert sorted(e["round"] for e in taps) == list(range(rounds))
    assert all(e["sigma"] == pytest.approx(0.7) for e in taps)

    manifest = json.load(open(os.path.join(run_dir, "manifest.json")))
    assert manifest["phases"][0]["engine"] == "Engine"
    assert manifest["phases"][0]["strategy"] == "FedAvgStrategy"
    assert ([t["round"] for t in manifest["trajectory"]]
            == [e["round"] for e in evals])
    assert "engine.chunk_cache" in manifest["probes"]

    # chunk spans carry the trace-vs-execute split read off the probe
    chunks = [e for e in events
              if e["type"] == "span" and e["name"] == "chunk"]
    assert chunks and chunks[0]["traced"] is True


def test_tap_on_history_is_bit_exact_with_tap_off(toy):
    state_off, hist_off = _fit(toy)
    tel = Telemetry(None, tap=True)     # disabled: run_dir=None
    state_dis, hist_dis = _fit(toy, telemetry=tel)
    assert hist_off.accuracy == hist_dis.accuracy
    for a, b in zip(jax.tree_util.tree_leaves(state_off),
                    jax.tree_util.tree_leaves(state_dis)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_tap_on_results_bit_exact_with_enabled_telemetry(toy, tmp_path):
    state_off, hist_off = _fit(toy)
    tel = Telemetry(str(tmp_path / "run"), tap=True)
    state_on, hist_on = _fit(toy, telemetry=tel)
    tel.close()
    assert hist_off.accuracy == hist_on.accuracy
    assert hist_off.metrics == hist_on.metrics
    for a, b in zip(jax.tree_util.tree_leaves(state_off),
                    jax.tree_util.tree_leaves(state_on)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_telemetry_off_is_provably_free(toy):
    strategy = _strategy()
    plain = Engine(strategy, eval_every=2)
    k = plain._chunk_key(8, 8)
    assert k == Engine(strategy, eval_every=2,
                       telemetry=Telemetry(None))._chunk_key(8, 8)
    assert k == Engine(strategy, eval_every=2,
                       telemetry=Telemetry(None, tap=True))._chunk_key(8, 8)

    # a disabled-telemetry engine must reuse the warm compiled chunk
    clear_chunk_cache()
    plain.fit(toy, rounds=4, key=jax.random.PRNGKey(0), batch_size=8,
              evaluate=False)
    with probe_deltas("engine.chunk_cache") as d:
        Engine(strategy, eval_every=2,
               telemetry=Telemetry(None, tap=True)).fit(
                   toy, rounds=4, key=jax.random.PRNGKey(0), batch_size=8,
                   evaluate=False)
    assert d["engine.chunk_cache"]["traces"] == 0
    assert d["engine.chunk_cache"]["hits"] > 0

    # ... while a *tapped* chunk is a different traced computation
    tapped = Engine(strategy, eval_every=2,
                    telemetry=Telemetry("/tmp/ignored", tap=True))
    assert tapped._chunk_key(8, 8) != k


def test_monitor_summarize_and_tail(toy, tmp_path):
    run_dir = str(tmp_path / "run")
    tel = Telemetry(run_dir, tap=True)
    _fit(toy, telemetry=tel)
    tel.close()

    text = monitor.summarize(run_dir)
    assert "phase 0: Engine/FedAvgStrategy" in text
    assert "span chunk:" in text
    assert "tap: 8 rounds streamed [0..7]" in text
    assert "trajectory:" in text

    lines = [monitor._fmt_event(e) for e in monitor.load_events(run_dir)]
    assert any(line.startswith("tap") for line in lines)
    assert any(line.startswith("eval") for line in lines)

    # empty dir degrades gracefully
    assert "no telemetry found" in monitor.summarize(str(tmp_path / "void"))
