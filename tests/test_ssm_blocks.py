"""Mamba2 SSD + xLSTM block correctness vs naive recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, SSMConfig
from repro.models.mamba2 import _ssd_chunked
from repro.models.xlstm import mlstm_apply, mlstm_specs, slstm_apply, slstm_specs
from repro.models.module import init_params


def _naive_ssd(x, dt, A, B, C):
    """Reference recurrence: S_t = S_{t-1}·exp(dt_t A) + dt_t B_t x_tᵀ."""
    b, s, H, P = x.shape
    N = B.shape[-1]
    S = np.zeros((b, H, P, N))
    ys = []
    for t in range(s):
        a = np.exp(dt[:, t] * A[None, :])                        # (b, H)
        S = S * a[:, :, None, None] + np.einsum(
            "bhn,bhp,bh->bhpn", B[:, t], x[:, t], dt[:, t])
        ys.append(np.einsum("bhn,bhpn->bhp", C[:, t], S))
    return np.stack(ys, 1), S


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (64, 64)])
def test_ssd_chunked_matches_recurrence(key, s, chunk):
    b, H, P, N = 2, 3, 4, 5
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, H, N))
    C = jax.random.normal(ks[4], (b, s, H, N))
    y, S = _ssd_chunked(x, dt, A, B, C, chunk)
    y_ref, S_ref = _naive_ssd(*(np.asarray(t) for t in (x, dt, A, B, C)))
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)


def test_ssd_chunked_state_continuation(key):
    """Processing [first half; second half with carried state] == full."""
    b, s, H, P, N = 1, 32, 2, 4, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, H, N))
    C = jax.random.normal(ks[4], (b, s, H, N))
    y_full, S_full = _ssd_chunked(x, dt, A, B, C, 8)
    h = s // 2
    y1, S1 = _ssd_chunked(x[:, :h], dt[:, :h], A, B[:, :h], C[:, :h], 8)
    y2, S2 = _ssd_chunked(x[:, h:], dt[:, h:], A, B[:, h:], C[:, h:], 8,
                          init_state=S1)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_full), np.asarray(S2),
                               rtol=2e-4, atol=2e-4)


def _xlstm_cfg():
    return ModelConfig(d_model=32, num_heads=2, num_kv_heads=2, vocab_size=64,
                       family="ssm", xlstm_pattern=("m", "s"), num_layers=2,
                       dtype="float32", param_dtype="float32",
                       ssm=SSMConfig(state_dim=16, num_heads=2, head_dim=16,
                                     chunk_size=8))


def test_mlstm_chunked_matches_stepwise(key):
    """Chunked-parallel mLSTM == sequential stabilized recurrence (decode)."""
    cfg = _xlstm_cfg()
    params = init_params(mlstm_specs(cfg), key, "float32")
    b, s, d = 1, 16, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 9), (b, s, d)) * 0.5

    y_par, _ = mlstm_apply(params, x, cfg, chunk=4)

    H = cfg.num_heads
    hd = d // H
    cache = {"C": jnp.zeros((b, H, hd, hd)), "n": jnp.zeros((b, H, hd)),
             "m": jnp.zeros((b, H))}
    outs = []
    for t in range(s):
        y_t, cache = mlstm_apply(params, x[:, t:t+1], cfg, cache=cache)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=5e-4, atol=5e-4)


def test_slstm_decode_continuation(key):
    """sLSTM over [x1; x2] == sLSTM(x1) then sLSTM(x2 | state)."""
    cfg = _xlstm_cfg()
    params = init_params(slstm_specs(cfg), key, "float32")
    b, s, d = 2, 12, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 3), (b, s, d)) * 0.5
    y_full, _ = slstm_apply(params, x, cfg)
    y1, st = slstm_apply(params, x[:, :6], cfg, return_state=True)
    y2, _ = slstm_apply(params, x[:, 6:], cfg, cache=st)
    np.testing.assert_allclose(np.asarray(y_full[:, 6:]), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
