"""``hypothesis`` when available, a deterministic fallback when not.

The property tier used to be one ``pytest.importorskip("hypothesis")`` away
from silently vanishing — on hosts without hypothesis the whole module
collapsed into the suite's perpetual "1 skipped", hiding every invariant it
covers. This shim keeps real hypothesis (shrinking, edge-case generation)
where it is installed — CI installs it — and otherwise degrades to a seeded
sweep: each ``@given`` test runs ``max_examples`` times over the strategies'
bounds first (the corners hypothesis would try) and uniform draws after.

Only the strategy surface the suite actually uses is emulated:
``st.integers``, ``st.floats``, ``st.sampled_from``, ``st.booleans``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, corners, draw):
            self.corners = list(corners)
            self.draw = draw

    class st:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy([min_value, max_value],
                             lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy([min_value, max_value],
                             lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(elements[:1],
                             lambda rng: rng.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy([False, True],
                             lambda rng: rng.random() < 0.5)

    def settings(max_examples: int = 25, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # zero-arg wrapper: pytest must not mistake the drawn parameters
            # for fixtures (hypothesis rewrites the signature the same way)
            def wrapper():
                n = getattr(wrapper, "_max_examples", 25)
                rng = random.Random(0x9E3779B9)
                corners = max(len(s.corners) for s in strats)
                for i in range(corners + n):
                    drawn = [s.corners[i] if i < len(s.corners) else s.draw(rng)
                             for s in strats]
                    fn(*drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 25)
            return wrapper
        return deco
