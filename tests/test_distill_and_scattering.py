"""Knowledge-distillation losses (Eqs. 6–9) + ScatterNet features (§4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distill import private_loss, proxy_loss
from repro.core.scattering import scatter_feature_dim, scatternet_features
from repro.models.layers import kl_divergence, softmax_cross_entropy


def test_alpha_zero_is_pure_ce(key):
    lg1 = jax.random.normal(key, (8, 10))
    lg2 = jax.random.normal(jax.random.fold_in(key, 1), (8, 10))
    y = jnp.arange(8) % 10
    assert float(proxy_loss(lg1, lg2, y, alpha=0.0)) == pytest.approx(
        float(softmax_cross_entropy(lg1, y)), rel=1e-6)
    assert float(private_loss(lg1, lg2, y, beta=0.0)) == pytest.approx(
        float(softmax_cross_entropy(lg1, y)), rel=1e-6)


def test_kl_self_zero(key):
    lg = jax.random.normal(key, (4, 7))
    assert abs(float(kl_divergence(lg, lg))) < 1e-6


def test_kl_nonnegative(key):
    p = jax.random.normal(key, (16, 9))
    q = jax.random.normal(jax.random.fold_in(key, 1), (16, 9))
    assert float(kl_divergence(p, q)) >= 0.0


def test_distill_targets_stop_gradient(key):
    """The KL target carries no gradient (deep-mutual-learning semantics)."""
    y = jnp.zeros((4,), jnp.int32)
    w1 = jax.random.normal(key, (3, 5))
    w2 = jax.random.normal(jax.random.fold_in(key, 1), (3, 5))
    x = jax.random.normal(jax.random.fold_in(key, 2), (4, 3))

    def loss_wrt_target(w_tgt):
        return proxy_loss(x @ w1, x @ w_tgt, y, alpha=0.7)
    g = jax.grad(loss_wrt_target)(w2)
    assert float(jnp.max(jnp.abs(g))) == 0.0


# ---------------------------------------------------------------------------
# ScatterNet
# ---------------------------------------------------------------------------

def test_scattering_dims_match_paper():
    """81 channels grayscale, 243 RGB, spatial /4 (paper §4.2)."""
    assert scatter_feature_dim((28, 28, 1)) == 81 * 7 * 7
    assert scatter_feature_dim((32, 32, 3)) == 243 * 8 * 8


@pytest.mark.parametrize("shape", [(28, 28, 1), (32, 32, 3)])
def test_scattering_output_shape(key, shape):
    x = jax.random.normal(key, (3,) + shape)
    f = scatternet_features(x)
    assert f.shape == (3, scatter_feature_dim(shape))
    assert bool(jnp.all(jnp.isfinite(f)))


def test_scattering_translation_stability(key):
    """Scattering features move less under a 2-px shift than raw pixels
    (the whole point of the handcrafted frontend)."""
    x = np.zeros((1, 28, 28, 1), np.float32)
    x[0, 10:18, 10:18, 0] = 1.0
    xs = np.roll(x, 2, axis=2)
    f1 = np.asarray(scatternet_features(jnp.asarray(x), normalize=False))
    f2 = np.asarray(scatternet_features(jnp.asarray(xs), normalize=False))
    rel_feat = np.linalg.norm(f1 - f2) / np.linalg.norm(f1)
    rel_raw = np.linalg.norm(x - xs) / np.linalg.norm(x)
    assert rel_feat < rel_raw
