"""Attention correctness: chunked (flash-algorithm) vs full, windows, GQA,
decode-vs-teacher-forcing consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, replace
from repro.models.attention import _chunked_attention, _full_attention, attention


def _cfg(**kw):
    base = dict(d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                vocab_size=128, dtype="float32", param_dtype="float32",
                logits_dtype="float32", remat="none")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("s", [128, 256])
def test_chunked_matches_full(key, window, s):
    cfg = _cfg()
    b, hq, hkv, hd = 2, 4, 2, 16
    q = jax.random.normal(key, (b, s, hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, hd))
    pos = jnp.arange(s)
    full = _full_attention(q, k, v, cfg, pos, pos, window)
    chunked = _chunked_attention(q, k, v, cfg, window, q_chunk=64, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_teacher_forcing(key):
    """Logits from (prefill s-1 tokens, then decode 1) == full forward."""
    from repro.configs import get_reduced_config
    from repro.models.api import build_model
    cfg = replace(get_reduced_config("llama3.2-1b"),
                  dtype="float32", logits_dtype="float32",
                  kv_cache_dtype="float32")
    api = build_model(cfg)
    params = api.init(key)
    b, s = 2, 24
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    from repro.models import transformer
    logits_full, _, _ = transformer.forward(params, cfg, {"tokens": tokens})
    want = logits_full[:, -1]

    # prefill on s-1 tokens, decode token s-1
    lg, cache = api.prefill_fn(params, {"tokens": tokens[:, :-1]})
    full_cache = api.init_caches(b, s)
    cache = jax.tree_util.tree_map(
        lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), 0, axis=2), full_cache, cache)
    got, _ = api.decode_fn(params, cache,
                           {"tokens": tokens[:, -1:],
                            "index": jnp.asarray(s - 1, jnp.int32)})
    np.testing.assert_allclose(np.asarray(want), np.asarray(got[:, 0]),
                               rtol=5e-3, atol=5e-3)


def test_gqa_kv_expansion_equivalence(key):
    """GQA with kv groups == MHA with repeated kv heads."""
    cfg = _cfg()
    b, s, hq, hkv, hd = 1, 64, 4, 2, 16
    q = jax.random.normal(key, (b, s, hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, hd))
    pos = jnp.arange(s)
    gqa = _full_attention(q, k, v, cfg, pos, pos, 0)
    mha = _full_attention(q, jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2),
                          _cfg(num_kv_heads=4), pos, pos, 0)
    np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha), rtol=1e-5, atol=1e-5)


def test_window_masks_distant_tokens(key):
    """With window w, changing a key beyond the window cannot change output."""
    cfg = _cfg()
    b, s, h, hd = 1, 64, 2, 8
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    pos = jnp.arange(s)
    out1 = _full_attention(q, k, v, _cfg(num_kv_heads=2), pos, pos, 16)
    k2 = k.at[:, 0].set(99.0)   # token 0 is outside the window of the last query
    v2 = v.at[:, 0].set(99.0)
    out2 = _full_attention(q, k2, v2, _cfg(num_kv_heads=2), pos, pos, 16)
    np.testing.assert_allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]),
                               rtol=1e-6, atol=1e-6)


def test_mrope_degenerates_to_rope_on_text(key):
    """Equal (t,h,w) positions => M-RoPE == RoPE (qwen2-vl property)."""
    from repro.models.rope import apply_mrope, apply_rope
    b, s, h, hd = 2, 16, 2, 16
    x = jax.random.normal(key, (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    thw = jnp.broadcast_to(pos[None], (3, b, s))
    got = apply_mrope(x, thw, (2, 3, 3))
    want = apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
